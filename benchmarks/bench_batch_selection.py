"""Batched-selection benchmark: selector-vs-oracle regret as the
right-hand-side batch grows.

Production decode traffic arrives in batches: one entropy decode of the
matrix amortizes over B right-hand sides, which changes the modeled
trade every format makes — matrix bytes and decode work are paid once
per SpMM pass, x/y bytes and contraction work once per RHS (the SMASH
co-design point: the winning compressed layout depends on the access
pattern that consumes it). This section sweeps ``select(batch=B)``
against the exhaustive exact-size oracle at the same B and reports

  * per (matrix, B): the selector's pick, the oracle's pick, and the
    modeled regret (both sides priced by the same `candidate_time`, so
    regret 0 means genuine agreement at that batch size);
  * per matrix: whether the winning config *flips* across the sweep —
    the whole reason the batch knob exists (e.g. low-padding row groups
    overtake SELL once contraction work dominates);
  * summary rows: distinct batch sizes recorded (CI asserts >= 2),
    flip count, and mean/max regret per B.
"""

from __future__ import annotations

import numpy as np

from benchmarks.suite import cached_suite
# The fig9 section's encode memo: `--only fig9,batch` (the CI smoke
# command) runs both sections in one process, and the oracle's
# constructed sizes are B-independent — a private cache here would
# re-encode every candidate, doubling the most expensive part of the
# smoke run.
from benchmarks.bench_format_selection import _ENC
from repro.autotune import DecisionCache, clear_memo, select
from repro.autotune.oracle import oracle_best
from repro.sparse.formats import CSR

#: Right-hand-side counts swept: the single-vector regime, a typical
#: serving pool, a prefill-sized burst, and the large-batch regime
#: where per-RHS contraction work dominates (the suite's stencil/BA
#: matrices flip SELL -> RGCSR there: padding-light row groups win
#: once the padded lock-step slots are paid B times per pass).
BATCH_SIZES = (1, 8, 32, 128)


def run(small: bool = False, batches: tuple = BATCH_SIZES):
    rows = []
    flips = 0
    total = 0
    regrets = {B: [] for B in batches}
    cache = DecisionCache(path=None)   # memory-only: honest measurement
    clear_memo()

    for name, a64 in cached_suite(small=small).items():
        a = CSR(a64.indptr, a64.indices,
                a64.values.astype(np.float32), a64.shape)
        enc = _ENC.setdefault(name, {})
        picks = {}
        for B in batches:
            dec = select(a, warm=True, batch=B, cache=cache)
            o_name, o_time, times = oracle_best(a, warm=True, batch=B,
                                                encode_cache=enc)
            regret = times[dec.config_name] / o_time - 1.0
            regrets[B].append(regret)
            picks[B] = dec.config_name
            rows.append((f"fig9batch/{name}@B{B}", 0.0,
                         f"pick={dec.config_name};oracle={o_name};"
                         f"regret={regret:.4f}"))
        flipped = len(set(picks.values())) > 1
        flips += flipped
        total += 1
        rows.append((f"fig9batch/{name}/sweep", 0.0,
                     "flips=" + ("yes" if flipped else "no") + ";" +
                     ";".join(f"B{B}={picks[B]}" for B in batches)))

    rows.append(("fig9batch/batch_sizes", 0.0,
                 f"count={len(batches)};" +
                 "sizes=" + ",".join(str(B) for B in batches)))
    rows.append(("fig9batch/format_flips", 0.0, f"{flips}/{total}"))
    for B in batches:
        rows.append((f"fig9batch/mean_regret@B{B}", 0.0,
                     f"{float(np.mean(regrets[B])):.4f}"))
        rows.append((f"fig9batch/max_regret@B{B}", 0.0,
                     f"{float(np.max(regrets[B])):.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
