"""MachineModel calibration section: fit the cost-model constants to
measured kernel times and report how far the hand-tuned defaults were.

Rows:
  calib/point/<matrix>_<config> — measured vs modeled-before/after
    seconds for every sweep measurement;
  calib/err_before, calib/err_after — mean |modeled - measured| /
    measured across the sweep under the default V5E constants vs the
    fitted ones (the ISSUE's acceptance number);
  calib/constants — the fitted MachineModel, also persisted as a named
    machine profile when ``profile_json`` is given (the CI timing-smoke
    leg uploads that file next to the fig9 smoke JSON).

On CPU hosts the kernels run in Pallas interpret mode, so the fitted
constants describe the *harness*, not a TPU — the point the section
demonstrates is the calibration loop itself: measured times in, a
MachineModel with a distinct cache signature and a smaller
modeled-vs-measured error out.
"""

from __future__ import annotations

from repro.autotune import calibrate, save_profile


def run(small: bool = False, profile_json: str | None = None,
        repeats: int = 2):
    res = calibrate(small=small, repeats=repeats)
    rows = []
    for p in res.points:
        rows.append((f"calib/point/{p.matrix}_{p.config_name}",
                     p.measured * 1e6,
                     f"modeled_before={p.modeled_before:.3e};"
                     f"modeled_after={p.modeled_after:.3e};"
                     f"measured={p.measured:.3e}"))
    rows.append(("calib/err_before", 0.0, f"{res.err_before:.4f}"))
    rows.append(("calib/err_after", 0.0, f"{res.err_after:.4f}"))
    m = res.model
    rows.append(("calib/constants", 0.0,
                 f"name={m.name};hbm_bw={m.hbm_bw:.4g};"
                 f"cache_bw={m.cache_bw:.4g};"
                 f"spmv_ops_per_elem={m.spmv_ops_per_elem:.4g};"
                 f"row_seq_penalty={m.row_seq_penalty:.4g};"
                 f"decode_ops_per_nnz={m.decode_ops_per_nnz:.4g}"))
    if profile_json:
        path = save_profile(m, meta={"err_before": res.err_before,
                                     "err_after": res.err_after,
                                     "points": len(res.points),
                                     "interpret": True},
                            path=profile_json)
        rows.append(("calib/profile", 0.0, f"saved={path}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
