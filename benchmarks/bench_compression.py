"""Paper Fig. 6 + Table I: CSR-dtANS compressed size vs the smallest of
CSR/COO/SELL, for 64- and 32-bit values, with the Table-I success-rate
grouping by total nonzeros and avg nonzeros/row."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.suite import cached_encode, cached_suite
from repro.core.csr_dtans import encode_matrix
from repro.sparse.formats import CSR, best_baseline_nbytes


def run(small: bool = False):
    rows = []
    cells: dict[tuple, list] = {}
    for name, a64 in cached_suite(small=small).items():
        for bits, dtype in ((64, np.float64), (32, np.float32)):
            a = CSR(a64.indptr, a64.indices,
                    a64.values.astype(dtype), a64.shape)
            t0 = time.time()
            mat = cached_encode(name, a, bits)
            enc_us = (time.time() - t0) * 1e6
            bname, bb = best_baseline_nbytes(a)
            ratio = bb / mat.nbytes
            rows.append((f"fig6/{name}_{bits}b", enc_us,
                         f"ratio={ratio:.3f};best={bname};"
                         f"dtans_B={mat.nbytes};base_B={bb}"))
            annzpr = a.nnz / max(a.shape[0], 1)
            nnz_bin = ("<=2^10" if a.nnz <= 2 ** 10 else
                       "<=2^15" if a.nnz <= 2 ** 15 else ">2^15")
            key = (bits, nnz_bin, "annzpr<=10" if annzpr <= 10
                   else "annzpr>10")
            cells.setdefault(key, []).append(ratio > 1.0)
    for (bits, nnz_bin, apr), oks in sorted(cells.items()):
        rows.append((f"table1/{bits}b_{nnz_bin}_{apr}", 0.0,
                     f"{sum(oks)}/{len(oks)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
