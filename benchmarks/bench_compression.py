"""Paper Fig. 6 + Table I: CSR-dtANS compressed size vs the smallest of
CSR/COO/SELL, for 64- and 32-bit values, with the Table-I success-rate
grouping by total nonzeros and avg nonzeros/row.

Beyond-paper column: the best row-grouped CSR size (`repro.sparse.rgcsr`,
byte-exact over the G sweep) next to the cuSPARSE baseline — RGCSR is
not part of the paper's Fig. 6 denominator (see
`formats.best_baseline_nbytes`), but shows what plain row grouping buys
before any entropy coding."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.suite import cached_encode, cached_suite
from repro.core.csr_dtans import encode_matrix
from repro.sparse.formats import CSR, all_format_nbytes


def run(small: bool = False):
    rows = []
    cells: dict[tuple, list] = {}
    for name, a64 in cached_suite(small=small).items():
        for bits, dtype in ((64, np.float64), (32, np.float32)):
            a = CSR(a64.indptr, a64.indices,
                    a64.values.astype(dtype), a64.shape)
            t0 = time.time()
            mat = cached_encode(name, a, bits)
            enc_us = (time.time() - t0) * 1e6
            sizes = all_format_nbytes(a)
            bname, bb = min(((k, sizes[k]) for k in ("csr", "coo",
                                                     "sell")),
                            key=lambda kv: kv[1])
            ratio = bb / mat.nbytes
            rg_name, rg_b = min(
                ((k, v) for k, v in sizes.items()
                 if k.startswith("rgcsr")), key=lambda kv: kv[1])
            rows.append((f"fig6/{name}_{bits}b", enc_us,
                         f"ratio={ratio:.3f};best={bname};"
                         f"dtans_B={mat.nbytes};base_B={bb};"
                         f"rg_B={rg_b};rg_best={rg_name}"))
            annzpr = a.nnz / max(a.shape[0], 1)
            nnz_bin = ("<=2^10" if a.nnz <= 2 ** 10 else
                       "<=2^15" if a.nnz <= 2 ** 15 else ">2^15")
            key = (bits, nnz_bin, "annzpr<=10" if annzpr <= 10
                   else "annzpr>10")
            cells.setdefault(key, []).append(ratio > 1.0)
    for (bits, nnz_bin, apr), oks in sorted(cells.items()):
        rows.append((f"table1/{bits}b_{nnz_bin}_{apr}", 0.0,
                     f"{sum(oks)}/{len(oks)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
