"""Paper Fig. 4: entropy reduction of delta-encoded column indices on
Erdős–Rényi / Watts–Strogatz / Barabási–Albert random graphs, degrees
5/10/20, growing node counts. Reports relative entropy H(delta)/H(raw)
(median of 3 seeds, as in the paper)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.delta import delta_encode_rows
from repro.core.entropy import stream_entropy_bits
from repro.sparse.random_graphs import (barabasi_albert, erdos_renyi,
                                        watts_strogatz)


def run(small: bool = False):
    sizes = [1000, 4000, 16000] if small else [1000, 4000, 16000, 64000]
    degrees = [5, 10, 20]
    models = {
        "erdos_renyi": lambda n, d, rng: erdos_renyi(n, d, rng),
        "watts_strogatz": lambda n, d, rng: watts_strogatz(
            n, max(1, d // 2), 0.1, rng),
        "barabasi_albert": lambda n, d, rng: barabasi_albert(
            n, max(1, d // 2), rng),
    }
    rows = []
    for mname, gen in models.items():
        for d in degrees:
            for n in sizes:
                rels = []
                t0 = time.time()
                for seed in range(3):
                    rng = np.random.default_rng(seed)
                    a = gen(n, d, rng)
                    h_raw = stream_entropy_bits(a.indices)
                    h_del = stream_entropy_bits(
                        delta_encode_rows(a.indptr, a.indices))
                    rels.append(h_del / max(h_raw, 1e-9))
                us = (time.time() - t0) / 3 * 1e6
                rel = float(np.median(rels))
                rows.append((f"fig4/{mname}_d{d}_n{n}", us, f"{rel:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
