"""Paper Fig. 9 analogue: CSR-dtANS vs a per-matrix oracle, plus the
`repro.autotune` selector measured against that oracle.

AlphaSparse (hours of GPU autotuning per matrix) is not runnable here; its
role — "the best format per matrix" — is played by an oracle that picks
argmin of the modeled runtime with *exact* byte counts for every
candidate, including actually-encoded CSR-dtANS. The paper's question
survives translation: can a FIXED entropy-coded format beat a
per-matrix-tuned uncompressed one? (Fig. 9: yes, for 28/229 matrices.)

New in this section: the fingerprint-based selector's *regret* vs that
oracle —

    regret = t_model(selector pick) / t_model(oracle pick) - 1

which is the number AlphaSparse pays hours to drive to zero and
`repro.autotune.select` pays microseconds to keep small. Also reported:
agreement rate, cold/warm selection wall time, and the warm-cache hit
overhead relative to one modeled SpMVM pass.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.suite import cached_encode, cached_suite, model_time, spmv_bytes
from repro.autotune import DecisionCache, clear_memo, dtans_config_name, select
from repro.autotune.cost_model import DTANS_LANE_WIDTHS, DTANS_SHARED_TABLE
from repro.sparse.formats import COO, CSR, SELL


def _oracle(name: str, a: CSR, warm: bool) -> tuple[str, float, dict]:
    """Exact-size argmin over {csr, coo, sell, dtans x configs}."""
    m, n = a.shape
    vb = a.values.dtype.itemsize
    times = {}
    for fmt, b in (("csr", a.nbytes), ("coo", COO.from_csr(a).nbytes),
                   ("sell", SELL.from_csr(a).nbytes)):
        times[fmt] = model_time(spmv_bytes(b, n, m, vb), a.nnz,
                                warm=warm, decode=False)
    from repro.core.csr_dtans import encode_matrix
    for w in DTANS_LANE_WIDTHS:
        for shared in DTANS_SHARED_TABLE:
            key = (name, w, shared)
            mat = _ENC.get(key)
            if mat is None:
                mat = encode_matrix(a, lane_width=w, shared_table=shared)
                _ENC[key] = mat
            times[dtans_config_name(w, shared)] = model_time(
                spmv_bytes(mat.nbytes, n, m, vb), a.nnz,
                warm=warm, decode=True)
    best = min(times, key=times.get)
    return best, times[best], times


_ENC: dict = {}


def run(small: bool = False):
    rows = []
    wins = 0
    agree = 0
    total = 0
    regrets = []
    cache = DecisionCache(path=None)  # memory-only: honest measurement
    clear_memo()

    for name, a64 in cached_suite(small=small).items():
        a = CSR(a64.indptr, a64.indices,
                a64.values.astype(np.float32), a64.shape)
        vb = 4
        m, n = a.shape

        # --- Fig. 9 proper: fixed CSR-dtANS vs best-uncompressed oracle
        sizes = {"csr": a.nbytes, "coo": COO.from_csr(a).nbytes,
                 "sell": SELL.from_csr(a).nbytes}
        t_uncomp = min(model_time(spmv_bytes(b, n, m, vb), a.nnz,
                                  warm=True, decode=False)
                       for b in sizes.values())
        mat = cached_encode(name, a, 32)
        _ENC.setdefault((name, 128, True), mat)  # encode_matrix defaults
        t_dtans = model_time(spmv_bytes(mat.nbytes, n, m, vb), a.nnz,
                             warm=True, decode=True)
        sp = t_uncomp / t_dtans
        wins += sp > 1.0
        total += 1
        rows.append((f"fig9/{name}", 0.0, f"speedup_vs_oracle={sp:.3f}"))

        # --- selector vs exact oracle (the autotune subsystem's regret)
        t0 = time.perf_counter()
        dec = select(a, warm=True, cache=cache)
        t_cold = time.perf_counter() - t0
        reps = 100
        t0 = time.perf_counter()
        for _ in range(reps):                # identity-memo hits
            select(a, warm=True, cache=cache)
        t_hit = (time.perf_counter() - t0) / reps
        o_name, o_time, times = _oracle(name, a, warm=True)
        t_pick = times[dec.config_name] if dec.config_name in times else \
            dec.modeled_time
        regret = t_pick / o_time - 1.0
        regrets.append(regret)
        agree += dec.config_name == o_name
        rows.append((f"fig9sel/{name}", t_cold * 1e6,
                     f"pick={dec.config_name};oracle={o_name};"
                     f"regret={regret:.4f};"
                     f"hit_overhead_vs_pass={t_hit / o_time:.3f}"))

    rows.append(("fig9/wins", 0.0, f"{wins}/{total}"))
    rows.append(("fig9sel/agreement", 0.0, f"{agree}/{total}"))
    rows.append(("fig9sel/mean_regret", 0.0,
                 f"{float(np.mean(regrets)):.4f}"))
    rows.append(("fig9sel/max_regret", 0.0,
                 f"{float(np.max(regrets)):.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
