"""Paper Fig. 9 analogue: CSR-dtANS vs a per-matrix oracle, plus the
`repro.autotune` selector measured against that oracle.

AlphaSparse (hours of GPU autotuning per matrix) is not runnable here; its
role — "the best format per matrix" — is played by the exhaustive oracle
of `repro.autotune.oracle`: argmin of the modeled runtime with *exact*
byte counts for every candidate, including actually-encoded CSR-dtANS
and RGCSR-dtANS. The paper's question survives translation: can a FIXED
entropy-coded format beat a per-matrix-tuned uncompressed one? (Fig. 9:
yes, for 28/229 matrices.)

Model bases, deliberately different per row family: the ``fig9/`` rows
keep the paper's legacy two-term model (`cost_model.model_time`, same
basis as Figs. 7/8 and as pre-RGCSR runs of this benchmark, so the win
count stays comparable to the paper's 28/229); the ``fig9sel/`` and
``fig9rg/`` rows use the selector's `spmv_time` model (per-format kernel
work terms), which is the model the selector is accountable to.

New in this section: the fingerprint-based selector's *regret* vs that
oracle —

    regret = t_model(selector pick) / t_model(oracle pick) - 1

which is the number AlphaSparse pays hours to drive to zero and
`repro.autotune.select` pays microseconds to keep small. Also reported:
agreement rate, cold/warm selection wall time, the warm-cache hit
overhead relative to one modeled SpMVM pass, and — per matrix — how the
best row-grouped candidate (RGCSR / RGCSR-dtANS) fares against the best
ungrouped one (the padding-waste vs slice-alignment trade the group
sweep exists for).

The ``fig9meas/`` rows close the modeled-vs-measured loop:
``select(budget=2, measure=True)`` wall-clock times the top candidates'
real kernels (`repro.autotune.measure`; Pallas interpret mode on CPU
hosts, so the absolute microseconds are harness numbers, not TPU
claims), and the *measured* regret compares the selector's measured
pick against the measured time of the exact-size oracle's pick — the
regret currency AlphaSparse actually optimizes. ``model_err`` is the
|modeled - measured| / measured gap of the pick under the hand-tuned
MachineModel; the ``calib`` benchmark section shows how much
calibration shrinks it.
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.suite import cached_suite, model_time, spmv_bytes
from repro.autotune import (DecisionCache, clear_memo, format_names,
                            measure_named, select)
from repro.autotune.oracle import oracle_best
from repro.sparse.formats import CSR, all_format_nbytes


_ENC: dict = {}


def load_mtx_dir(mtx_dir: str, max_nnz: int | None = None) -> dict:
    """Real MatrixMarket files (SuiteSparse downloads) -> name -> CSR,
    skipping files whose nnz exceeds ``max_nnz`` (encode-everything
    oracles get expensive; the guard keeps a stray full-size
    SuiteSparse drop from hanging the benchmark)."""
    from repro.sparse.io import load_mtx
    out: dict = {}
    for fn in sorted(os.listdir(mtx_dir)):
        if not (fn.endswith(".mtx") or fn.endswith(".mtx.gz")):
            continue
        stem = fn[:-len(".mtx.gz")] if fn.endswith(".mtx.gz") \
            else fn[:-len(".mtx")]
        if f"mtx/{stem}" in out:
            # foo.mtx already loaded and foo.mtx.gz sits beside it (a
            # kept-compressed download next to its extraction).
            print(f"# mtx/{stem}: skipped ({fn} duplicates an "
                  f"already-loaded stem)", flush=True)
            continue
        try:
            a = load_mtx(os.path.join(mtx_dir, fn))
        except (ValueError, OSError, EOFError) as e:
            # ValueError: unsupported/malformed MatrixMarket content;
            # OSError covers gzip.BadGzipFile and unreadable files,
            # EOFError truncated .gz — all skip-and-continue, a stray
            # corrupt download must not abort the whole benchmark.
            print(f"# mtx/{stem}: skipped ({e})", flush=True)
            continue
        if max_nnz is not None and a.nnz > max_nnz:
            print(f"# mtx/{stem}: skipped (nnz {a.nnz} > "
                  f"--max-nnz {max_nnz})", flush=True)
            continue
        out[f"mtx/{stem}"] = a
    return out


def run(small: bool = False, measure: bool = True,
        mtx_dir: str | None = None, max_nnz: int | None = 2_000_000):
    rows = []
    wins = 0
    agree = 0
    total = 0
    regrets = []
    meas_regrets = []
    model_errs = []
    rg_wins = 0
    cache = DecisionCache(path=None)  # memory-only: honest measurement
    cache_meas = DecisionCache(path=None)
    clear_memo()

    suite = dict(cached_suite(small=small))
    if mtx_dir:
        suite.update(load_mtx_dir(mtx_dir, max_nnz=max_nnz))
    # A silently broken FormatSpec registration would shrink this count
    # and the candidate sweep with it; CI asserts >= 9 (every built-in)
    # on the smoke JSON artifact.
    rows.append(("fig9/registry_formats", 0.0,
                 f"count={len(format_names())}"))

    for name, a64 in suite.items():
        a = CSR(a64.indptr, a64.indices,
                a64.values.astype(np.float32), a64.shape)

        # --- selection wall time (cold search, then identity-memo hits)
        t0 = time.perf_counter()
        dec = select(a, warm=True, cache=cache)
        t_cold = time.perf_counter() - t0
        reps = 100
        t0 = time.perf_counter()
        for _ in range(reps):
            select(a, warm=True, cache=cache)
        t_hit = (time.perf_counter() - t0) / reps

        # --- exhaustive exact-size oracle (shared with the tests)
        enc = _ENC.setdefault(name, {})
        o_name, o_time, times = oracle_best(a, warm=True,
                                            encode_cache=enc)

        # --- Fig. 9 proper: fixed CSR-dtANS vs best-uncompressed oracle,
        # on the paper's legacy model (see module docstring).
        m, n = a.shape
        vb = a.values.dtype.itemsize
        sizes = all_format_nbytes(a, group_sizes=())
        t_uncomp = min(model_time(spmv_bytes(sizes[k], n, m, vb), a.nnz,
                                  warm=True, decode=False)
                       for k in ("csr", "coo", "sell"))
        dtans_b = enc[("dtans", 128, True)].nbytes   # encode_matrix defaults
        t_dtans = model_time(spmv_bytes(dtans_b, n, m, vb), a.nnz,
                             warm=True, decode=True)
        sp = t_uncomp / t_dtans
        wins += sp > 1.0
        total += 1
        rows.append((f"fig9/{name}", 0.0, f"speedup_vs_oracle={sp:.3f}"))

        # --- row-grouping head-to-head: best grouped vs best ungrouped
        grouped = min(v for k, v in times.items() if k.startswith("rgcsr"))
        ungrouped = min(v for k, v in times.items()
                        if not k.startswith("rgcsr"))
        rg_wins += grouped < ungrouped
        rows.append((f"fig9rg/{name}", 0.0,
                     f"grouped_speedup={ungrouped / grouped:.3f}"))

        # --- selector vs exact oracle (the autotune subsystem's regret)
        t_pick = times[dec.config_name] if dec.config_name in times else \
            dec.modeled_time
        regret = t_pick / o_time - 1.0
        regrets.append(regret)
        agree += dec.config_name == o_name
        rows.append((f"fig9sel/{name}", t_cold * 1e6,
                     f"pick={dec.config_name};oracle={o_name};"
                     f"regret={regret:.4f};"
                     f"hit_overhead_vs_pass={t_hit / o_time:.3f}"))

        # --- measured refinement: time the real kernels of the top
        # candidates and compare against the measured oracle pick
        if measure:
            clear_memo()
            dec_m = select(a, warm=True, budget=2, measure=True,
                           measure_repeats=2, cache=cache_meas,
                           artifacts=enc)
            if dec_m.config_name == o_name:
                t_meas_oracle = dec_m.measured_time
            else:
                t_meas_oracle = measure_named(a, o_name, repeats=2,
                                              artifacts=enc)
            m_regret = dec_m.measured_time / t_meas_oracle - 1.0
            meas_regrets.append(m_regret)
            m_err = (abs(dec_m.modeled_time - dec_m.measured_time)
                     / dec_m.measured_time)
            model_errs.append(m_err)
            rows.append((f"fig9meas/{name}", dec_m.measured_time * 1e6,
                         f"pick={dec_m.config_name};oracle={o_name};"
                         f"measured_regret={m_regret:.4f};"
                         f"model_err={m_err:.3f}"))

    rows.append(("fig9/wins", 0.0, f"{wins}/{total}"))
    rows.append(("fig9rg/wins", 0.0, f"{rg_wins}/{total}"))
    rows.append(("fig9sel/agreement", 0.0, f"{agree}/{total}"))
    rows.append(("fig9sel/mean_regret", 0.0,
                 f"{float(np.mean(regrets)):.4f}"))
    rows.append(("fig9sel/max_regret", 0.0,
                 f"{float(np.max(regrets)):.4f}"))
    if meas_regrets:
        rows.append(("fig9meas/mean_measured_regret", 0.0,
                     f"{float(np.mean(meas_regrets)):.4f}"))
        rows.append(("fig9meas/max_measured_regret", 0.0,
                     f"{float(np.max(meas_regrets)):.4f}"))
        rows.append(("fig9meas/mean_model_err", 0.0,
                     f"{float(np.mean(model_errs)):.3f}"))

    # --- obs snapshot: what the autotune/kernel instrumentation saw
    # over this whole section (process default registry — decision-cache
    # traffic, selector decisions by source, decode-kernel invocations,
    # timing dispersion). These rows make the smoke JSON carry the
    # telemetry the observability layer exists to track.
    from repro import obs
    snap = obs.default_registry().snapshot()
    c, h = snap["counters"], snap["histograms"]
    hits = c.get("autotune.decision_cache.hits", 0)
    misses = c.get("autotune.decision_cache.misses", 0)
    rows.append(("fig9obs/decision_cache", 0.0,
                 f"hits={hits};misses={misses};"
                 f"hit_rate={hits / max(hits + misses, 1):.3f}"))
    rows.append(("fig9obs/decisions", 0.0,
                 f"search={c.get('autotune.decisions.search', 0)};"
                 f"cache={c.get('autotune.decisions.cache', 0)};"
                 f"memo_hits={c.get('autotune.memo_hits', 0)}"))
    rows.append(("fig9obs/kernels", 0.0,
                 f"decode_invocations="
                 f"{c.get('kernels.decode_invocations', 0)};"
                 f"spmm_calls={c.get('kernels.spmm_calls', 0)}"))
    tq = h.get("autotune.timing.rel_iqr", {})
    rows.append(("fig9obs/timing", 0.0,
                 f"timings={c.get('autotune.timings', 0)};"
                 f"noisy={c.get('autotune.timing.noisy', 0)};"
                 f"rel_iqr_p50={tq.get('p50', 0.0):.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
