"""Paper Fig. 9 analogue: CSR-dtANS vs a per-matrix oracle format selector.

AlphaSparse (hours of GPU autotuning per matrix) is not runnable here; its
role — "the best uncompressed format per matrix" — is played by an oracle
that picks argmin of the modeled runtime over {CSR, COO, SELL} per matrix
(which upper-bounds any selector restricted to those formats). The paper's
question survives translation: can a FIXED entropy-coded format beat a
per-matrix-tuned uncompressed one? (Fig. 9: yes, for 28/229 matrices.)"""

from __future__ import annotations

import numpy as np

from benchmarks.suite import (cached_encode, cached_suite, model_time,
                              spmv_bytes)
from repro.core.csr_dtans import encode_matrix
from repro.sparse.formats import COO, CSR, SELL


def run(small: bool = False):
    rows = []
    wins = 0
    total = 0
    for name, a64 in cached_suite(small=small).items():
        a = CSR(a64.indptr, a64.indices,
                a64.values.astype(np.float32), a64.shape)
        vb = 4
        m, n = a.shape
        sizes = {"csr": a.nbytes, "coo": COO.from_csr(a).nbytes,
                 "sell": SELL.from_csr(a).nbytes}
        t_oracle = min(model_time(spmv_bytes(b, n, m, vb), a.nnz,
                                  warm=True, decode=False)
                       for b in sizes.values())
        mat = cached_encode(name, a, 32)
        t_dtans = model_time(spmv_bytes(mat.nbytes, n, m, vb), a.nnz,
                             warm=True, decode=True)
        sp = t_oracle / t_dtans
        wins += sp > 1.0
        total += 1
        rows.append((f"fig9/{name}", 0.0, f"speedup_vs_oracle={sp:.3f}"))
    rows.append(("fig9/wins", 0.0, f"{wins}/{total}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
