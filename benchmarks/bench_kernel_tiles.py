"""fig9tile — kernel tile/pipeline microbench: the grid-blocked SpMM
schedule and the fused BCSR-dtANS block-decode, measured.

Three row families over a batch sweep (B in {8, 128, 1024, 4096}; the
``--small`` CI run stops at 1024):

* ``fig9tile/tiled_*`` — the dtANS SpMM at the best tile configuration
  (bn swept over {untiled, B/4, B/8} and the VMEM-budget auto choice)
  vs the untiled kernel. On hardware the win is VMEM capacity: tiling
  keeps x/y column blocks resident while the stream decodes once per
  tile. Interpret mode has no VMEM, so the best-config sweep INCLUDES
  the untiled schedule — the reported ratio is best-over-configs and
  is >= 1 up to timer noise by construction; the hardware-shaped claim
  lives in the cost model's capacity term (docs/kernels.md).
* ``fig9tile/fused_*`` — the fused BCSR-dtANS shared-column contraction
  (`shared_cols`: one gather per block row) vs the generic per-lane
  gather path on the same packed artifact — a genuine measured kernel
  win at every B.
* Every row carries ``bit_identical`` — the blocked/fused result
  compared ``==`` against the plain kernel before timing; a 0 here
  fails the tile-smoke CI leg.

Not a TPU performance claim: interpret-mode wall time on CPU, the same
caveat as benchmarks/bench_spmv.py's measured columns.
"""

from __future__ import annotations

import numpy as np

from repro.autotune.measure import time_kernel
from repro.core.bcsr_dtans import encode_bcsr_matrix
from repro.core.csr_dtans import encode_matrix
from repro.kernels import ops
from repro.kernels.pack import pack_matrix
from repro.kernels.tiling import choose_bn
from repro.sparse.formats import CSR


def _weight(m: int, n: int, sparsity: float, seed: int) -> CSR:
    from benchmarks.suite import nn_weight
    return nn_weight(m, n, sparsity=sparsity, seed=seed)


def _time(fn, small: bool):
    return float(time_kernel(fn, warmup=1, repeats=3 if small else 5))


def run(small: bool = False):
    rows = []
    batches = (8, 128, 1024) if small else (8, 128, 1024, 4096)
    m, n = (96, 80) if small else (512, 384)
    a = _weight(m, n, sparsity=0.85, seed=7)
    rng = np.random.default_rng(0xB0)

    # ---- grid-blocked dtANS SpMM: best tile config vs untiled ----------
    pm = pack_matrix(encode_matrix(a, lane_width=16))
    vb = pm.dtype.itemsize
    for B in batches:
        X = rng.standard_normal((n, B)).astype(np.float32)
        base = np.asarray(ops.spmm(pm, X))
        cands: dict[str, int | None] = {"untiled": None}
        for bn in {max(B // 4, 8), max(B // 8, 8)}:
            if bn < B:
                cands[f"bn{bn}"] = bn
        auto = choose_bn(n, pm.lane_width, B, vb)
        if auto is not None and auto < B:
            cands[f"auto{auto}"] = auto
        bit_ok = all(
            np.array_equal(base, np.asarray(ops.spmm(pm, X, bn=bn)))
            for bn in cands.values() if bn is not None)
        t_untiled = _time(lambda: ops.spmm(pm, X), small)
        best_name, t_best = "untiled", t_untiled
        for cname, bn in cands.items():
            if bn is None:
                continue
            t = _time(lambda bn=bn: ops.spmm(pm, X, bn=bn), small)
            if t < t_best:
                best_name, t_best = cname, t
        rows.append((f"fig9tile/tiled_dtans_B{B}", t_best * 1e6,
                     f"ratio_tiled={t_untiled / t_best:.3f};"
                     f"best={best_name};us_untiled={t_untiled * 1e6:.1f};"
                     f"bit_identical={int(bit_ok)}"))

    # ---- fused BCSR-dtANS block decode vs generic per-lane gather ------
    pb = pack_matrix(encode_bcsr_matrix(a, block_shape=(4, 4)))
    assert pb.shared_cols
    for B in batches:
        X = rng.standard_normal((n, B)).astype(np.float32)
        gen = np.asarray(ops.spmm(pb, X, fused=False))
        fus = np.asarray(ops.spmm(pb, X, fused=True))
        bit_ok = np.array_equal(gen, fus)
        t_gen = _time(lambda: ops.spmm(pb, X, fused=False), small)
        t_fus = _time(lambda: ops.spmm(pb, X, fused=True), small)
        rows.append((f"fig9tile/fused_bcsr_dtans_B{B}", t_fus * 1e6,
                     f"fused_vs_generic={t_gen / t_fus:.3f};"
                     f"us_generic={t_gen * 1e6:.1f};"
                     f"bit_identical={int(bit_ok)}"))

    # ---- pipelined decode vs serial (dtANS) ----------------------------
    B = batches[-1]
    X = rng.standard_normal((n, B)).astype(np.float32)
    bit_ok = np.array_equal(np.asarray(ops.spmm(pm, X)),
                            np.asarray(ops.spmm(pm, X, pipeline=True)))
    t_ser = _time(lambda: ops.spmm(pm, X), small)
    t_pip = _time(lambda: ops.spmm(pm, X, pipeline=True), small)
    rows.append((f"fig9tile/pipeline_dtans_B{B}", t_pip * 1e6,
                 f"pipeline_vs_serial={t_ser / t_pip:.3f};"
                 f"bit_identical={int(bit_ok)}"))
    return rows
