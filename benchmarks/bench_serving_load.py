"""Serving load benchmark: seeded Poisson arrivals into the Engine,
dense head vs entropy-coded compressed head.

ROADMAP item 3's complaint is structural: the Engine is
continuous-batching-lite and *nothing measures throughput under load* —
there is no number a sharding or scheduler PR could claim to have
improved. This benchmark is that number. A seeded Poisson arrival
process (exponential inter-arrival gaps against the wall clock) feeds
requests into two engines built from the same params — one serving the
dense LM head, one the pruned + dtANS-compressed head through the fused
SpMM path — and reports, per head:

  * tokens/sec over the whole run (arrival to drain),
  * p50/p99 step latency (from the engine's own ``engine.step_s``
    reservoir histogram — the same numbers a production scrape reads),
  * mean slot occupancy, TTFT and end-to-end latency percentiles.

It also measures the *instrumentation overhead* the obs layer adds to
`Engine.step` with no trace sink configured, by timing an identical
drain with a real `MetricsRegistry` against one with `obs.NULL`
(every instrument a no-op). The acceptance bar is < 2%; the measured
number is written into the JSON so regressions are visible per PR.

Everything lands in ``BENCH_serving.json`` at the repo root (via
``benchmarks/run.py --only load``) — the first ``BENCH_*.json`` of the
repo, so every future PR has a perf trajectory to compare against.
Absolute numbers are CPU-interpret harness numbers, not TPU claims;
the *dense/compressed ratio* and the trajectory across PRs are the
signal.
"""

from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

DEFAULT_BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json")


def _percentiles(xs, qs=(50, 99)):
    if not len(xs):
        return {f"p{q}": float("nan") for q in qs}
    return {f"p{q}": float(np.percentile(np.asarray(xs), q)) for q in qs}


def _drive_poisson(eng, *, rng, rate_per_s: float,
                   prompt_lens, max_new_tokens: int, vocab: int,
                   max_steps: int):
    """Feed a seeded Poisson schedule into ``eng`` against the wall
    clock and drain it; returns the per-run report dict.

    Arrival times are cumulative exponential gaps drawn once up front
    (seeded — the dense and compressed runs see the *same* schedule).
    ``prompt_lens`` gives request k a prompt of ``prompt_lens[k]``
    tokens — a constant list is the uniform workload, a cycling
    {1, 3, 7, 12} list is the mixed workload that exercises per-slot
    positions and mid-flight refills. The loop submits every request
    whose arrival time has passed, steps the engine while it has work,
    and sleeps to the next arrival when idle (virtual idle time still
    counts toward wall time, exactly like a real server waiting on
    traffic).
    """
    n_requests = len(prompt_lens)
    schedule = np.cumsum(rng.exponential(1.0 / rate_per_s,
                                         size=n_requests))
    prompts = [rng.integers(0, vocab, size=int(n))
               for n in prompt_lens]
    reqs = []
    step_times = []
    steps = 0
    t0 = time.perf_counter()
    i = 0
    while i < n_requests or eng.queue or any(r is not None
                                             for r in eng.active):
        now = time.perf_counter() - t0
        while i < n_requests and schedule[i] <= now:
            reqs.append(eng.submit(prompts[i], max_new_tokens))
            i += 1
        if not (eng.queue or any(r is not None for r in eng.active)):
            # Idle pool, future arrivals: wait for the next one instead
            # of spinning empty steps.
            time.sleep(max(min(schedule[i] - now, 0.05), 0.0))
            continue
        s0 = time.perf_counter()
        eng.step()
        step_times.append(time.perf_counter() - s0)
        steps += 1
        if steps >= max_steps:
            raise RuntimeError(
                f"load run exceeded max_steps={max_steps} before "
                f"draining — results would be truncated")
    wall = time.perf_counter() - t0

    snap = eng.metrics.snapshot()
    h = snap["histograms"]
    toks = sum(len(r.out) for r in reqs)
    done = sum(r.done for r in reqs)
    ttfts = [r.t_first - r.t_submit for r in reqs
             if r.t_first is not None and r.t_submit is not None]
    e2es = [r.t_done - r.t_submit for r in reqs
            if r.t_done is not None and r.t_submit is not None]
    step_h = h.get("engine.step_s", {})
    return {
        "requests": int(done),
        "requests_submitted": int(len(reqs)),
        "truncations": int(snap["counters"].get(
            "engine.drain_truncations", 0)),
        "prompt_lens": [int(n) for n in prompt_lens],
        "tokens": int(toks),
        "wall_s": float(wall),
        "tokens_per_sec": float(toks / wall) if wall > 0 else 0.0,
        "steps": int(steps),
        # Step latency from the engine's own metrics registry (what a
        # production scrape would read) — bench-side timings agree but
        # include numpy bookkeeping.
        "p50_step_s": step_h.get("p50", float("nan")),
        "p99_step_s": step_h.get("p99", float("nan")),
        "mean_step_s": step_h.get("mean", float("nan")),
        "occupancy_mean": h.get("engine.occupancy", {}).get(
            "mean", float("nan")),
        "queue_depth_last": snap["gauges"].get("engine.queue_depth", 0.0),
        "ttft_s": _percentiles(ttfts),
        "e2e_s": _percentiles(e2es),
        "prefill_s": {"mean": h.get("engine.prefill_s", {}).get(
            "mean", float("nan"))},
        "decode_s": {"mean": h.get("engine.decode_s", {}).get(
            "mean", float("nan"))},
    }


def _instr_cost_per_step(metrics, iters: int = 20_000) -> float:
    """Seconds of pure instrumentation work per `Engine.step` against
    ``metrics``: exactly the instrument sequence `step` executes — 3
    disabled-span entries, 7 histogram observes, 3 counter adds, 2
    gauge sets (no trace sink)."""
    from repro import obs

    hs = [metrics.histogram(f"oh.h{i}") for i in range(7)]
    cs = [metrics.counter(f"oh.c{i}") for i in range(3)]
    gs = [metrics.gauge(f"oh.g{i}") for i in range(2)]
    t0 = time.perf_counter()
    for _ in range(iters):
        with obs.span("oh.step"):
            with obs.span("oh.refill"):
                pass
            with obs.span("oh.decode"):
                pass
        for h in hs:
            h.observe(0.001)
        for c in cs:
            c.add(1)
        for g in gs:
            g.set(1.0)
    return (time.perf_counter() - t0) / iters


def _measure_overhead(make_engine, *, rng, n_requests: int,
                      prompt_len: int, max_new_tokens: int, vocab: int):
    """Instrumentation overhead of `Engine.step` with no trace sink.

    Two views: (1) *direct* — microbenchmark the exact per-step
    instrument sequence with real instruments vs `obs.NULL` no-ops and
    divide the delta by the median step time (the headline number: the
    added work is ~µs on a ~ms step, far below the run-to-run variance
    of whole drains, so an end-to-end A/B alone would just report that
    variance with either sign); (2) *end-to-end* — alternating measured
    drains of otherwise identical engines, as a cross-check that
    nothing outside the instrument sequence regressed.

    Returns ``(on_s, off_s, overhead_fraction, direct_cost_s)`` where
    ``overhead_fraction = (direct real − direct null) / off_s``."""
    from repro import obs

    prompts = [rng.integers(0, vocab, size=prompt_len)
               for _ in range(n_requests)]

    def drained(eng):
        for p in prompts:
            eng.submit(p, max_new_tokens)
        times = []
        while eng.queue or any(r is not None for r in eng.active):
            s0 = time.perf_counter()
            eng.step()
            times.append(time.perf_counter() - s0)
        eng.finished.clear()
        return times

    # Each Engine owns fresh `jax.jit` closures, so the warmup drain
    # must happen per engine — a warm sibling engine absorbs nothing.
    eng_off = make_engine(metrics=obs.NULL)
    eng_on = make_engine(metrics=obs.MetricsRegistry())
    drained(eng_off)
    drained(eng_on)
    # Alternate rounds so machine drift hits both arms equally; the
    # min of per-round medians is the robust estimator against
    # interference (noise only ever adds time).
    off_meds, on_meds = [], []
    for _ in range(5):
        off_meds.append(float(np.median(drained(eng_off))))
        on_meds.append(float(np.median(drained(eng_on))))
    off_s, on_s = min(off_meds), min(on_meds)

    cost = (_instr_cost_per_step(obs.MetricsRegistry())
            - _instr_cost_per_step(obs.NULL))
    cost = max(cost, 0.0)
    frac = cost / off_s if off_s > 0 else 0.0
    return on_s, off_s, frac, cost


def run(small: bool = False, seed: int = 0,
        bench_json: str | None = DEFAULT_BENCH_JSON):
    """Benchmark rows (for ``benchmarks.run`` CSV) + BENCH_serving.json.

    ``bench_json=None`` skips the file write (unit tests).
    """
    import jax

    from repro import obs
    from repro.configs import get_smoke
    from repro.models import api
    from repro.serving.engine import Engine

    if small:
        vocab, slots, n_requests = 48, 3, 6
        prompt_len, max_new, rate = 3, 4, 8.0
    else:
        vocab, slots, n_requests = 128, 4, 16
        prompt_len, max_new, rate = 6, 8, 4.0
    cfg = get_smoke("smollm-135m").with_(vocab=vocab)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    sparse_head = Engine.compress_lm_head(cfg, params, sparsity=0.8,
                                          value_bits=6, lane_width=32)

    def make_engine(head=None, metrics=None):
        return Engine(cfg, params, slots=slots, max_seq=64,
                      sparse_head=head,
                      metrics=metrics if metrics is not None
                      else obs.MetricsRegistry())

    # Mixed workload: prompt lengths cycle {1, 3, 7, 12} across the
    # arrival schedule, so slots decode at genuinely different
    # positions and every mid-flight refill prefills next to live
    # requests — the workload the per-slot scheduler exists for (the
    # uniform workload cannot distinguish per-slot positions from the
    # old shared-position decode).
    mixed_lens = tuple(
        (1, 3, 7, 12) * ((n_requests + 3) // 4))[:n_requests]

    def warmed_engine(head, lens):
        """Fresh engine with every distinct prompt length jit-traced
        (prefill retraces per length; the measured run should time
        steady-state steps, not tracing)."""
        eng = make_engine(head=head)
        wrng = np.random.default_rng(seed + 7)
        for ln in sorted(set(lens)):
            eng.submit(wrng.integers(0, vocab, size=int(ln)), 2)
        eng.run_until_drained()
        return eng

    results = {}
    for label, head, lens in (
            ("dense", None, (prompt_len,) * n_requests),
            ("compressed", sparse_head, (prompt_len,) * n_requests),
            ("dense_mixed", None, mixed_lens),
            ("compressed_mixed", sparse_head, mixed_lens)):
        # Same seed => same arrival schedule and prompts for both heads.
        eng = warmed_engine(head, lens)
        rng = np.random.default_rng(seed)
        results[label] = _drive_poisson(
            eng, rng=rng, rate_per_s=rate, prompt_lens=lens,
            max_new_tokens=max_new, vocab=vocab, max_steps=10_000)

    on_s, off_s, frac, cost = _measure_overhead(
        lambda metrics: make_engine(head=sparse_head, metrics=metrics),
        rng=np.random.default_rng(seed + 1), n_requests=max(slots, 2),
        prompt_len=prompt_len, max_new_tokens=max_new, vocab=vocab)
    results["obs_overhead"] = {
        "instr_cost_per_step_s": cost,
        "overhead_fraction": frac,
        "step_s_instrumented_e2e": on_s,
        "step_s_null_registry_e2e": off_s,
        "e2e_delta_fraction": (on_s - off_s) / off_s if off_s else 0.0,
        "trace_sink": False,
        "budget_fraction": 0.02,
    }

    doc = {
        "bench": "serving_load",
        "meta": {
            "seed": seed, "small": bool(small), "arch": "smollm-135m",
            "vocab": vocab, "slots": slots, "n_requests": n_requests,
            "prompt_len": prompt_len, "max_new_tokens": max_new,
            "mixed_prompt_lens": [int(n) for n in mixed_lens],
            "arrival_rate_per_s": rate,
            "sparsity": 0.8,
            "head_compression_vs_dense":
                float(sparse_head.compression_vs_dense),
            "interpret_mode": True,
            "platform": platform.platform(),
        },
        **results,
    }
    if bench_json:
        with open(bench_json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)

    rows = []
    for label in ("dense", "compressed", "dense_mixed",
                  "compressed_mixed"):
        r = results[label]
        rows.append((
            f"load/{label}", r["mean_step_s"] * 1e6,
            f"tok_s={r['tokens_per_sec']:.2f};"
            f"p50_step_ms={r['p50_step_s'] * 1e3:.2f};"
            f"p99_step_ms={r['p99_step_s'] * 1e3:.2f};"
            f"occ={r['occupancy_mean']:.2f};"
            f"reqs={r['requests']}/{r['requests_submitted']}"))
    rel = (results["compressed"]["tokens_per_sec"]
           / max(results["dense"]["tokens_per_sec"], 1e-12))
    rows.append(("load/compressed_vs_dense", 0.0,
                 f"tok_s_ratio={rel:.3f}"))
    rows.append(("load/obs_overhead", on_s * 1e6,
                 f"overhead={frac * 100:.2f}%;budget=2%"))
    if bench_json:
        rows.append(("load/bench_json", 0.0, bench_json))
    return rows


if __name__ == "__main__":
    for row in run(small=True):
        print(",".join(str(x) for x in row))
