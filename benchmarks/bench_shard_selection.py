"""Sharded-selection benchmark: selector-vs-oracle regret per shard
count, plus the mesh sweep that lets the argmin decide how many chips
each matrix wants.

The sharding layer reprices every candidate for k-device execution:
the critical-path device holds ~1/k of the matrix bytes and does 1/k
of the decode and contraction work, then pays the x-broadcast/y-reduce
collective (`repro.autotune.cost_model.collective_time`).  This
section sweeps ``select(n_shards=k)`` against the exhaustive
exact-size oracle priced at the same k and reports

  * per (matrix, k): the selector's pick, the oracle's pick, and the
    modeled regret (both sides share `candidate_time(n_shards=k)`, so
    regret 0 means genuine agreement at that shard count — the CI
    shard-smoke leg asserts exactly this at k in {1, 4});
  * per matrix: the ``select(mesh=)`` sweep outcome — the winning
    config AND chip count against the oracle's argmin over all counts,
    priced streaming (``warm=False``: matrix bytes dominate there, so
    big matrices genuinely want chips while small ones stay
    latency-bound on one);
  * summary rows: shard counts recorded, mean/max regret per k, and
    how many suite matrices the mesh sweep actually sharded.
"""

from __future__ import annotations

import numpy as np

from benchmarks.suite import cached_suite
# Shared with the fig9/batch sections: `--only shard,...` runs in one
# process and constructed candidate sizes are shard-independent (every
# shard count prices the same encoded artifacts), so a private memo
# would re-encode the most expensive part of the smoke run.
from benchmarks.bench_format_selection import _ENC
from repro.autotune import DecisionCache, clear_memo, oracle_times, select
from repro.sparse.formats import CSR

#: Shard counts priced head-to-head: single-chip and the 4-chip slice
#: of a v5e pod — the pair the CI shard-smoke leg pins at zero regret.
SHARD_COUNTS = (1, 4)

#: Counts the mesh sweep may land on (powers of two up to the model
#: axis the smoke leg hosts).
SWEEP_COUNTS = (1, 2, 4)


def _sweep_mesh():
    """A 4-device ``model``-axis mesh when the host exposes one (the CI
    leg forces 8 host devices); None means the sweep below falls back
    to pinned per-count selection — same cost model, same argmin."""
    import jax
    if len(jax.devices()) < SWEEP_COUNTS[-1]:
        return None
    from repro.launch.mesh import make_debug_mesh
    return make_debug_mesh((SWEEP_COUNTS[-1],), ("model",))


def _spelled(dec) -> str:
    return (dec.config_name if dec.n_shards == 1
            else f"{dec.config_name}@S{dec.n_shards}")


def run(small: bool = False, shard_counts: tuple = SHARD_COUNTS):
    rows = []
    regrets = {k: [] for k in shard_counts}
    sharded_picks = 0
    total = 0
    mesh = _sweep_mesh()
    cache = DecisionCache(path=None)   # memory-only: honest measurement
    clear_memo()

    for name, a64 in cached_suite(small=small).items():
        a = CSR(a64.indptr, a64.indices,
                a64.values.astype(np.float32), a64.shape)
        enc = _ENC.setdefault(name, {})

        # -- pinned shard counts: regret vs the oracle at the same k --
        for k in shard_counts:
            dec = select(a, warm=True, n_shards=k, cache=cache)
            times = oracle_times(a, warm=True, n_shards=k,
                                 encode_cache=enc)
            o_name = min(times, key=times.get)
            key = _spelled(dec)
            regret = times[key] / times[o_name] - 1.0
            regrets[k].append(regret)
            rows.append((f"fig9shard/{name}@S{k}", 0.0,
                         f"pick={key};oracle={o_name};"
                         f"regret={regret:.4f}"))

        # -- mesh sweep: let the argmin pick the chip count ------------
        if mesh is not None:
            dec = select(a, warm=False, mesh=mesh, cache=cache)
        else:
            picks = [select(a, warm=False, n_shards=k, cache=cache)
                     for k in SWEEP_COUNTS]
            dec = min(picks, key=lambda d: d.modeled_time)
        times = oracle_times(a, warm=False, n_shards=SWEEP_COUNTS,
                             encode_cache=enc)
        o_name = min(times, key=times.get)
        regret = times[_spelled(dec)] / times[o_name] - 1.0
        sharded_picks += dec.n_shards > 1
        total += 1
        rows.append((f"fig9shard/{name}/sweep", 0.0,
                     f"pick={_spelled(dec)};n_shards={dec.n_shards};"
                     f"oracle={o_name};regret={regret:.4f}"))

    rows.append(("fig9shard/shard_counts", 0.0,
                 f"count={len(shard_counts)};"
                 "sizes=" + ",".join(str(k) for k in shard_counts)))
    rows.append(("fig9shard/mesh_sweep", 0.0,
                 ("mode=shard_map" if mesh is not None else
                  "mode=pinned_fallback")
                 + f";sharded_picks={sharded_picks}/{total}"))
    for k in shard_counts:
        rows.append((f"fig9shard/mean_regret@S{k}", 0.0,
                     f"{float(np.mean(regrets[k])):.4f}"))
        rows.append((f"fig9shard/max_regret@S{k}", 0.0,
                     f"{float(np.max(regrets[k])):.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
