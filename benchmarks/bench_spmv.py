"""Paper Tables II/III + Figs. 7/8: SpMVM runtime of CSR-dtANS vs the best
uncompressed format, warm and cold cache.

Two numbers per matrix:
  * modeled speedup — the v5e roofline model of benchmarks/suite.py
    (bytes/HBM + cache + decode-ops term). This is the TPU-target claim.
  * measured interpret-mode wall time of the fused Pallas kernel vs the
    SELL baseline kernel on small matrices — a correctness-bearing
    harness check, NOT a TPU performance claim (CPU interpret mode).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.suite import (cached_encode, cached_suite, model_time,
                              spmv_bytes)
from repro.core.csr_dtans import encode_matrix
from repro.kernels import ops
from repro.kernels.pack import pack_matrix
from repro.kernels.sell_spmv import pack_sell
from repro.sparse.formats import CSR, best_baseline_nbytes


def _sample_banded():
    from repro.sparse.random_graphs import banded
    return banded(120000, 8)


def _sample_er():
    from repro.sparse.random_graphs import erdos_renyi
    return erdos_renyi(50000, 20, np.random.default_rng(3))


def _sample_nn():
    from benchmarks.suite import nn_weight
    return nn_weight(2600, 2600, sparsity=0.85, seed=2)


def run(small: bool = False, warm: bool = True, measure: bool = True):
    tag = "warm" if warm else "cold"
    table = "table2" if warm else "table3"
    rows = []
    cells: dict[tuple, list] = {}
    for name, a64 in cached_suite(small=small).items():
        for bits, dtype in ((64, np.float64), (32, np.float32)):
            a = CSR(a64.indptr, a64.indices,
                    a64.values.astype(dtype), a64.shape)
            vb = a.values.dtype.itemsize
            mat = cached_encode(name, a, bits)
            bname, bb = best_baseline_nbytes(a)
            m, n = a.shape
            t_base = model_time(spmv_bytes(bb, n, m, vb), a.nnz,
                                warm=warm, decode=False)
            t_dtans = model_time(spmv_bytes(mat.nbytes, n, m, vb), a.nnz,
                                 warm=warm, decode=True)
            speedup = t_base / t_dtans
            rows.append((f"fig7_{tag}/{name}_{bits}b", 0.0,
                         f"modeled_speedup={speedup:.3f};"
                         f"size_ratio={mat.nbytes/bb:.3f};base={bname}"))
            nnz_bin = ("<=2^20" if a.nnz <= 2 ** 20 else
                       "<=2^25" if a.nnz <= 2 ** 25 else ">2^25")
            annzpr = a.nnz / max(m, 1)
            key = (bits, nnz_bin,
                   "annzpr<=10" if annzpr <= 10 else "annzpr>10")
            cells.setdefault(key, []).append(speedup > 1.0)
    for (bits, nnz_bin, apr), oks in sorted(cells.items()):
        rows.append((f"{table}/{bits}b_{nnz_bin}_{apr}", 0.0,
                     f"{sum(oks)}/{len(oks)}"))

    # ---- paper-scale projection (Table II/III's > 2^25 nnz column) -------
    # Matrices with 2^25+ nonzeros are where the paper sees most speedups
    # (they exceed any cache). Encoding 33M nonzeros with the host encoder
    # is minutes-slow, so: measure bits/nnz on a 1M-nnz sample of the same
    # generator family, project the format size linearly in nnz (exact for
    # these generators: per-row distributions are size-invariant), and
    # model the runtime. Marked "projected".
    proj_specs = [
        ("banded_2^25", lambda: _sample_banded(), 1 << 25),
        ("er_d20_2^25", lambda: _sample_er(), 1 << 25),
        ("nn_s85_2^26", lambda: _sample_nn(), 1 << 26),
    ]
    for pname, sampler, target_nnz in proj_specs:
        for bits, dtype in ((64, np.float64), (32, np.float32)):
            a = sampler()
            a = CSR(a.indptr, a.indices, a.values.astype(dtype), a.shape)
            vb = a.values.dtype.itemsize
            mat = cached_encode("proj_" + pname, a, bits)
            bname, bb = best_baseline_nbytes(a)
            scale = target_nnz / a.nnz
            # variable parts scale with nnz; table overhead stays constant
            table_b = sum(t.nbytes(vb) for t in mat.tables)
            dt_proj = (mat.nbytes - table_b) * scale + table_b
            bb_proj = bb * scale
            m = int(a.shape[0] * scale)
            n = int(a.shape[1] * scale)
            t_base = model_time(spmv_bytes(bb_proj, n, m, vb), target_nnz,
                                warm=warm, decode=False)
            t_dtans = model_time(spmv_bytes(dt_proj, n, m, vb), target_nnz,
                                 warm=warm, decode=True)
            speedup = t_base / t_dtans
            rows.append((f"{table}_projected/{pname}_{bits}b", 0.0,
                         f"modeled_speedup={speedup:.3f};"
                         f"size_ratio={dt_proj/bb_proj:.3f}"))
    if measure and warm:   # one measured pair, harness sanity (CPU!)
        a = cached_suite(small=True)["tiny_er"]
        a = CSR(a.indptr, a.indices, a.values.astype(np.float64), a.shape)
        mat = encode_matrix(a, lane_width=64)
        pm = pack_matrix(mat)
        ps = pack_sell(a, lane_width=64)
        x = np.random.default_rng(0).standard_normal(a.shape[1])
        y1 = ops.spmv(pm, x)
        y1.block_until_ready()
        t0 = time.time()
        for _ in range(3):
            ops.spmv(pm, x).block_until_ready()
        us_dtans = (time.time() - t0) / 3 * 1e6
        y2 = ops.sell_spmv(ps, x)
        y2.block_until_ready()
        t0 = time.time()
        for _ in range(3):
            ops.sell_spmv(ps, x).block_until_ready()
        us_sell = (time.time() - t0) / 3 * 1e6
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-9)
        rows.append(("measured_cpu_interpret/dtans_spmv", us_dtans,
                     "correctness=match"))
        rows.append(("measured_cpu_interpret/sell_spmv", us_sell,
                     "cpu-interpret-only"))
    return rows


if __name__ == "__main__":
    for r in run(warm=True) + run(warm=False, measure=False):
        print(",".join(str(x) for x in r))
