"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  fig4    — delta-encoding entropy reduction (random graph models)
  fig6    — compression vs best of CSR/COO/SELL + Table I success rates
  fig7/8  — modeled SpMVM speedup, warm (Table II) & cold (Table III)
  fig9    — vs oracle format selector (AlphaSparse stand-in), including
            measured-refinement regret (wall-clock timed kernels)
  batch   — batched selection: selector-vs-oracle regret with B right-
            hand sides per pass (B in {1, 8, 32, 128}; the winning
            format flips once per-RHS contraction work overtakes the
            amortized per-pass costs)
  shard   — sharded selection: selector-vs-oracle regret at pinned
            shard counts {1, 4} plus the ``select(mesh=)`` sweep that
            lets the argmin pick the chip count per matrix
  calib   — MachineModel calibration: fit cost-model constants to
            measured kernel times; ``--profile-json`` persists the
            fitted machine profile (CI uploads it as an artifact)
  load    — serving load test: seeded Poisson arrivals into the
            Engine, dense vs compressed LM head (tokens/sec, p50/p99
            step latency, occupancy, obs-layer overhead); writes
            ``--bench-serving-json`` (default: BENCH_serving.json at
            the repo root — the tracked perf trajectory)
  tiles   — kernel tile/pipeline microbench (fig9tile rows): grid-
            blocked SpMM best-tile-config vs untiled over a batch
            sweep, fused BCSR-dtANS block-decode vs the generic
            gather path, pipelined decode vs serial — every row
            carries a bit_identical flag the tile-smoke CI leg gates on
  roofline— summary of the dry-run roofline table when present

``--only`` accepts a comma-separated list (``--only fig9,batch``) so
one smoke JSON can carry several sections.
"""

from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="trimmed sizes (CI)")
    ap.add_argument("--only", default=None,
                    help="run only these sections (comma-separated, "
                         "e.g. 'fig9,batch')")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON list of "
                         "{name, us_per_call, derived} objects (CI "
                         "artifact)")
    ap.add_argument("--no-measure", action="store_true",
                    help="skip wall-clock kernel timing in fig9 "
                         "(modeled-only rows)")
    ap.add_argument("--profile-json", default=None, metavar="PATH",
                    help="persist the calib section's fitted machine "
                         "profile to this JSON file (CI artifact)")
    ap.add_argument("--mtx-dir", default=None, metavar="PATH",
                    help="directory of MatrixMarket files (.mtx / "
                         ".mtx.gz, e.g. SuiteSparse downloads) fed "
                         "through repro.sparse.io into the fig9 "
                         "selection suite")
    ap.add_argument("--bench-serving-json", default=None, metavar="PATH",
                    help="where the load section writes its "
                         "BENCH_serving.json (default: repo root)")
    ap.add_argument("--max-nnz", default=2_000_000, type=int,
                    help="skip --mtx-dir files with more stored "
                         "nonzeros than this (default 2e6; the "
                         "exhaustive oracle encodes every candidate)")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_batch_selection, bench_calibration,
                            bench_compression, bench_delta_entropy,
                            bench_format_selection, bench_kernel_tiles,
                            bench_serving_load, bench_shard_selection,
                            bench_spmv)

    print("name,us_per_call,derived")
    sections = {
        "fig4": lambda: bench_delta_entropy.run(small=args.small),
        "fig6": lambda: bench_compression.run(small=args.small),
        "fig7": lambda: bench_spmv.run(small=args.small, warm=True),
        "fig8": lambda: bench_spmv.run(small=args.small, warm=False,
                                       measure=False),
        "fig9": lambda: bench_format_selection.run(
            small=args.small, measure=not args.no_measure,
            mtx_dir=args.mtx_dir, max_nnz=args.max_nnz),
        "batch": lambda: bench_batch_selection.run(small=args.small),
        "shard": lambda: bench_shard_selection.run(small=args.small),
        "calib": lambda: bench_calibration.run(
            small=args.small, profile_json=args.profile_json),
        "tiles": lambda: bench_kernel_tiles.run(small=args.small),
        "load": lambda: bench_serving_load.run(
            small=args.small,
            bench_json=args.bench_serving_json
            or bench_serving_load.DEFAULT_BENCH_JSON),
    }
    only = set(args.only.split(",")) if args.only else None
    collected = []
    for name, fn in sections.items():
        if only is not None and name not in only:
            continue
        for row in fn():
            collected.append(row)
            print(",".join(str(x) for x in row), flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": r[0], "us_per_call": r[1],
                        "derived": r[2]} for r in collected], f, indent=1)

    # roofline summary from dry-run artifacts, if present
    ddir = os.path.join(os.path.dirname(__file__), "..",
                        "experiments", "dryrun")
    if os.path.isdir(ddir) and not args.only:
        for f in sorted(os.listdir(ddir)):
            if not f.endswith(".json"):
                continue
            rec = json.load(open(os.path.join(ddir, f)))
            if rec.get("status") != "ok":
                continue
            r = rec["roofline"]
            print(f"roofline/{rec['arch']}_{rec['shape']}_{rec['mesh']},"
                  f"0.0,dom={r['dominant']};compute_s={r['compute_s']:.3e};"
                  f"memory_s={r['memory_s']:.3e};"
                  f"collective_s={r['collective_s']:.3e}", flush=True)


if __name__ == "__main__":
    main()
