"""Benchmark matrix suite (the performance model now lives in
`repro.autotune.cost_model`; this module is a thin consumer).

Matrices are synthetic stand-ins for the SuiteSparse families the paper
evaluates (stencils / banded systems / random-graph adjacency / pruned NN
weights / incompressible-value matrices). Each generator is deterministic.

`model_time` / `spmv_bytes` and the machine constants are re-exported
for the benchmark sections; see `repro.autotune.cost_model.MachineModel`
for the model itself (two-level memory time + decode-compute term).
"""

from __future__ import annotations

import numpy as np

from repro.autotune.cost_model import V5E, model_time, spmv_bytes  # noqa: F401 (re-exported)
from repro.sparse.formats import CSR
from repro.sparse.prune import codebook_quantize, magnitude_prune
from repro.sparse.random_graphs import (banded, barabasi_albert,
                                        block_sparse, erdos_renyi,
                                        stencil_2d, watts_strogatz)

# Backwards-compatible constant names (now sourced from the V5E model).
HBM_BW = V5E.hbm_bw
CACHE_BW = V5E.cache_bw
CACHE_BYTES = V5E.cache_bytes
VPU_RATE = V5E.vpu_rate
DECODE_OPS_PER_NNZ = V5E.decode_ops_per_nnz


def nn_weight(rows=2048, cols=2048, sparsity=0.85, seed=0,
              dtype=np.float32) -> CSR:
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((rows, cols)) / np.sqrt(cols)).astype(dtype)
    a = magnitude_prune(w, sparsity)
    return codebook_quantize(a, bits=8)


def random_values(n=3000, avg_deg=12, seed=0) -> CSR:
    """Adversarial: ER pattern with fully random (incompressible) values."""
    rng = np.random.default_rng(seed)
    a = erdos_renyi(n, avg_deg, rng)
    return CSR(a.indptr, a.indices,
               rng.standard_normal(a.nnz), a.shape)


def suite(small: bool = False) -> dict:
    """name -> CSR matrix. `small` trims sizes for CI."""
    f = 0.4 if small else 1.0
    rng = np.random.default_rng(7)
    out = {
        "stencil_120": stencil_2d(int(120 * f)),
        "stencil_300": stencil_2d(int(300 * f)),
        "banded_20k": banded(int(20000 * f), 8),
        "er_n4k_d10": erdos_renyi(int(4000 * f), 10, rng),
        "er_n30k_d20": erdos_renyi(int(30000 * f), 20, rng),
        "ws_n20k_k10": watts_strogatz(int(20000 * f), 5, 0.1, rng),
        "ba_n20k_m10": barabasi_albert(int(20000 * f), 10, rng),
        "nn_2048_s85": nn_weight(int(2048 * f), int(2048 * f)),
        "nn_4096_s90": nn_weight(int(4096 * f), int(4096 * f),
                                 sparsity=0.9, seed=1),
        "random_vals": random_values(int(3000 * f)),
        "tiny_er": erdos_renyi(300, 6, rng),
        # Block-structured sparsity (FEM / multi-DOF / structured
        # pruning): the case the blocked formats exist for.
        "blocked_4x4": block_sparse(int(500 * f), int(500 * f), (4, 4),
                                    0.03, np.random.default_rng(21)),
    }
    return out


_ENC_CACHE: dict = {}
_SUITE_CACHE: dict = {}


def cached_suite(small: bool = False) -> dict:
    key = bool(small)
    if key not in _SUITE_CACHE:
        _SUITE_CACHE[key] = suite(small=small)
    return _SUITE_CACHE[key]


def cached_encode(name: str, a, bits: int):
    """Matrix encodes are deterministic; benchmark sections share them."""
    from repro.core.csr_dtans import encode_matrix
    key = (name, bits, a.nnz)
    if key not in _ENC_CACHE:
        _ENC_CACHE[key] = encode_matrix(a)
    return _ENC_CACHE[key]


