"""Conjugate-gradient solve with an entropy-coded system matrix — the
paper's headline scientific-computing use case (iterative solvers re-read
the same matrix every iteration; compression cuts the bytes per iteration).

    PYTHONPATH=src python examples/cg_solver.py
"""

import numpy as np

from repro.core.csr_dtans import encode_matrix
from repro.kernels import ops
from repro.kernels.pack import pack_matrix
from repro.sparse.formats import best_baseline_nbytes
from repro.sparse.random_graphs import stencil_2d


def cg(spmv, b, n, tol=1e-8, maxiter=300):
    x = np.zeros(n)
    r = b - spmv(x)
    p = r.copy()
    rs = r @ r
    for it in range(maxiter):
        ap = spmv(p)
        alpha = rs / (p @ ap)
        x += alpha * p
        r -= alpha * ap
        rs_new = r @ r
        if np.sqrt(rs_new) < tol:
            return x, it + 1
        p = r + (rs_new / rs) * p
        rs = rs_new
    return x, maxiter


def main():
    a = stencil_2d(48)          # SPD Laplacian, 2304 unknowns
    n = a.shape[0]
    mat = encode_matrix(a, lane_width=128)
    pm = pack_matrix(mat)
    _, bb = best_baseline_nbytes(a)
    print(f"system: {n} unknowns, nnz={a.nnz}; matrix bytes/iteration "
          f"{mat.nbytes:,} (dtANS) vs {bb:,} (best uncompressed)")

    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(n)
    b = a.to_dense() @ x_true

    x, iters = cg(lambda v: np.asarray(ops.spmv(pm, v)), b, n)
    err = np.linalg.norm(x - x_true) / np.linalg.norm(x_true)
    print(f"CG converged in {iters} iterations, rel. error {err:.2e}")
    assert err < 1e-6
    print("solution matches: OK")


if __name__ == "__main__":
    main()
