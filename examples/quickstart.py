"""Quickstart: compress a sparse matrix with CSR-dtANS and run SpMVM with
on-the-fly entropy decoding (paper Fig. 1 end to end).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.autotune import DecisionCache, select
from repro.core.csr_dtans import decode_matrix, encode_matrix
from repro.kernels import ops
from repro.serving.sparse_linear import SparseLinear
from repro.sparse.formats import CSR, best_baseline_nbytes
from repro.sparse.random_graphs import (erdos_renyi, stencil_2d,
                                        watts_strogatz)


def main():
    # 1. a classic scientific-computing matrix: 2-D Laplacian stencil
    a = stencil_2d(120)                      # 14400 x 14400, ~72k nnz
    print(f"matrix: {a.shape}, nnz={a.nnz}, dtype={a.values.dtype}")

    # 2. compress: CSR -> delta-encode -> dtANS entropy-code -> interleave
    mat = encode_matrix(a, lane_width=128)
    bname, bb = best_baseline_nbytes(a)
    print(f"CSR-dtANS: {mat.nbytes:,} B; best cuSPARSE-style format "
          f"({bname}): {bb:,} B -> compression {bb/mat.nbytes:.2f}x")
    print(f"escapes (delta, value): {tuple(mat.esc_count_by_domain)}")

    # 3. lossless check
    back = decode_matrix(mat)
    assert np.array_equal(back.indices, a.indices)
    assert np.array_equal(back.values, a.values)
    print("lossless roundtrip: OK")

    # 4. SpMVM with fused decode (Pallas kernel, interpret mode on CPU)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(a.shape[1])
    y = np.asarray(ops.spmv(mat, x))
    y_ref = np.zeros(a.shape[0])
    for i in range(a.shape[0]):
        lo, hi = a.indptr[i], a.indptr[i + 1]
        y_ref[i] = (a.values[lo:hi] * x[a.indices[lo:hi]]).sum()
    np.testing.assert_allclose(y, y_ref, rtol=1e-10)
    print(f"fused decode+SpMVM: OK  (y[:4] = {y[:4].round(4)})")

    # 5. automatic format selection (repro.autotune; paper Fig. 9 without
    #    the AlphaSparse tuning bill): fingerprint each matrix, pick the
    #    modeled-fastest of {CSR, COO, SELL, CSR-dtANS x configs}.
    cache = DecisionCache(path=None)
    graphs = {
        "erdos_renyi": erdos_renyi(2000, 10, rng),
        "watts_strogatz": watts_strogatz(2000, 5, 0.1, rng),
    }
    for name, g in graphs.items():
        g32 = CSR(g.indptr, g.indices, g.values.astype(np.float32),
                  g.shape)
        for warm in (True, False):
            d = select(g32, warm=warm, cache=cache)
            regime = "warm" if warm else "cold"
            print(f"autotune[{name:14s}|{regime}]: {d.config_name:22s}"
                  f" {d.nbytes:,} B, modeled {d.modeled_time*1e6:.2f} us")

    # 6. serving integration: a SparseLinear layer with auto=True lets the
    #    tuner choose the CSR-dtANS lane width / table sharing per weight.
    w = (rng.standard_normal((256, 512)) / 16).astype(np.float32)
    sl = SparseLinear.from_dense(w, sparsity=0.85, auto=True,
                                 autotune_cache=cache)
    d = sl.decision
    print(f"SparseLinear(auto=True): {d.config_name}, "
          f"{sl.compressed_bytes:,} B "
          f"({sl.compression_vs_dense:.2f}x vs dense)")


if __name__ == "__main__":
    main()
