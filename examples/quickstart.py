"""Quickstart: compress a sparse matrix with CSR-dtANS and run SpMVM with
on-the-fly entropy decoding (paper Fig. 1 end to end).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.csr_dtans import decode_matrix, encode_matrix
from repro.kernels import ops
from repro.sparse.formats import best_baseline_nbytes
from repro.sparse.random_graphs import stencil_2d


def main():
    # 1. a classic scientific-computing matrix: 2-D Laplacian stencil
    a = stencil_2d(120)                      # 14400 x 14400, ~72k nnz
    print(f"matrix: {a.shape}, nnz={a.nnz}, dtype={a.values.dtype}")

    # 2. compress: CSR -> delta-encode -> dtANS entropy-code -> interleave
    mat = encode_matrix(a, lane_width=128)
    bname, bb = best_baseline_nbytes(a)
    print(f"CSR-dtANS: {mat.nbytes:,} B; best cuSPARSE-style format "
          f"({bname}): {bb:,} B -> compression {bb/mat.nbytes:.2f}x")
    print(f"escapes (delta, value): {tuple(mat.esc_count_by_domain)}")

    # 3. lossless check
    back = decode_matrix(mat)
    assert np.array_equal(back.indices, a.indices)
    assert np.array_equal(back.values, a.values)
    print("lossless roundtrip: OK")

    # 4. SpMVM with fused decode (Pallas kernel, interpret mode on CPU)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(a.shape[1])
    y = np.asarray(ops.spmv(mat, x))
    y_ref = np.zeros(a.shape[0])
    for i in range(a.shape[0]):
        lo, hi = a.indptr[i], a.indptr[i + 1]
        y_ref[i] = (a.values[lo:hi] * x[a.indices[lo:hi]]).sum()
    np.testing.assert_allclose(y, y_ref, rtol=1e-10)
    print(f"fused decode+SpMVM: OK  (y[:4] = {y[:4].round(4)})")


if __name__ == "__main__":
    main()
