"""Serve a pruned LM with an entropy-coded (CSR-dtANS) projection matrix —
the paper's pruned-LLM-inference motivation (Section I) end to end:

  1. train-free setup: init a SmolLM-family model;
  2. magnitude-prune + 8-bit-codebook the LM head (vocab x d — the largest
     matrix of a small LM, matvec-bound at decode);
  3. serve a batch of requests with the engine; verify the sparse-head
     logits track the dense ones and report the compression.

    PYTHONPATH=src python examples/sparse_inference.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import api
from repro.serving.engine import Engine
from repro.serving.sparse_linear import SparseLinear


def main():
    cfg = get_smoke("smollm-135m").with_(vocab=512, d_model=128,
                                         n_heads=8, n_kv_heads=4)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(cfg, rng)

    # --- compress the LM head -------------------------------------------
    emb = params["embed"]
    w = np.asarray(emb["head"] if "head" in emb else emb["tok"].T,
                   dtype=np.float32)                     # (d, vocab)
    sl = SparseLinear.from_dense(w, sparsity=0.7, value_bits=6)
    print(f"LM head: dense {sl.dense_bytes:,} B -> CSR-dtANS "
          f"{sl.compressed_bytes:,} B "
          f"({sl.compression_vs_dense:.2f}x vs dense, "
          f"{sl.compression_vs_best_sparse:.2f}x vs best sparse format)")

    # --- logits parity: sparse head vs its own dense reconstruction ------
    h = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model),
                          dtype=jnp.float32)
    ls = np.asarray(sl.apply(h))
    ld = np.asarray(sl.apply_dense_reference(h))
    np.testing.assert_allclose(ls, ld, rtol=1e-4, atol=1e-4)
    agree = (ls.argmax(-1) == ld.argmax(-1)).mean()
    print(f"sparse-head decode == dense(pruned) reference: OK "
          f"(argmax agreement {agree:.0%})")

    # --- batched serving ---------------------------------------------------
    eng = Engine(cfg, params, slots=4, max_seq=48)
    rng_np = np.random.default_rng(0)
    reqs = [eng.submit(rng_np.integers(0, cfg.vocab, size=5), 8)
            for _ in range(6)]
    eng.run_until_drained()
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens generated")
    assert done == len(reqs)
    print("batched serving: OK")


if __name__ == "__main__":
    main()
