"""End-to-end training driver: train a ~100M-param SmolLM-family model for
a few hundred steps on the synthetic pipeline, with checkpointing and an
injected mid-run failure + automatic restore (fault-tolerance demo).
After training, the tied LM head is swapped for a pruned, entropy-coded
`SparseLinear` and the eval loss recomputed with every hidden state of a
training-shaped batch (B = batch * seq rows) contracted through the
grid-blocked SpMM kernel in ONE decode pass — the paper's serving story
exercised at training batch shapes.

Full run (~100M params, few hundred steps — minutes on real hardware,
hours on this 1-core CPU container):
    PYTHONPATH=src python examples/train_lm.py --steps 300

CI-sized run (default here):
    PYTHONPATH=src python examples/train_lm.py --steps 30 --tiny
"""

import argparse
import shutil

import numpy as np

from repro.configs import get, get_smoke
from repro.data.pipeline import PipelineConfig, SyntheticTokens
from repro.train.trainer import TrainConfig, Trainer


def masked_ce(logits, targets, mask=None):
    """Masked next-token cross entropy over (B, S, V) logits — the
    `repro.models.api.loss_fn` formula, reusable with logits from any
    head (dense or sparse)."""
    import jax
    import jax.numpy as jnp
    logits = jnp.asarray(logits, jnp.float32)
    targets = jnp.asarray(targets)
    if mask is None:
        mask = jnp.ones_like(targets, dtype=jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0),
                   axis=-1)
    return float(((logz - gold) * mask).sum()
                 / jnp.maximum(mask.sum(), 1.0))


def sparse_head_eval(params, cfg, batch, *, sparsity: float = 0.5,
                     value_bits: int = 8, pipeline: bool = False):
    """Eval loss with the tied unembed replaced by a compressed head.

    The (d_model, vocab) unembed (`params["embed"]["tok"].T`) is
    magnitude-pruned, codebook-quantized and CSR-dtANS-encoded into a
    `repro.serving.SparseLinear`; the model's hidden states for the
    whole batch flatten to a training-shaped RHS pool of B * S rows and
    contract through `ops.spmm` — which column-tiles the pool through
    the grid-blocked kernel when it overflows the VMEM budget.

    Returns ``(dense_loss, sparse_loss, head)``; the two losses agree
    to the compression error (exactly at sparsity=0, value_bits high),
    and the sparse logits are bit-identical whether or not the pool is
    column-tiled (the tiling contract, conformance-pinned).
    """
    from repro.models import api
    from repro.serving.sparse_linear import SparseLinear
    hidden, _ = api.forward_hidden(params, cfg, batch)
    ep = params["embed"]                              # tied or untied head
    w = np.asarray(ep["head"] if "head" in ep else
                   np.asarray(ep["tok"]).T)           # (d_model, vocab)
    head = SparseLinear.from_dense(w, sparsity=sparsity,
                                   value_bits=value_bits)
    logits = head.apply(np.asarray(hidden, np.float32),
                        pipeline=pipeline)            # (B, S, vocab)
    dense = masked_ce(api.forward(params, cfg, batch)[0],
                      batch["targets"], batch.get("mask"))
    sparse = masked_ce(logits, batch["targets"], batch.get("mask"))
    return dense, sparse, head


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (fault-tolerance "
                         "demo); run resumes from the last checkpoint")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--head-sparsity", type=float, default=0.5,
                    help="prune fraction of the compressed LM head "
                         "evaluated after training")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_smoke("smollm-135m").with_(vocab=512)
        batch, seq = 8, 64
    else:
        cfg = get("smollm-135m").with_(remat=False)   # ~135M params
        batch, seq = 16, 512

    shutil.rmtree(args.ckpt, ignore_errors=True)
    pipe = SyntheticTokens(PipelineConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=0))
    tcfg = TrainConfig(optimizer="adamw", lr=3e-4, microbatches=2,
                       ckpt_every=10, ckpt_dir=args.ckpt)
    trainer = Trainer(cfg, tcfg, pipe)
    print(f"arch={cfg.name} params~"
          f"{sum(x.size for x in __import__('jax').tree.leaves(trainer.params))/1e6:.1f}M "
          f"batch={batch} seq={seq}")

    try:
        trainer.run(args.steps, log_every=5, fail_at=args.fail_at)
    except RuntimeError as e:
        print(f"!! {e} — restoring from checkpoint and resuming")
        restored = trainer.try_restore()
        print(f"restored={restored} at step {trainer.step}")
        trainer.run(args.steps, log_every=5)

    h = trainer.history
    k = max(3, len(h) // 5)
    print(f"loss: first-{k}-avg {sum(h[:k])/k:.4f} -> "
          f"last-{k}-avg {sum(h[-k:])/k:.4f}")
    assert sum(h[-k:]) < sum(h[:k]), "loss did not decrease"
    print("training loss decreased: OK")
    if trainer.straggler_steps:
        print(f"straggler steps detected: {trainer.straggler_steps}")

    # Serving story at training shapes: swap the tied unembed for a
    # compressed SparseLinear and re-score one training batch — all
    # batch * seq hidden rows decode-and-contract in one blocked SpMM
    # pass.
    eval_batch = pipe.batch(trainer.step)
    dense, sparse, head = sparse_head_eval(
        trainer.params, cfg, eval_batch, sparsity=args.head_sparsity)
    print(f"sparse head: {head.compression_vs_dense:.1f}x vs dense "
          f"({head.compressed_bytes} B), pool B={batch * seq}")
    print(f"eval loss: dense-head {dense:.4f}  sparse-head {sparse:.4f}")


if __name__ == "__main__":
    main()
