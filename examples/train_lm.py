"""End-to-end training driver: train a ~100M-param SmolLM-family model for
a few hundred steps on the synthetic pipeline, with checkpointing and an
injected mid-run failure + automatic restore (fault-tolerance demo).

Full run (~100M params, few hundred steps — minutes on real hardware,
hours on this 1-core CPU container):
    PYTHONPATH=src python examples/train_lm.py --steps 300

CI-sized run (default here):
    PYTHONPATH=src python examples/train_lm.py --steps 30 --tiny
"""

import argparse
import shutil

from repro.configs import get, get_smoke
from repro.data.pipeline import PipelineConfig, SyntheticTokens
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (fault-tolerance "
                         "demo); run resumes from the last checkpoint")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_smoke("smollm-135m").with_(vocab=512)
        batch, seq = 8, 64
    else:
        cfg = get("smollm-135m").with_(remat=False)   # ~135M params
        batch, seq = 16, 512

    shutil.rmtree(args.ckpt, ignore_errors=True)
    pipe = SyntheticTokens(PipelineConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=0))
    tcfg = TrainConfig(optimizer="adamw", lr=3e-4, microbatches=2,
                       ckpt_every=10, ckpt_dir=args.ckpt)
    trainer = Trainer(cfg, tcfg, pipe)
    print(f"arch={cfg.name} params~"
          f"{sum(x.size for x in __import__('jax').tree.leaves(trainer.params))/1e6:.1f}M "
          f"batch={batch} seq={seq}")

    try:
        trainer.run(args.steps, log_every=5, fail_at=args.fail_at)
    except RuntimeError as e:
        print(f"!! {e} — restoring from checkpoint and resuming")
        restored = trainer.try_restore()
        print(f"restored={restored} at step {trainer.step}")
        trainer.run(args.steps, log_every=5)

    h = trainer.history
    k = max(3, len(h) // 5)
    print(f"loss: first-{k}-avg {sum(h[:k])/k:.4f} -> "
          f"last-{k}-avg {sum(h[-k:])/k:.4f}")
    assert sum(h[-k:]) < sum(h[:k]), "loss did not decrease"
    print("training loss decreased: OK")
    if trainer.straggler_steps:
        print(f"straggler steps detected: {trainer.straggler_steps}")


if __name__ == "__main__":
    main()
