"""Summarize dry-run JSONs into the EXPERIMENTS.md roofline table."""

import json
import os
import sys

DDIR = os.path.join(os.path.dirname(__file__), "dryrun")


def load(mesh="single"):
    rows = []
    for f in sorted(os.listdir(DDIR)):
        if not f.endswith(f"__{mesh}.json"):
            continue
        rec = json.load(open(os.path.join(DDIR, f)))
        rows.append(rec)
    return rows


def table(mesh="single", fmt="md"):
    rows = load(mesh)
    out = []
    hdr = ("| arch | shape | kind | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS/HLO | live GiB | fits |")
    sep = "|" + "---|" * 10
    out += [hdr, sep]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skip | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | "
                       f"| |")
            continue
        ro = r["roofline"]
        m = r["memory"]
        ur = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{ro['compute_s']:.2e} | {ro['memory_s']:.2e} | "
            f"{ro['collective_s']:.2e} | {ro['dominant']} | "
            f"{ur:.2f} | {m['peak_live_bytes']/2**30:.2f} | "
            f"{'Y' if m['fits_hbm'] else 'N'} |")
    return "\n".join(out)


def pick_hillclimb():
    """Worst roofline fraction / most collective-bound / paper-representative."""
    rows = [r for r in load("single") if r["status"] == "ok"]
    def frac(r):   # compute / total: lower = further from compute roofline
        ro = r["roofline"]
        tot = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        return ro["compute_s"] / tot if tot else 1.0
    worst = min(rows, key=frac)
    coll = max(rows, key=lambda r: (r["roofline"]["collective_s"]
                                    / max(r["roofline"]["compute_s"]
                                          + r["roofline"]["memory_s"],
                                          1e-12)))
    print("worst roofline fraction:", worst["arch"], worst["shape"],
          f"frac={frac(worst):.4f}")
    print("most collective-bound:", coll["arch"], coll["shape"],
          f"coll={coll['roofline']['collective_s']:.2e}")
    srt = sorted(rows, key=frac)
    for r in srt[:8]:
        ro = r["roofline"]
        print(f"  {r['arch']:22s} {r['shape']:12s} frac={frac(r):.4f} "
              f"dom={ro['dominant']} c/m/x={ro['compute_s']:.2e}/"
              f"{ro['memory_s']:.2e}/{ro['collective_s']:.2e}")


def write_md():
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("<!-- ROOFLINE_TABLE_SINGLE -->", table("single"))
    text = text.replace("<!-- ROOFLINE_TABLE_MULTI -->", table("multi"))
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables written")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "pick":
        pick_hillclimb()
    elif len(sys.argv) > 1 and sys.argv[1] == "write-md":
        write_md()
    else:
        print(table(sys.argv[1] if len(sys.argv) > 1 else "single"))
