"""repro: JAX/TPU reproduction of "Fast Entropy Decoding for Sparse MVM on GPUs".

The dtANS codec works on 32-bit words with up-to-96-bit intermediate decoder
state (held as uint64 limb pairs, mirroring the paper's use of ``__umul_hi``
on GPU). JAX therefore runs with x64 enabled, package-wide. All model /
training code uses *explicit* dtypes (bf16/f32/i32) so nothing silently
widens; ``tests/test_dryrun.py`` asserts no f64/s64 leaks into lowered HLO.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
