"""repro.autotune: per-matrix format selection and kernel autotuning.

The paper's Fig. 9 argues per-matrix format tuning is valuable but — in
AlphaSparse form — prohibitively expensive. This package is the cheap
version: fingerprint the sparsity structure (`fingerprint`), predict
runtime and encoded size of each candidate format under a roofline
machine model (`cost_model`), search the candidates with an optional
measured-refinement budget (`search.select`), and remember decisions in
a persistent cache (`cache.DecisionCache`).

    from repro.autotune import select
    decision = select(csr_matrix)          # Decision(fmt="sell", ...)
    decision = select(csr_matrix, warm=False, budget=2)  # refine top-2
"""

from repro.autotune.cache import (DecisionCache, default_cache,
                                  default_cache_path)
from repro.autotune.cost_model import (DTANS_LANE_WIDTHS, V5E, Candidate,
                                       MachineModel, candidates,
                                       coo_nbytes, csr_nbytes,
                                       dtans_config_name,
                                       dtans_nbytes_estimate, model_time,
                                       sell_nbytes, spmv_bytes)
from repro.autotune.fingerprint import (Fingerprint, codeable_bits,
                                        fingerprint)
from repro.autotune.search import (ALL_FORMATS, Decision,
                                   choose_dtans_config, clear_memo,
                                   select)

__all__ = [
    "ALL_FORMATS", "Candidate", "Decision", "DecisionCache",
    "DTANS_LANE_WIDTHS", "Fingerprint", "MachineModel", "V5E",
    "candidates", "choose_dtans_config", "clear_memo", "codeable_bits",
    "coo_nbytes", "csr_nbytes", "default_cache", "default_cache_path",
    "dtans_config_name",
    "dtans_nbytes_estimate", "fingerprint", "model_time", "select",
    "sell_nbytes", "spmv_bytes",
]
