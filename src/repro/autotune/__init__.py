"""repro.autotune: per-matrix format selection and kernel autotuning.

The paper's Fig. 9 argues per-matrix format tuning is valuable but — in
AlphaSparse form — prohibitively expensive. This package is the cheap
version: fingerprint the sparsity structure (`fingerprint`), predict
runtime and encoded size of each candidate format under a roofline
machine model (`cost_model`), search the candidates with an optional
measured-refinement budget (`search.select`), and remember decisions in
a persistent cache (`cache.DecisionCache`).

    from repro.autotune import select
    decision = select(csr_matrix)          # Decision(fmt="sell", ...)
    decision = select(csr_matrix, warm=False, budget=2)  # refine top-2
"""

from repro.autotune.cache import (DecisionCache, atomic_merge_json,
                                  default_cache, default_cache_path)
from repro.autotune.cost_model import (DTANS_LANE_WIDTHS, V5E, Candidate,
                                       MachineModel, bcsr_config_name,
                                       bcsr_dtans_nbytes_estimate,
                                       candidate_time,
                                       candidates, collective_time,
                                       coo_nbytes, csr_nbytes,
                                       dtans_config_name,
                                       dtans_nbytes_estimate,
                                       memory_time, model_time,
                                       rgcsr_config_name,
                                       rgcsr_dtans_config_name,
                                       rgcsr_dtans_nbytes_estimate,
                                       rgcsr_nbytes, sell_nbytes,
                                       spmm_bytes, spmv_bytes, spmv_time,
                                       work_time)
from repro.sparse.registry import (CostTerms, FormatSpec, format_names,
                                   get_format, iter_formats,
                                   parse_config, register, unregister)
from repro.autotune.fingerprint import (Fingerprint, codeable_bits,
                                        fingerprint, lockstep_elems,
                                        max_group_nnz)
from repro.autotune.measure import (NOISY_REL_IQR, CalibrationResult,
                                    TimingSample, calibrate,
                                    default_profiles_path, list_profiles,
                                    load_profile, measure_candidate,
                                    measure_config, measure_named,
                                    parse_config_name, save_profile,
                                    spmv_runner, time_kernel)
from repro.autotune.oracle import oracle_best, oracle_times
from repro.autotune.search import (ALL_FORMATS, Decision,
                                   choose_dtans_config, clear_memo,
                                   select, shard_counts)
from repro.sparse.rgcsr import RGCSR_GROUP_SIZES

__all__ = [
    "ALL_FORMATS", "CalibrationResult", "Candidate", "CostTerms",
    "Decision", "DecisionCache", "NOISY_REL_IQR", "TimingSample",
    "DTANS_LANE_WIDTHS", "Fingerprint", "FormatSpec", "MachineModel",
    "RGCSR_GROUP_SIZES", "V5E",
    "atomic_merge_json", "bcsr_config_name",
    "bcsr_dtans_nbytes_estimate", "calibrate",
    "candidate_time", "candidates", "choose_dtans_config", "clear_memo",
    "codeable_bits", "collective_time",
    "coo_nbytes", "csr_nbytes", "default_cache", "default_cache_path",
    "default_profiles_path",
    "dtans_config_name",
    "dtans_nbytes_estimate", "fingerprint", "format_names",
    "get_format", "iter_formats",
    "list_profiles", "load_profile", "lockstep_elems", "max_group_nnz",
    "measure_candidate", "measure_config", "measure_named",
    "memory_time", "model_time",
    "oracle_best", "parse_config", "parse_config_name",
    "oracle_times", "register", "rgcsr_config_name",
    "rgcsr_dtans_config_name",
    "rgcsr_dtans_nbytes_estimate", "rgcsr_nbytes", "save_profile",
    "select", "shard_counts",
    "sell_nbytes", "spmm_bytes", "spmv_bytes", "spmv_time",
    "time_kernel", "unregister", "work_time",
]
