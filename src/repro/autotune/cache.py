"""Persistent decision cache for the format autotuner.

Decisions are keyed by ``fingerprint hash | machine | model knobs`` so a
serving process that re-loads the same matrix (same structure, same
values) skips the candidate search entirely — the AlphaSparse overhead
the paper calls "extreme" becomes a dictionary lookup on every run after
the first.

Storage is a single JSON file (human-inspectable, atomic-rename writes).
Default location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``. A cache constructed with
``path=None`` is memory-only (used by tests and benchmarks).
"""

from __future__ import annotations

import json
import os
import tempfile

from repro import obs

_ENV_VAR = "REPRO_AUTOTUNE_CACHE"


def _flock(path: str):
    """Best-effort exclusive advisory lock (context manager).

    Locks a ``<path>.lock`` sidecar, not the target itself — the target
    inode changes on every ``os.replace``, so a lock on it would not
    serialize anything. Platforms/filesystems without working flock
    degrade to unlocked operation (the atomic rename still guarantees
    readers never see a torn file)."""
    import contextlib

    @contextlib.contextmanager
    def cm():
        fd = None
        try:
            try:
                import fcntl
                fd = os.open(path + ".lock",
                             os.O_CREAT | os.O_RDWR, 0o644)
                fcntl.flock(fd, fcntl.LOCK_EX)
            except (ImportError, OSError):
                pass
            yield
        finally:
            if fd is not None:
                try:
                    os.close(fd)       # closing drops the flock
                except OSError:
                    pass

    return cm()


def atomic_merge_json(path: str, updates: dict, *,
                      strict: bool = False) -> dict:
    """Merge ``updates`` into the JSON object at ``path`` atomically.

    Re-reads the file under an exclusive advisory lock so concurrent
    processes cannot clobber each other's keys: whatever is on disk at
    write time is kept and ``updates`` wins per key (last-write-wins).
    The write lands via tempfile + ``os.replace`` so readers never
    observe a torn file. Returns the merged mapping.

    ``strict=False`` (decision cache): any filesystem error degrades to
    a no-op — the caller keeps its in-memory copy. ``strict=True``
    (machine profiles): write errors re-raise, and a *read* error other
    than the file not existing also re-raises — treating a momentarily
    unreadable file as empty would silently discard every previously
    saved key on the next write.
    """
    with _flock(path):
        merged: dict = {}
        try:
            with open(path) as f:
                on_disk = json.load(f)
            if isinstance(on_disk, dict):
                merged = on_disk
        except FileNotFoundError:
            pass                      # first write
        except ValueError:
            pass  # corrupt file == empty mapping (heals on write)
        except OSError:
            if strict:
                raise
        merged.update(updates)

        tmp = None
        try:
            d = os.path.dirname(path) or "."
            os.makedirs(d, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(merged, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
            tmp = None
        except OSError:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            if strict:
                raise
    return merged


def default_cache_path() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


class DecisionCache:
    """key (str) -> decision (JSON-serializable dict)."""

    def __init__(self, path: str | os.PathLike | None = "default"):
        if path == "default":
            path = default_cache_path()
        self.path = os.fspath(path) if path is not None else None
        self._mem: dict | None = None

    # -- internals ------------------------------------------------------
    def _load(self) -> dict:
        if self._mem is None:
            self._mem = {}
            if self.path and os.path.exists(self.path):
                try:
                    with open(self.path) as f:
                        data = json.load(f)
                    if isinstance(data, dict):
                        self._mem = data
                except (OSError, ValueError):
                    pass  # corrupt/unreadable cache == empty cache
        return self._mem

    def _persist(self) -> None:
        """Merge this process's decisions into the on-disk file.

        Writing the in-process memo verbatim would let two serving
        processes sharing one cache file clobber each other's keys
        (each overwrites with only the decisions *it* has seen);
        `atomic_merge_json` re-reads the disk contents under the same
        atomic rename, so concurrent writers union their keys with
        last-write-wins per key. An unwritable cache degrades to
        memory-only; selection must never fail because persistence did.
        """
        if not self.path:
            return
        merged = atomic_merge_json(self.path, self._mem, strict=False)
        # Adopt keys other processes persisted meanwhile — the next
        # get() on this process sees them without a disk re-read.
        self._mem = merged

    # -- API ------------------------------------------------------------
    def get(self, key: str) -> dict | None:
        """Lookup with hit/miss accounting: every ``get`` bumps
        ``autotune.decision_cache.hits`` or ``.misses`` in the default
        metrics registry, so serving runs can see whether repeated
        selections actually short-circuit (a cold cache on every
        process start shows up as a miss streak, not silence)."""
        v = self._load().get(key)
        obs.default_registry().counter(
            "autotune.decision_cache.hits" if v is not None
            else "autotune.decision_cache.misses").add(1)
        return v

    def put(self, key: str, decision: dict) -> None:
        obs.default_registry().counter(
            "autotune.decision_cache.puts").add(1)
        self._load()[key] = decision
        self._persist()

    def clear(self) -> None:
        self._mem = {}
        if self.path and os.path.exists(self.path):
            try:
                os.unlink(self.path)
            except OSError:
                pass

    def __len__(self) -> int:
        return len(self._load())

    def __contains__(self, key: str) -> bool:
        return key in self._load()


_default: DecisionCache | None = None


def default_cache() -> DecisionCache:
    """Process-wide cache at the default on-disk location."""
    global _default
    if _default is None:
        _default = DecisionCache()
    return _default
