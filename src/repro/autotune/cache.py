"""Persistent decision cache for the format autotuner.

Decisions are keyed by ``fingerprint hash | machine | model knobs`` so a
serving process that re-loads the same matrix (same structure, same
values) skips the candidate search entirely — the AlphaSparse overhead
the paper calls "extreme" becomes a dictionary lookup on every run after
the first.

Storage is a single JSON file (human-inspectable, atomic-rename writes).
Default location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``. A cache constructed with
``path=None`` is memory-only (used by tests and benchmarks).
"""

from __future__ import annotations

import json
import os
import tempfile

_ENV_VAR = "REPRO_AUTOTUNE_CACHE"


def default_cache_path() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


class DecisionCache:
    """key (str) -> decision (JSON-serializable dict)."""

    def __init__(self, path: str | os.PathLike | None = "default"):
        if path == "default":
            path = default_cache_path()
        self.path = os.fspath(path) if path is not None else None
        self._mem: dict | None = None

    # -- internals ------------------------------------------------------
    def _load(self) -> dict:
        if self._mem is None:
            self._mem = {}
            if self.path and os.path.exists(self.path):
                try:
                    with open(self.path) as f:
                        data = json.load(f)
                    if isinstance(data, dict):
                        self._mem = data
                except (OSError, ValueError):
                    pass  # corrupt/unreadable cache == empty cache
        return self._mem

    def _persist(self) -> None:
        if not self.path:
            return
        d = os.path.dirname(self.path) or "."
        tmp = None
        try:
            os.makedirs(d, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(self._mem, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            # An unwritable cache degrades to memory-only; selection
            # must never fail because persistence did.
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    # -- API ------------------------------------------------------------
    def get(self, key: str) -> dict | None:
        return self._load().get(key)

    def put(self, key: str, decision: dict) -> None:
        self._load()[key] = decision
        self._persist()

    def clear(self) -> None:
        self._mem = {}
        if self.path and os.path.exists(self.path):
            try:
                os.unlink(self.path)
            except OSError:
                pass

    def __len__(self) -> int:
        return len(self._load())

    def __contains__(self, key: str) -> bool:
        return key in self._load()


_default: DecisionCache | None = None


def default_cache() -> DecisionCache:
    """Process-wide cache at the default on-disk location."""
    global _default
    if _default is None:
        _default = DecisionCache()
    return _default
