"""Roofline-style cost model for sparse formats on one accelerator chip.

This is the library home of the performance model that previously lived
in ``benchmarks/suite.py``: SpMVM is memory-bound, so the runtime of a
format is two-level memory time plus (for entropy-coded formats) a
decode-compute term:

    t = miss_bytes / hbm_bw + hit_bytes / cache_bw + ops / vpu_rate

with ``hit_bytes = min(bytes, cache_bytes)`` for a warm cache (the
paper's 96 MB GPU L2 has the v5e CMEM/VMEM-resident working set as its
analogue) and 0 for a cold one. CSR-dtANS adds ``decode_ops_per_nnz``
vector ops per nonzero (segment unpack + table gathers + limb update,
counted from ``kernels/common.py``). This mirrors the paper's
observation that warm caches shift the bottleneck from bytes to decode
throughput (Section V-B vs V-C), and is the predictor behind the
paper-Fig. 9 format-selection question that `repro.autotune.select`
answers per matrix.

Byte counts for CSR/COO/SELL are *exact* given a fingerprint; CSR-dtANS
bytes are estimated from the fingerprint's escape-aware entropy features
(see `fingerprint.codeable_bits`) and can be refined by actually
encoding (``search.select(budget=...)``).
"""

from __future__ import annotations

import dataclasses
import math

from repro.autotune.fingerprint import Fingerprint
from repro.core.params import PAPER, DtansParams


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Per-chip machine constants of the roofline model."""

    name: str = "v5e"
    hbm_bw: float = 819e9            # bytes/s
    cache_bw: float = 4 * 819e9      # VMEM-resident reread bandwidth
    cache_bytes: float = 96e6        # paper's L2 size, for comparability
    vpu_rate: float = 1.9e12         # vector ops/s (8x128 x 2 ALUs)
    decode_ops_per_nnz: float = 16   # unpack + 2 gathers + limb ops

    def signature(self) -> str:
        """Cache-key component: the *constants*, not just the name, so
        recalibrating a model never serves stale cached decisions."""
        return (f"{self.name}:{self.hbm_bw:g}:{self.cache_bw:g}:"
                f"{self.cache_bytes:g}:{self.vpu_rate:g}:"
                f"{self.decode_ops_per_nnz:g}")


def dtans_config_name(lane_width: int, shared_table: bool) -> str:
    """Canonical display/lookup name of one CSR-dtANS configuration.

    Single source of truth — `Candidate.config_name`,
    `search.Decision.config_name`, the benchmarks and the tests all key
    result tables by this string.
    """
    tables = "shared" if shared_table else "split"
    return f"dtans[w={lane_width},{tables}]"


#: Default chip model (TPU v5e), numerically identical to the constants
#: the benchmarks have always used.
V5E = MachineModel()

#: dtANS configurations enumerated by the tuner: GPU-warp and TPU-lane
#: interleave widths x shared vs per-domain coding tables.
DTANS_LANE_WIDTHS = (32, 128)
DTANS_SHARED_TABLE = (True, False)


def spmv_bytes(fmt_bytes: int, n: int, m: int, vbytes: int) -> int:
    """Bytes moved by one SpMVM: matrix + x + y (paper Section III-A)."""
    return fmt_bytes + n * vbytes + m * vbytes


def model_time(bytes_moved: int, nnz: int, *, warm: bool, decode: bool,
               machine: MachineModel = V5E) -> float:
    """Modeled seconds of one SpMVM pass."""
    hit = min(bytes_moved, machine.cache_bytes) if warm else 0.0
    miss = bytes_moved - hit
    t = miss / machine.hbm_bw + hit / machine.cache_bw
    if decode:
        t += nnz * machine.decode_ops_per_nnz / machine.vpu_rate
    return t


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One (format, config) point with its size and modeled runtime."""

    fmt: str                      # "csr" | "coo" | "sell" | "dtans"
    nbytes: int                   # format bytes (estimated or exact)
    modeled_time: float           # seconds per SpMVM pass
    exact_size: bool              # True when nbytes is not an estimate
    lane_width: int | None = None      # dtans only
    shared_table: bool | None = None   # dtans only

    @property
    def config_name(self) -> str:
        if self.fmt != "dtans":
            return self.fmt
        return dtans_config_name(self.lane_width, self.shared_table)


def csr_nbytes(fp: Fingerprint) -> int:
    return fp.nnz * (4 + fp.value_bytes) + (fp.rows + 1) * 4


def coo_nbytes(fp: Fingerprint) -> int:
    return fp.nnz * (8 + fp.value_bytes)


def sell_nbytes(fp: Fingerprint) -> int:
    from repro.autotune.fingerprint import SELL_SLICE_HEIGHT
    nslices = -(-fp.rows // SELL_SLICE_HEIGHT)
    return (fp.sell_padded_nnz * (4 + fp.value_bytes)
            + (nslices + 1) * 4)


def dtans_nbytes_estimate(fp: Fingerprint, *, lane_width: int = 128,
                          shared_table: bool = True,
                          params: DtansParams = PAPER) -> int:
    """Estimated `CSRdtANS.nbytes` from fingerprint features alone.

    Mirrors the exact accounting in `repro.core.csr_dtans.CSRdtANS`:
    tables + 4-byte stream words + escaped raw payloads + one 4-byte
    per-row length + per-slice offsets.

    The stream-word count uses the encoder's segment mechanics rather
    than raw entropy: every l-symbol segment emits ``o`` words minus the
    conditional-load extractions it earns, extraction happens only on
    non-final segments of a row (``encode_scalar`` branches only while
    ``j < nseg - 1``), and each extraction is a whole 32-bit word — so a
    segment carrying ``b`` information bits extracts
    ``clip(floor((o*32 - b) / 32), 0, f)`` words. Information bits per
    symbol come from the fingerprint's escape-aware table estimate.
    """
    vb = fp.value_bytes
    K = params.K
    T = 1 if shared_table else 2
    n_slices = -(-fp.rows // lane_width) if fp.rows else 0

    symbols = 2 * fp.nnz + fp.segment_pad_symbols
    if shared_table:
        real_bps = fp.merged_stream_bits
    else:
        real_bps = (fp.delta_stream_bits + fp.value_stream_bits) / 2.0
    # Tail padding uses the cheapest in-table symbol: log2(K/M) bits.
    pad_bps = params.k_bits - params.m_bits
    bps = ((2 * fp.nnz * real_bps + fp.segment_pad_symbols * pad_bps)
           / symbols) if symbols else 0.0

    seg_bits = params.l * bps
    extracts = min(max(math.floor((params.o * 32 - seg_bits) / 32.0), 0),
                   params.f)
    n_nonlast = fp.n_segments - fp.nonempty_rows
    stream_words = params.o * fp.n_segments - extracts * n_nonlast
    stream_bytes = 4 * stream_words

    esc_bytes = int(fp.delta_escape_frac * fp.nnz) * 4
    esc_bytes += int(fp.value_escape_frac * fp.nnz) * vb

    b = T * K * (vb + 8)                 # coding tables
    b += stream_bytes
    b += esc_bytes
    b += fp.rows * 4                     # per-row n
    b += (n_slices + 1) * 8              # stream offsets
    b += (n_slices + 1) * 4 * T          # escape offsets
    return int(b)


def candidates(fp: Fingerprint, *, machine: MachineModel = V5E,
               warm: bool = True, params: DtansParams = PAPER,
               formats: tuple = ("csr", "coo", "sell", "dtans"),
               lane_widths: tuple = DTANS_LANE_WIDTHS) -> list[Candidate]:
    """Enumerate candidate formats, cheapest modeled time first."""
    m, n, vb = fp.rows, fp.cols, fp.value_bytes

    def t(nbytes: int, decode: bool) -> float:
        return model_time(spmv_bytes(nbytes, n, m, vb), fp.nnz,
                          warm=warm, decode=decode, machine=machine)

    out: list[Candidate] = []
    exact = {"csr": csr_nbytes, "coo": coo_nbytes, "sell": sell_nbytes}
    for fmt in formats:
        if fmt in exact:
            b = exact[fmt](fp)
            out.append(Candidate(fmt=fmt, nbytes=b, modeled_time=t(b, False),
                                 exact_size=True))
        elif fmt == "dtans":
            for w in lane_widths:
                for shared in DTANS_SHARED_TABLE:
                    b = dtans_nbytes_estimate(fp, lane_width=w,
                                              shared_table=shared,
                                              params=params)
                    out.append(Candidate(
                        fmt="dtans", nbytes=b, modeled_time=t(b, True),
                        exact_size=False, lane_width=w,
                        shared_table=shared))
        else:
            raise ValueError(f"unknown format {fmt!r}")
    out.sort(key=lambda c: c.modeled_time)
    return out
