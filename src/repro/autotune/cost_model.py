"""Roofline-style cost model for sparse formats on one accelerator chip.

This is the library home of the performance model that previously lived
in ``benchmarks/suite.py``: SpMVM is memory-bound, so the runtime of a
format is two-level memory time plus a compute term:

    t = miss_bytes / hbm_bw + hit_bytes / cache_bw + work / vpu_rate

with ``hit_bytes = min(bytes, cache_bytes)`` for a warm cache (the
paper's 96 MB GPU L2 has the v5e CMEM/VMEM-resident working set as its
analogue) and 0 for a cold one.

The compute term distinguishes *how* each format's kernel walks the
matrix (``work = work_elems * ops_per_elem``):

* **lock-step formats** (SELL, RGCSR, the dtANS family) process slices
  of ``width`` rows to the longest row in the slice, so their
  ``work_elems`` is `fingerprint.lockstep_elems` — stored *plus padded*
  element slots. SELL additionally pays that padding in bytes; RGCSR and
  RGCSR-dtANS store compactly and pay it only here, which is exactly the
  padding-waste vs slice-alignment trade the selector arbitrates.
* **row-sequential formats** (CSR, COO) touch only real nonzeros but
  cannot fill the vector unit with irregular rows; they are charged
  ``row_seq_penalty`` ops per element (sublane utilization, the reason
  GPU SpMV abandons plain CSR).
* **entropy-coded formats** add ``decode_ops_per_nnz`` vector ops per
  processed element (segment unpack + table gathers + limb update,
  counted from ``kernels/common.py``) — the paper's observation that
  warm caches shift the bottleneck from bytes to decode throughput
  (Section V-B vs V-C). This is the predictor behind the paper-Fig. 9
  format-selection question that `repro.autotune.select` answers per
  matrix.

Byte counts for CSR/COO/SELL/RGCSR are *exact* given a fingerprint;
dtANS-family bytes are estimated from the fingerprint's escape-aware
entropy features (see `fingerprint.codeable_bits`) and can be refined by
actually encoding (``search.select(budget=...)``).

(`model_time` keeps the original two-term + decode-flag form for the
paper-figure benchmarks, Figs. 7/8; the selector path uses `spmv_time`.)
"""

from __future__ import annotations

import dataclasses
import math

from repro.autotune.fingerprint import Fingerprint
from repro.core.params import PAPER, DtansParams
from repro.sparse.rgcsr import RGCSR_GROUP_SIZES, local_indptr_bytes


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Per-chip machine constants of the roofline model."""

    name: str = "v5e"
    hbm_bw: float = 819e9            # bytes/s
    cache_bw: float = 4 * 819e9      # VMEM-resident reread bandwidth
    cache_bytes: float = 96e6        # paper's L2 size, for comparability
    vpu_rate: float = 1.9e12         # vector ops/s (8x128 x 2 ALUs)
    decode_ops_per_nnz: float = 16   # unpack + 2 gathers + limb ops
    spmv_ops_per_elem: float = 1     # madd+gather per lock-step element
    row_seq_penalty: float = 8       # CSR/COO sublane utilization factor

    def signature(self) -> str:
        """Cache-key component: the *constants*, not just the name, so
        recalibrating a model never serves stale cached decisions."""
        return (f"{self.name}:{self.hbm_bw:g}:{self.cache_bw:g}:"
                f"{self.cache_bytes:g}:{self.vpu_rate:g}:"
                f"{self.decode_ops_per_nnz:g}:{self.spmv_ops_per_elem:g}:"
                f"{self.row_seq_penalty:g}")

    def to_dict(self) -> dict:
        """JSON form — the payload of a persisted machine profile
        (`repro.autotune.measure.save_profile`)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MachineModel":
        """Inverse of `to_dict`; unknown keys are rejected so a foreign
        profile file fails loudly rather than half-applying."""
        fields = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - fields
        if extra:
            raise ValueError(f"unknown MachineModel fields: {sorted(extra)}")
        return cls(**d)


def dtans_config_name(lane_width: int, shared_table: bool) -> str:
    """Canonical display/lookup name of one CSR-dtANS configuration.

    Single source of truth — `Candidate.config_name`,
    `search.Decision.config_name`, the benchmarks and the tests all key
    result tables by this string.
    """
    tables = "shared" if shared_table else "split"
    return f"dtans[w={lane_width},{tables}]"


def rgcsr_config_name(group_size: int) -> str:
    """Canonical name of one plain-RGCSR configuration."""
    return f"rgcsr[G={group_size}]"


def rgcsr_dtans_config_name(group_size: int,
                            shared_table: bool = True) -> str:
    """Canonical name of one RGCSR-dtANS configuration."""
    tables = "shared" if shared_table else "split"
    return f"rgcsr_dtans[G={group_size},{tables}]"


#: Default chip model (TPU v5e), numerically identical to the constants
#: the benchmarks have always used.
V5E = MachineModel()

#: dtANS configurations enumerated by the tuner: GPU-warp and TPU-lane
#: interleave widths x shared vs per-domain coding tables.
DTANS_LANE_WIDTHS = (32, 128)
DTANS_SHARED_TABLE = (True, False)


def spmv_bytes(fmt_bytes: int, n: int, m: int, vbytes: int) -> int:
    """Bytes moved by one SpMVM: matrix + x + y (paper Section III-A)."""
    return fmt_bytes + n * vbytes + m * vbytes


def model_time(bytes_moved: int, nnz: int, *, warm: bool, decode: bool,
               machine: MachineModel = V5E) -> float:
    """Modeled seconds of one SpMVM pass (legacy two-term form).

    Kept verbatim for the paper-figure benchmarks (Figs. 7/8 compare a
    fixed CSR-dtANS against byte-count baselines under the paper's own
    model). The selector uses `spmv_time`, which also charges the
    per-format kernel work."""
    hit = min(bytes_moved, machine.cache_bytes) if warm else 0.0
    miss = bytes_moved - hit
    t = miss / machine.hbm_bw + hit / machine.cache_bw
    if decode:
        t += nnz * machine.decode_ops_per_nnz / machine.vpu_rate
    return t


#: Lock-step formats (work_elems from `Fingerprint.lockstep`); the rest
#: of the known formats are row-sequential.
LOCKSTEP_FORMATS = ("sell", "rgcsr", "dtans", "rgcsr_dtans")
DECODE_FORMATS = ("dtans", "rgcsr_dtans")
KNOWN_FORMATS = ("csr", "coo", "sell", "rgcsr", "dtans", "rgcsr_dtans")


def format_ops_per_elem(fmt: str, machine: MachineModel = V5E) -> float:
    """Vector ops one kernel spends per processed element slot."""
    if fmt in ("csr", "coo"):
        return machine.spmv_ops_per_elem * machine.row_seq_penalty
    if fmt in ("sell", "rgcsr"):
        return machine.spmv_ops_per_elem
    if fmt in DECODE_FORMATS:
        return machine.spmv_ops_per_elem + machine.decode_ops_per_nnz
    raise ValueError(f"unknown format {fmt!r}")


def spmv_time(nbytes: int, work_elems: float, ops_per_elem: float, *,
              rows: int, cols: int, vbytes: int, warm: bool,
              machine: MachineModel = V5E) -> float:
    """Modeled seconds of one SpMVM pass (selector model: memory time
    plus per-format kernel work)."""
    bytes_moved = spmv_bytes(nbytes, cols, rows, vbytes)
    hit = min(bytes_moved, machine.cache_bytes) if warm else 0.0
    miss = bytes_moved - hit
    return (miss / machine.hbm_bw + hit / machine.cache_bw
            + work_elems * ops_per_elem / machine.vpu_rate)


def candidate_time(fp: Fingerprint, fmt: str, nbytes: int, *, warm: bool,
                   machine: MachineModel = V5E,
                   lane_width: int | None = None,
                   group_size: int | None = None) -> float:
    """`spmv_time` of one (format, config) from fingerprint features.

    The single formula shared by `candidates`, `search._refine` and the
    exhaustive oracle (`repro.autotune.oracle`) — selector and oracle
    cannot drift apart.
    """
    if fmt in ("csr", "coo"):
        work = fp.nnz
    elif fmt == "sell":
        work = fp.sell_padded_nnz
    elif fmt == "rgcsr":
        work = fp.lockstep(group_size)
    elif fmt == "dtans":
        work = fp.lockstep(lane_width)
    elif fmt == "rgcsr_dtans":
        work = fp.lockstep(group_size)
    else:
        raise ValueError(f"unknown format {fmt!r}")
    return spmv_time(nbytes, work, format_ops_per_elem(fmt, machine),
                     rows=fp.rows, cols=fp.cols, vbytes=fp.value_bytes,
                     warm=warm, machine=machine)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One (format, config) point with its size and modeled runtime."""

    fmt: str                      # one of KNOWN_FORMATS
    nbytes: int                   # format bytes (estimated or exact)
    modeled_time: float           # seconds per SpMVM pass
    exact_size: bool              # True when nbytes is not an estimate
    lane_width: int | None = None      # dtans family only
    shared_table: bool | None = None   # dtans family only
    group_size: int | None = None      # rgcsr family only
    # Median wall-clock seconds from `repro.autotune.measure`; filled
    # by the measured-refinement pass, None for modeled-only search.
    measured_time: float | None = None

    @property
    def config_name(self) -> str:
        if self.fmt == "dtans":
            return dtans_config_name(self.lane_width, self.shared_table)
        if self.fmt == "rgcsr":
            return rgcsr_config_name(self.group_size)
        if self.fmt == "rgcsr_dtans":
            return rgcsr_dtans_config_name(self.group_size,
                                           self.shared_table)
        return self.fmt


def csr_nbytes(fp: Fingerprint) -> int:
    return fp.nnz * (4 + fp.value_bytes) + (fp.rows + 1) * 4


def coo_nbytes(fp: Fingerprint) -> int:
    return fp.nnz * (8 + fp.value_bytes)


def sell_nbytes(fp: Fingerprint) -> int:
    from repro.autotune.fingerprint import SELL_SLICE_HEIGHT
    nslices = -(-fp.rows // SELL_SLICE_HEIGHT)
    return (fp.sell_padded_nnz * (4 + fp.value_bytes)
            + (nslices + 1) * 4)


def rgcsr_nbytes(fp: Fingerprint, group_size: int) -> int:
    """`repro.sparse.rgcsr.RGCSR.nbytes` from the fingerprint's row-nnz
    histogram features (mirrors `rgcsr_nbytes_exact`).

    Exact for group sizes in RGCSR_GROUP_SIZES; for other sizes
    `Fingerprint.group_max_nnz` falls back to ``nnz`` (conservative:
    may charge 4-byte local indptr where the real format uses 2), so
    `candidates` marks those estimated and ``budget`` refinement
    constructs the truth."""
    G = int(group_size)
    ngroups = -(-fp.rows // G) if fp.rows else 0
    lb = local_indptr_bytes(fp.group_max_nnz(G))
    return (fp.nnz * (4 + fp.value_bytes) + ngroups * (G + 1) * lb
            + (ngroups + 1) * 4)


def dtans_nbytes_estimate(fp: Fingerprint, *, lane_width: int = 128,
                          shared_table: bool = True,
                          params: DtansParams = PAPER) -> int:
    """Estimated `CSRdtANS.nbytes` from fingerprint features alone.

    Mirrors the exact accounting in `repro.core.csr_dtans.CSRdtANS`:
    tables + 4-byte stream words + escaped raw payloads + one 4-byte
    per-row length + per-slice offsets.

    The stream-word count uses the encoder's segment mechanics rather
    than raw entropy: every l-symbol segment emits ``o`` words minus the
    conditional-load extractions it earns, extraction happens only on
    non-final segments of a row (``encode_scalar`` branches only while
    ``j < nseg - 1``), and each extraction is a whole 32-bit word — so a
    segment carrying ``b`` information bits extracts
    ``clip(floor((o*32 - b) / 32), 0, f)`` words. Information bits per
    symbol come from the fingerprint's escape-aware table estimate.
    """
    vb = fp.value_bytes
    K = params.K
    T = 1 if shared_table else 2
    n_slices = -(-fp.rows // lane_width) if fp.rows else 0

    symbols = 2 * fp.nnz + fp.segment_pad_symbols
    if shared_table:
        real_bps = fp.merged_stream_bits
    else:
        real_bps = (fp.delta_stream_bits + fp.value_stream_bits) / 2.0
    # Tail padding uses the cheapest in-table symbol: log2(K/M) bits.
    pad_bps = params.k_bits - params.m_bits
    bps = ((2 * fp.nnz * real_bps + fp.segment_pad_symbols * pad_bps)
           / symbols) if symbols else 0.0

    seg_bits = params.l * bps
    extracts = min(max(math.floor((params.o * 32 - seg_bits) / 32.0), 0),
                   params.f)
    n_nonlast = fp.n_segments - fp.nonempty_rows
    stream_words = params.o * fp.n_segments - extracts * n_nonlast
    stream_bytes = 4 * stream_words

    esc_bytes = int(fp.delta_escape_frac * fp.nnz) * 4
    esc_bytes += int(fp.value_escape_frac * fp.nnz) * vb

    b = T * K * (vb + 8)                 # coding tables
    b += stream_bytes
    b += esc_bytes
    b += fp.rows * 4                     # per-row n
    b += (n_slices + 1) * 8              # stream offsets
    b += (n_slices + 1) * 4 * T          # escape offsets
    return int(b)


def rgcsr_dtans_nbytes_estimate(fp: Fingerprint, *, group_size: int = 32,
                                shared_table: bool = True,
                                params: DtansParams = PAPER) -> int:
    """Estimated `RGCSRdtANS.nbytes`: the CSR-dtANS estimate at interleave
    width G, with 4-byte per-row lengths replaced by group-local ones
    (16-bit unless some row reaches 2**16 nonzeros)."""
    base = dtans_nbytes_estimate(fp, lane_width=group_size,
                                 shared_table=shared_table, params=params)
    row_bytes = local_indptr_bytes(fp.row_nnz_max)
    return base - fp.rows * 4 + fp.rows * row_bytes


def candidates(fp: Fingerprint, *, machine: MachineModel = V5E,
               warm: bool = True, params: DtansParams = PAPER,
               formats: tuple = KNOWN_FORMATS,
               lane_widths: tuple = DTANS_LANE_WIDTHS,
               group_sizes: tuple = RGCSR_GROUP_SIZES) -> list[Candidate]:
    """Enumerate candidate formats, cheapest modeled time first."""

    def t(fmt: str, nbytes: int, lane_width=None, group_size=None) -> float:
        return candidate_time(fp, fmt, nbytes, warm=warm, machine=machine,
                              lane_width=lane_width, group_size=group_size)

    out: list[Candidate] = []
    exact = {"csr": csr_nbytes, "coo": coo_nbytes, "sell": sell_nbytes}
    for fmt in formats:
        if fmt in exact:
            b = exact[fmt](fp)
            out.append(Candidate(fmt=fmt, nbytes=b, modeled_time=t(fmt, b),
                                 exact_size=True))
        elif fmt == "rgcsr":
            for g in group_sizes:
                b = rgcsr_nbytes(fp, g)
                out.append(Candidate(
                    fmt="rgcsr", nbytes=b,
                    modeled_time=t("rgcsr", b, group_size=g),
                    # Sizes are exact only where the fingerprint carries
                    # the group-nnz feature; other sweeps are estimates
                    # until budget refinement constructs them.
                    exact_size=g in RGCSR_GROUP_SIZES, group_size=g))
        elif fmt == "dtans":
            for w in lane_widths:
                for shared in DTANS_SHARED_TABLE:
                    b = dtans_nbytes_estimate(fp, lane_width=w,
                                              shared_table=shared,
                                              params=params)
                    out.append(Candidate(
                        fmt="dtans", nbytes=b,
                        modeled_time=t("dtans", b, lane_width=w),
                        exact_size=False, lane_width=w,
                        shared_table=shared))
        elif fmt == "rgcsr_dtans":
            # Shared table only: the group sweep already multiplies the
            # candidate set, and split tables never paid off at narrow
            # interleave widths (table bytes double, stream bits do not).
            for g in group_sizes:
                b = rgcsr_dtans_nbytes_estimate(fp, group_size=g,
                                                shared_table=True,
                                                params=params)
                out.append(Candidate(
                    fmt="rgcsr_dtans", nbytes=b,
                    modeled_time=t("rgcsr_dtans", b, group_size=g),
                    exact_size=False, lane_width=g, shared_table=True,
                    group_size=g))
        else:
            raise ValueError(f"unknown format {fmt!r}")
    out.sort(key=lambda c: c.modeled_time)
    return out
