"""Roofline-style cost model for sparse formats on one accelerator chip.

This is the library home of the performance model that previously lived
in ``benchmarks/suite.py``: SpMVM is memory-bound, so the runtime of a
format is two-level memory time plus a compute term:

    t = miss_bytes / hbm_bw + hit_bytes / cache_bw + work / vpu_rate

with ``hit_bytes = min(bytes, cache_bytes)`` for a warm cache (the
paper's 96 MB GPU L2 has the v5e CMEM/VMEM-resident working set as its
analogue) and 0 for a cold one.

The compute term is priced from each format's
`repro.sparse.registry.FormatSpec.cost_terms` work split:

* **lock-step work** (SELL, RGCSR, BCSR, the dtANS family) — element
  slots processed ``spmv_ops_per_elem`` at a time, slices running to
  their longest row (`Fingerprint.lockstep`; BCSR counts its filled
  block cells). SELL additionally pays the padding in bytes; RGCSR
  stores compactly and pays it only here — exactly the padding-waste vs
  slice-alignment trade the selector arbitrates.
* **row-sequential work** (CSR, COO) — real nonzeros that cannot fill
  the vector unit with irregular rows, charged ``row_seq_penalty`` ops
  per element (sublane utilization, the reason GPU SpMV abandons plain
  CSR).
* **decode work** (the entropy-coded formats) — ``decode_ops_per_nnz``
  vector ops per processed element (segment unpack + table gathers +
  limb update, counted from ``kernels/common.py``) — the paper's
  observation that warm caches shift the bottleneck from bytes to
  decode throughput (Section V-B vs V-C). This is the predictor behind
  the paper-Fig. 9 format-selection question `repro.autotune.select`
  answers per matrix.

Byte counts come from the registry too: `FormatSpec.nbytes_exact` where
the fingerprint carries the format's features, `nbytes_estimate`
(escape-aware entropy features, see `fingerprint.codeable_bits`) for
the entropy-coded families, refinable by actually encoding
(``search.select(budget=...)``). The estimate formulas live here; the
specs call back into them lazily.

(`model_time` keeps the original two-term + decode-flag form for the
paper-figure benchmarks, Figs. 7/8; the selector path uses
`candidate_time` = `memory_time` + `work_time`.)
"""

from __future__ import annotations

import dataclasses
import math

from repro.autotune.fingerprint import Fingerprint
from repro.core.params import PAPER, DtansParams
from repro.sparse.registry import (CostTerms, DTANS_LANE_WIDTHS,
                                   DTANS_SHARED_TABLE, KnobbedConfigMixin,
                                   format_names, get_format)
from repro.sparse.rgcsr import RGCSR_GROUP_SIZES, local_indptr_bytes


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Per-chip machine constants of the roofline model."""

    name: str = "v5e"
    hbm_bw: float = 819e9            # bytes/s
    cache_bw: float = 4 * 819e9      # VMEM-resident reread bandwidth
    cache_bytes: float = 96e6        # paper's L2 size, for comparability
    vpu_rate: float = 1.9e12         # vector ops/s (8x128 x 2 ALUs)
    decode_ops_per_nnz: float = 16   # unpack + 2 gathers + limb ops
    spmv_ops_per_elem: float = 1     # madd+gather per lock-step element
    row_seq_penalty: float = 8       # CSR/COO sublane utilization factor
    # Interconnect terms of the sharded path (x broadcast + y psum over
    # the mesh ``model`` axis): effective per-device ring-collective
    # bandwidth over the v5e 2D-torus ICI, plus a fixed per-hop launch
    # latency.
    ici_bw: float = 9e10             # bytes/s per device, ring collective
    collective_latency: float = 1e-6  # seconds per collective hop
    # VMEM capacity available to one kernel program — the budget
    # `repro.kernels.tiling.choose_bn` tiles the RHS against.  When a
    # batch's x/y columns exceed it, the pass splits into column tiles
    # and the matrix stream (and its decode) is re-read once per tile:
    # the capacity term `spmm_bytes` / `work_time` charge via
    # ``col_tiles``.
    vmem_bytes: float = float(16 * 2 ** 20)

    def signature(self) -> str:
        """Cache-key component: the *constants*, not just the name, so
        recalibrating a model never serves stale cached decisions."""
        return (f"{self.name}:{self.hbm_bw:g}:{self.cache_bw:g}:"
                f"{self.cache_bytes:g}:{self.vpu_rate:g}:"
                f"{self.decode_ops_per_nnz:g}:{self.spmv_ops_per_elem:g}:"
                f"{self.row_seq_penalty:g}:{self.ici_bw:g}:"
                f"{self.collective_latency:g}:{self.vmem_bytes:g}")

    def to_dict(self) -> dict:
        """JSON form — the payload of a persisted machine profile
        (`repro.autotune.measure.save_profile`)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MachineModel":
        """Inverse of `to_dict`; unknown keys are rejected so a foreign
        profile file fails loudly rather than half-applying."""
        fields = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - fields
        if extra:
            raise ValueError(f"unknown MachineModel fields: {sorted(extra)}")
        return cls(**d)


def dtans_config_name(lane_width: int, shared_table: bool) -> str:
    """Canonical name of one CSR-dtANS configuration (registry-backed;
    `FormatSpec.encode_knobs` is the single source of truth)."""
    return get_format("dtans").encode_knobs(
        {"lane_width": lane_width, "shared_table": shared_table})


def rgcsr_config_name(group_size: int) -> str:
    """Canonical name of one plain-RGCSR configuration."""
    return get_format("rgcsr").encode_knobs({"group_size": group_size})


def rgcsr_dtans_config_name(group_size: int,
                            shared_table: bool = True) -> str:
    """Canonical name of one RGCSR-dtANS configuration."""
    return get_format("rgcsr_dtans").encode_knobs(
        {"group_size": group_size, "shared_table": shared_table})


def bcsr_config_name(block_shape: tuple) -> str:
    """Canonical name of one plain-BCSR configuration."""
    return get_format("bcsr").encode_knobs({"block_shape": block_shape})


#: Default chip model (TPU v5e), numerically identical to the constants
#: the benchmarks have always used.
V5E = MachineModel()


def spmm_bytes(fmt_bytes: int, n: int, m: int, vbytes: int,
               batch: int = 1, col_tiles: int = 1) -> int:
    """Bytes moved by one multi-RHS SpMM pass: the matrix (and for the
    entropy formats, its one decode) is paid ONCE, while the x and y
    vectors are paid per right-hand side — the amortization that lets a
    compressed format win at batch sizes where it loses at B=1.

    ``col_tiles > 1`` is the VMEM-capacity term: when the batch's x/y
    columns overflow `MachineModel.vmem_bytes`, the grid-blocked kernel
    (`repro.kernels.tiling`) splits the RHS into column tiles and
    re-reads the matrix stream once per tile, so the format bytes are
    charged ``col_tiles`` times while the x/y traffic is unchanged
    (each column still moves exactly once)."""
    return fmt_bytes * max(int(col_tiles), 1) + batch * (n + m) * vbytes


def spmv_bytes(fmt_bytes: int, n: int, m: int, vbytes: int) -> int:
    """Bytes moved by one SpMVM: matrix + x + y (paper Section III-A)."""
    return spmm_bytes(fmt_bytes, n, m, vbytes, 1)


def model_time(bytes_moved: int, nnz: int, *, warm: bool, decode: bool,
               machine: MachineModel = V5E) -> float:
    """Modeled seconds of one SpMVM pass (legacy two-term form).

    Kept verbatim for the paper-figure benchmarks (Figs. 7/8 compare a
    fixed CSR-dtANS against byte-count baselines under the paper's own
    model). The selector uses `candidate_time`, which also charges the
    per-format kernel work."""
    t = memory_time(bytes_moved, warm=warm, machine=machine)
    if decode:
        t += nnz * machine.decode_ops_per_nnz / machine.vpu_rate
    return t


def work_time(terms: CostTerms, machine: MachineModel = V5E,
              batch: int = 1, col_tiles: int = 1) -> float:
    """Seconds of kernel compute for one `FormatSpec.cost_terms` split.

    The contraction terms (``lockstep``/``rowseq``) scale with the
    number of right-hand sides; the ``decode`` term does not — the
    fused SpMM kernels decode each segment once and contract it against
    all B columns, so entropy-decode overhead amortizes with batch.
    The amortization is bounded by VMEM capacity: a pass split into
    ``col_tiles`` column tiles re-decodes the stream once per tile
    (`spmm_bytes` charges the matching byte term)."""
    ops = ((terms.lockstep + terms.rowseq * machine.row_seq_penalty)
           * machine.spmv_ops_per_elem * batch
           + terms.decode * machine.decode_ops_per_nnz
           * max(int(col_tiles), 1))
    return ops / machine.vpu_rate


def memory_time(bytes_moved: float, *, warm: bool,
                machine: MachineModel = V5E) -> float:
    """Two-level memory seconds for one pass over ``bytes_moved`` —
    the single home of the warm hit/miss split (`spmv_time`,
    `candidate_time` and `model_time`'s callers all price memory
    through this formula)."""
    hit = min(bytes_moved, machine.cache_bytes) if warm else 0.0
    return (bytes_moved - hit) / machine.hbm_bw + hit / machine.cache_bw


def spmv_time(nbytes: int, work_elems: float, ops_per_elem: float, *,
              rows: int, cols: int, vbytes: int, warm: bool,
              machine: MachineModel = V5E) -> float:
    """Modeled seconds of one SpMVM pass (selector model: memory time
    plus per-format kernel work, here as a flat work x ops/elem
    product; `candidate_time` is the `CostTerms`-split form)."""
    return (memory_time(spmv_bytes(nbytes, cols, rows, vbytes),
                        warm=warm, machine=machine)
            + work_elems * ops_per_elem / machine.vpu_rate)


def collective_time(n_shards: int, *, rows: int, cols: int, vbytes: int,
                    batch: int = 1,
                    machine: MachineModel = V5E) -> float:
    """Seconds of interconnect work for one sharded SpMM pass: the x
    broadcast (each device receives the full (cols, B) operand) and the
    y all-reduce (ring psum moves ``(k-1)/k`` of the (rows, B) result
    through each device), plus a log2(k) hop-latency floor per
    collective — the reason tiny matrices never want 16 chips no matter
    how fast their shards decode.  Zero at one shard (no collectives on
    the single-device path)."""
    k = int(n_shards)
    if k <= 1:
        return 0.0
    wire = (cols + rows) * batch * vbytes * (k - 1) / k
    return wire / machine.ici_bw + \
        2 * machine.collective_latency * math.ceil(math.log2(k))


def candidate_time(fp: Fingerprint, fmt: str, nbytes: int, *, warm: bool,
                   machine: MachineModel = V5E, batch: int = 1,
                   n_shards: int = 1, **knobs) -> float:
    """Modeled seconds of one (format, config) from fingerprint
    features: `memory_time` plus the `work_time` of the format's
    `CostTerms` — for a ``batch``-RHS SpMM pass (matrix bytes and
    decode work once, x/y bytes and contraction work per RHS).

    ``n_shards > 1`` prices the sharded path: the critical-path device
    holds ~1/k of the matrix bytes and does 1/k of the decode and
    contraction work (the row partition is balanced over decode
    slices), pays the full broadcast x against the cache, and the pass
    ends in the `collective_time` x-broadcast/y-reduce — the
    single-chip-vs-k-chips trade `search.select(mesh=)` arbitrates.

    The single formula shared by `candidates`, `search._refine`, the
    exhaustive oracle (`repro.autotune.oracle`) and calibration —
    selector and oracle cannot drift apart. Knobs the format does not
    declare are ignored, so callers may pass a candidate's full knob
    set."""
    from repro.kernels.tiling import n_col_tiles
    spec = get_format(fmt)
    terms = spec.cost_terms(fp, **spec.filter_knobs(knobs))
    k = max(int(n_shards), 1)
    if k > 1:
        nbytes = -(-int(nbytes) // k)
        terms = CostTerms(lockstep=terms.lockstep / k,
                          rowseq=terms.rowseq / k,
                          decode=terms.decode / k)
    # VMEM-capacity tile count of the grid-blocked kernel: how many
    # column tiles the batch's x/y working set forces, hence how many
    # times the matrix stream is re-read and re-decoded.
    tiles = n_col_tiles(fp.cols, 0, max(int(batch), 1), fp.value_bytes,
                        machine.vmem_bytes)
    return (memory_time(spmm_bytes(nbytes, fp.cols, fp.rows,
                                   fp.value_bytes, batch, tiles),
                        warm=warm, machine=machine)
            + work_time(terms, machine, batch, tiles)
            + collective_time(k, rows=fp.rows, cols=fp.cols,
                              vbytes=fp.value_bytes, batch=batch,
                              machine=machine))


@dataclasses.dataclass(frozen=True)
class Candidate(KnobbedConfigMixin):
    """One (format, config) point with its size and modeled runtime.

    ``knobs`` is the canonical ``((name, value), ...)`` tuple of the
    configuration — the registry's generic replacement for per-format
    fields; `lane_width` / `shared_table` / `group_size` /
    `block_shape` remain available via `KnobbedConfigMixin`.
    """

    fmt: str                      # a registered format family
    nbytes: int                   # format bytes (estimated or exact)
    modeled_time: float           # seconds per SpMVM pass
    exact_size: bool              # True when nbytes is not an estimate
    knobs: tuple = ()             # ((knob, value), ...), domain order
    # Devices the candidate is priced for (1 = single-chip path; > 1
    # adds the `collective_time` terms). Not part of the config name —
    # the same (format, knobs) point exists once per shard count.
    n_shards: int = 1
    # Median wall-clock seconds from `repro.autotune.measure`; filled
    # by the measured-refinement pass, None for modeled-only search.
    measured_time: float | None = None


def make_candidate(fp: Fingerprint, fmt: str, knobs: dict, nbytes: int,
                   exact: bool, *, warm: bool,
                   machine: MachineModel = V5E,
                   batch: int = 1, n_shards: int = 1) -> Candidate:
    """Price one (format, knobs, nbytes) point into a `Candidate`."""
    spec = get_format(fmt)
    kn = spec.normalize_knobs(knobs)
    return Candidate(
        fmt=fmt, nbytes=int(nbytes),
        modeled_time=candidate_time(fp, fmt, nbytes, warm=warm,
                                    machine=machine, batch=batch,
                                    n_shards=n_shards, **kn),
        exact_size=bool(exact),
        knobs=tuple((k, kn[k]) for k in spec.knob_domains),
        n_shards=int(n_shards))


def csr_nbytes(fp: Fingerprint) -> int:
    return get_format("csr").nbytes_exact(fp)


def coo_nbytes(fp: Fingerprint) -> int:
    return get_format("coo").nbytes_exact(fp)


def sell_nbytes(fp: Fingerprint, slice_height: int = 32) -> int:
    return get_format("sell").nbytes_exact(fp, slice_height=slice_height)


def rgcsr_nbytes(fp: Fingerprint, group_size: int) -> int:
    """`repro.sparse.rgcsr.RGCSR.nbytes` from the fingerprint's row-nnz
    RLE (mirrors `rgcsr_nbytes_exact`) — exact for *any* group size."""
    return get_format("rgcsr").nbytes_exact(fp, group_size=group_size)


def dtans_nbytes_estimate(fp: Fingerprint, *, lane_width: int = 128,
                          shared_table: bool = True,
                          params: DtansParams = PAPER) -> int:
    """Estimated `CSRdtANS.nbytes` from fingerprint features alone.

    Mirrors the exact accounting in `repro.core.csr_dtans.CSRdtANS`:
    tables + 4-byte stream words + escaped raw payloads + one 4-byte
    per-row length + per-slice offsets.

    The stream-word count uses the encoder's segment mechanics rather
    than raw entropy: every l-symbol segment emits ``o`` words minus the
    conditional-load extractions it earns, extraction happens only on
    non-final segments of a row (``encode_scalar`` branches only while
    ``j < nseg - 1``), and each extraction is a whole 32-bit word — so a
    segment carrying ``b`` information bits extracts
    ``clip(floor((o*32 - b) / 32), 0, f)`` words. Information bits per
    symbol come from the fingerprint's escape-aware table estimate.
    """
    vb = fp.value_bytes
    K = params.K
    T = 1 if shared_table else 2
    n_slices = -(-fp.rows // lane_width) if fp.rows else 0

    symbols = 2 * fp.nnz + fp.segment_pad_symbols
    if shared_table:
        real_bps = fp.merged_stream_bits
    else:
        real_bps = (fp.delta_stream_bits + fp.value_stream_bits) / 2.0
    # Tail padding uses the cheapest in-table symbol: log2(K/M) bits.
    pad_bps = params.k_bits - params.m_bits
    bps = ((2 * fp.nnz * real_bps + fp.segment_pad_symbols * pad_bps)
           / symbols) if symbols else 0.0

    seg_bits = params.l * bps
    extracts = min(max(math.floor((params.o * 32 - seg_bits) / 32.0), 0),
                   params.f)
    n_nonlast = fp.n_segments - fp.nonempty_rows
    stream_words = params.o * fp.n_segments - extracts * n_nonlast
    stream_bytes = 4 * stream_words

    esc_bytes = int(fp.delta_escape_frac * fp.nnz) * 4
    esc_bytes += int(fp.value_escape_frac * fp.nnz) * vb

    b = T * K * (vb + 8)                 # coding tables
    b += stream_bytes
    b += esc_bytes
    b += fp.rows * 4                     # per-row n
    b += (n_slices + 1) * 8              # stream offsets
    b += (n_slices + 1) * 4 * T          # escape offsets
    return int(b)


def rgcsr_dtans_nbytes_estimate(fp: Fingerprint, *, group_size: int = 32,
                                shared_table: bool = True,
                                params: DtansParams = PAPER) -> int:
    """Estimated `RGCSRdtANS.nbytes`: the CSR-dtANS estimate at interleave
    width G, with 4-byte per-row lengths replaced by group-local ones
    (16-bit unless some row reaches 2**16 nonzeros)."""
    base = dtans_nbytes_estimate(fp, lane_width=group_size,
                                 shared_table=shared_table, params=params)
    row_bytes = local_indptr_bytes(fp.row_nnz_max)
    return base - fp.rows * 4 + fp.rows * row_bytes


def bcsr_dtans_nbytes_estimate(fp: Fingerprint, *,
                               block_shape: tuple = (2, 2),
                               shared_table: bool = True,
                               params: DtansParams = PAPER) -> int:
    """Estimated `BCSRdtANS.nbytes` from fingerprint features alone.

    The encoded stream covers the *block-filled* matrix: ``F`` stored
    cells (`Fingerprint.block_nonempty` x r x c). Unlike the plain
    dtANS estimate's uniform bits/symbol, segments here come in two
    classes — ones carrying at least one original value (priced at the
    value domain's escape-aware bits; these rarely earn conditional-
    load extractions) and fill-only segments (runs of delta 1 and value
    0, near the cheapest-in-table floor of ``k_bits - m_bits``, which
    extract eagerly) — mixed by the probability a segment contains a
    real value. Exact-fill matrices (F == nnz) have no fill-only
    segments and reduce to the real-segment model. Still an estimate
    (within ~10-15% on the stress corpus): ``select(budget=k)``
    refinement and the oracle construct the truth. Metadata follows
    `BCSRdtANS.nbytes`: tables, per-block-row 16-bit block counts,
    per-block-row offsets.
    """
    r, c = block_shape
    vb = fp.value_bytes
    K = params.K
    T = 1 if shared_table else 2
    from repro.sparse.registry import block_count
    blocks, _ = block_count(fp, block_shape)
    F = blocks * r * c
    nbr = -(-fp.rows // r) if fp.rows else 0
    if F == 0:
        return T * K * (vb + 8) + nbr * 2 + (nbr + 1) * (8 + 4 * T)

    filled_rows = min(fp.rows, blocks * r)   # rows with >= 1 stored cell
    ell = params.l
    # Segment structure of the filled matrix: 2F symbols across
    # ~filled_rows rows, each row padded to a whole segment.
    n_segments = max(int(math.ceil(2 * F / ell)), filled_rows)

    fill_bps = params.k_bits - params.m_bits + 0.5
    # Real-value bits/symbol: the value domain's escape-aware estimate
    # (the fill symbols dilute the merged table, so the merged average
    # is a floor, not a price).
    vbits = max(fp.value_stream_bits, fp.merged_stream_bits)
    pairs_per_seg = ell / 2
    bits_real_seg = pairs_per_seg * (vbits + fill_bps)
    bits_fill_seg = ell * fill_bps
    # P(segment holds no original value) under a uniform fill mix.
    p_fill_only = (1.0 - fp.nnz / F) ** pairs_per_seg

    def extracts(seg_bits: float) -> int:
        return min(max(math.floor((params.o * 32 - seg_bits) / 32.0),
                       0), params.f)

    n_nonlast = max(n_segments - filled_rows, 0)
    extract_words = n_nonlast * (
        p_fill_only * extracts(bits_fill_seg)
        + (1.0 - p_fill_only) * extracts(bits_real_seg))
    stream_words = params.o * n_segments - int(extract_words)
    esc_bytes = int(fp.delta_escape_frac * fp.nnz) * 4
    esc_bytes += int(fp.value_escape_frac * fp.nnz) * vb

    b = T * K * (vb + 8)                 # coding tables
    b += 4 * stream_words
    b += esc_bytes
    b += nbr * 2                         # per-block-row block counts
    b += (nbr + 1) * 8                   # stream offsets
    b += (nbr + 1) * 4 * T               # escape offsets
    return int(b)


def merge_knob_overrides(knob_overrides: dict | None = None, *,
                         lane_widths: tuple | None = None,
                         group_sizes: tuple | None = None,
                         block_shapes: tuple | None = None) -> dict:
    """One canonical knob-override dict from the generic
    ``knob_overrides`` parameter plus the legacy named sugar
    (``lane_widths`` / ``group_sizes`` / ``block_shapes``, kept for
    compatibility; the named form wins when both spell the same knob).
    Shared by `candidates`, `search.select` and `oracle.oracle_times`
    so the three can never disagree about what a sweep override means.
    """
    out = {k: tuple(v) for k, v in (knob_overrides or {}).items()
           if v is not None}
    if lane_widths is not None:
        out["lane_width"] = tuple(lane_widths)
    if group_sizes is not None:
        out["group_size"] = tuple(group_sizes)
    if block_shapes is not None:
        out["block_shape"] = tuple(tuple(b) for b in block_shapes)
    return out


def render_knob_overrides(overrides: dict) -> str:
    """Deterministic cache-key spelling of one override dict
    (``"def"`` when empty — no overrides, the specs' own domains)."""
    if not overrides:
        return "def"

    def one(v) -> str:
        if isinstance(v, (tuple, list)):
            return "x".join(str(x) for x in v)
        return str(v)

    return ";".join(f"{k}=" + ",".join(one(v) for v in vs)
                    for k, vs in sorted(overrides.items()))


def candidates(fp: Fingerprint, *, machine: MachineModel = V5E,
               warm: bool = True, params: DtansParams = PAPER,
               formats: tuple = None,
               batch: int = 1,
               n_shards: int = 1,
               knob_overrides: dict | None = None,
               lane_widths: tuple = None,
               group_sizes: tuple = None,
               block_shapes: tuple = None) -> list[Candidate]:
    """Enumerate candidate formats, cheapest modeled time first.

    Iterates the `repro.sparse.registry` — a newly registered
    selectable format joins the sweep with no edit here. ``formats``
    defaults to every selectable registered family; ``batch`` prices a
    multi-RHS SpMM pass (decode and matrix bytes amortize over B);
    ``n_shards`` prices every point for a k-device sharded pass
    (`search.select(mesh=)` unions the sweep over shard counts);
    ``knob_overrides`` narrows/extends any knob domain by name (the
    named keywords remain as sugar for the three built-in knobs).
    """
    if formats is None:
        # Dynamic, not the module constant: formats registered after
        # import (e.g. in tests) must join the sweep.
        formats = format_names(selectable=True)
    overrides = merge_knob_overrides(knob_overrides,
                                     lane_widths=lane_widths,
                                     group_sizes=group_sizes,
                                     block_shapes=block_shapes)
    out: list[Candidate] = []
    for fmt in formats:
        spec = get_format(fmt)
        for knobs, nbytes, exact in spec.candidates(fp, overrides,
                                                    params=params):
            out.append(make_candidate(fp, fmt, knobs, nbytes, exact,
                                      warm=warm, machine=machine,
                                      batch=batch, n_shards=n_shards))
    out.sort(key=lambda cand: cand.modeled_time)
    return out
