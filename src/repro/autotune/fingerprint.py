"""Cheap sparsity-structure fingerprints for format selection.

A fingerprint is a small, hashable summary of a CSR matrix: shape/nnz
statistics, structural features (bandwidth, SELL padding), and
entropy-based compressibility estimates for the delta and value symbol
domains (paper Section IV-A: delta-encoding collapses structured column
indices onto a low-entropy distribution; Fig. 9 motivates picking a
format *per matrix* without AlphaSparse-scale tuning cost).

Everything here is O(nnz) or better, deterministic (strided subsampling,
no RNG), and orders of magnitude cheaper than actually encoding the
matrix — the point is that `autotune.select` can run per matrix at
serving time.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

from repro.core.delta import delta_encode_rows
from repro.core.entropy import entropy_bits
from repro.core.params import PAPER, DtansParams
from repro.sparse.rgcsr import (RGCSR_GROUP_SIZES,  # noqa: F401 (re-export)
                                max_group_nnz)

#: Max symbols per domain used for the entropy estimates. Strided (not
#: random) subsampling keeps fingerprints deterministic.
SAMPLE_CAP = 1 << 16

#: Slice height used for the exact SELL padding feature (matches
#: `repro.sparse.formats.SELL.from_csr`'s default).
SELL_SLICE_HEIGHT = 32


def lockstep_elems(row_nnz: np.ndarray, width: int) -> int:
    """Elements processed by a ``width``-row lock-step SpMV kernel.

    Each slice of ``width`` consecutive rows runs to its longest row, so
    the kernel touches ``width * max(row_nnz in slice)`` element slots —
    SELL's padded storage count, but as *compute* (formats like RGCSR and
    CSR-dtANS store compactly yet still decode in lock-step). Equals
    `SELL.from_csr(a, width).indices.size`.
    """
    row_nnz = np.asarray(row_nnz, dtype=np.int64)
    m = int(row_nnz.size)
    if m == 0:
        return 0
    nsl = (m + width - 1) // width
    padded = np.zeros(nsl * width, dtype=np.int64)
    padded[:m] = row_nnz
    return int(padded.reshape(nsl, width).max(axis=1).sum() * width)


# (max_group_nnz is defined in `repro.sparse.rgcsr` next to the format
# accounting it feeds, and re-exported here for the fingerprint API.)


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """Structure features of one sparse matrix (all deterministic)."""

    rows: int
    cols: int
    nnz: int
    value_bytes: int            # itemsize of the value dtype
    row_nnz_mean: float
    row_nnz_cv: float           # coefficient of variation (std/mean)
    row_nnz_max: int
    bandwidth: int              # max |col - row| over nonzeros
    sell_padded_nnz: int        # exact stored entries of SELL (slice 32)
    segment_pad_symbols: int    # per-row padding to l-symbol segments
    n_segments: int             # total l-symbol segments over all rows
    nonempty_rows: int
    delta_entropy_bits: float   # empirical H of sampled column deltas
    value_entropy_bits: float   # empirical H of sampled value bit patterns
    distinct_deltas: int        # within the sample
    distinct_values: int        # within the sample
    content_checksum: int       # cheap hash of sampled symbol content
    # Escape-aware achievable bits/symbol under a (K, M)-constrained dtANS
    # table (stream bits only; escape raw bits are accounted separately):
    delta_stream_bits: float
    value_stream_bits: float
    merged_stream_bits: float   # shared delta+value table (paper default)
    delta_escape_frac: float
    value_escape_frac: float
    # Run-length-encoded row-nnz sequence — the row-nnz histogram in
    # its exact, order-preserving form, packed as the raw bytes of an
    # int64 (2, n_runs) array ``[values; run_lengths]`` (bytes, not a
    # tuple-of-tuples: irregular matrices degenerate to one run per
    # row, and a 400k-row matrix must not pay seconds building Python
    # ints or JSON-serializing them into the cache key — `key` hashes
    # a digest of this blob instead). Every lock-step / group-size
    # feature derives from it for *arbitrary* widths (no optimistic
    # fallback), at O(rows) per width, memoized.
    row_nnz_rle: bytes = b""

    def _derived(self) -> dict:
        """Per-instance memo for O(rows) derived features (not a
        dataclass field: excluded from equality and `key`)."""
        cache = self.__dict__.get("_derived_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_derived_cache", cache)
        return cache

    def row_nnz(self) -> np.ndarray:
        """The exact row-nnz sequence, expanded from the RLE."""
        cache = self._derived()
        if "row_nnz" not in cache:
            if self.row_nnz_rle:
                vals, runs = np.frombuffer(self.row_nnz_rle,
                                           dtype=np.int64).reshape(2, -1)
                cache["row_nnz"] = np.repeat(vals, runs)
            else:
                cache["row_nnz"] = np.zeros(0, dtype=np.int64)
        return cache["row_nnz"]

    def lockstep(self, width: int) -> int:
        """Exact lock-step work elements for ``width``-row slices, any
        width (each slice of ``width`` consecutive rows runs to its
        longest row). A hand-built Fingerprint without the RLE degrades
        to the conservative ``nnz`` instead of a silent 0 (which would
        make every lock-step format look free)."""
        if not self.row_nnz_rle and self.nnz:
            return self.nnz
        cache = self._derived()
        key = ("lockstep", int(width))
        if key not in cache:
            cache[key] = lockstep_elems(self.row_nnz(), int(width))
        return cache[key]

    def group_max_nnz(self, group_size: int) -> int:
        """Exact largest group-total nnz for any group size (decides
        RGCSR's 16- vs 32-bit local indptr width); conservative ``nnz``
        for a hand-built Fingerprint without the RLE."""
        if not self.row_nnz_rle and self.nnz:
            return self.nnz
        cache = self._derived()
        key = ("group_max", int(group_size))
        if key not in cache:
            cache[key] = max_group_nnz(self.row_nnz(), int(group_size))
        return cache[key]

    def block_nonempty(self, block_shape: tuple) -> int | None:
        """Exact nonempty r x c block count for ANY block shape — the
        block-fill histogram behind the exact BCSR byte counts.

        Computed lazily from the CSR structure `fingerprint` stashes on
        the instance (an O(nnz log nnz) np.unique per shape is too
        expensive to pay eagerly for sweeps that never consider a
        blocked format) and memoized per shape. None only for
        hand-built Fingerprints without stashed structure (callers
        fall back to a conservative one-block-per-nonzero estimate)."""
        st = self.__dict__.get("_structure")
        if st is None:
            return None
        cache = self._derived()
        key = ("blocks", tuple(block_shape))
        if key not in cache:
            from repro.sparse.bcsr import count_nonempty_blocks
            indptr, indices, shape = st
            cache[key] = count_nonempty_blocks(indptr, indices, shape,
                                               tuple(block_shape))
        return cache[key]

    def key(self) -> str:
        """Stable content hash — the on-disk decision-cache key.

        The packed row-nnz RLE enters as a sha1 digest, not its (up to
        O(rows)) contents, so key() stays sub-millisecond on matrices
        with hundreds of thousands of irregular rows."""
        d = dataclasses.asdict(self)
        for k, v in d.items():
            if isinstance(v, float):
                d[k] = round(v, 6)
        d["row_nnz_rle"] = hashlib.sha1(self.row_nnz_rle).hexdigest()
        blob = json.dumps(d, sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()[:16]


def _pack_rle(row_nnz: np.ndarray) -> bytes:
    """Run-length-encode a row-nnz sequence into the packed int64
    ``[values; run_lengths]`` bytes of `Fingerprint.row_nnz_rle`
    (vectorized — no per-row Python objects)."""
    row_nnz = np.asarray(row_nnz, dtype=np.int64)
    if row_nnz.size == 0:
        return b""
    change = np.flatnonzero(np.diff(row_nnz)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [row_nnz.size]])
    return np.ascontiguousarray(
        np.vstack([row_nnz[starts], ends - starts])).tobytes()


def _sample(arr: np.ndarray, cap: int) -> np.ndarray:
    """Deterministic strided subsample of at most ``cap`` elements."""
    if arr.size <= cap:
        return arr
    idx = np.linspace(0, arr.size - 1, cap).astype(np.int64)
    return arr[idx]


def _value_bit_patterns(values: np.ndarray) -> np.ndarray:
    """Values -> uint64 bit patterns (the dtANS value-domain symbols)."""
    dt = values.dtype
    if dt == np.float64:
        return values.view(np.uint64)
    if dt == np.float32:
        return values.view(np.uint32).astype(np.uint64)
    # Fallback for integer matrices: the raw values are the symbols.
    return values.astype(np.uint64, casting="unsafe")


def codeable_bits(counts: np.ndarray, params: DtansParams = PAPER,
                  esc_raw_bits: int = 32) -> tuple[float, float]:
    """Estimate (stream bits/symbol, escape fraction) of a dtANS table.

    Vectorized approximation of `repro.core.tables.build_table`'s greedy
    allocation: the most frequent symbols get in-table multiplicities
    proportional to their counts (capped at M, at least 1); everything
    else escapes through a shared ESC symbol. A symbol also escapes when
    its in-table cost exceeds its escape cost (digit bits + raw bits) —
    the same eviction rule build_table applies.

    Returned stream bits *exclude* the ``esc_raw_bits`` raw payload of
    escaped symbols (those bytes live in the separate escape stream, as
    in `CSRdtANS.nbytes` accounting); the escape fraction lets the cost
    model charge them.
    """
    c = np.asarray(counts, dtype=np.float64)
    c = np.sort(c[c > 0])[::-1]
    total = c.sum()
    if total == 0:
        return 0.0, 0.0
    K, M = params.K, params.M
    n_in = min(c.size, K - 1)
    in_c, tail = c[:n_in], c[n_in:].sum()

    mult = np.clip(np.floor(K * in_c / total), 1, M)
    budget = K - (1 if (tail > 0 or c.size > n_in) else 0)
    if mult.sum() > budget:
        scale = budget / mult.sum()
        mult = np.maximum(1.0, np.floor(mult * scale))
    esc_mult = max(1.0, K - mult.sum())

    keep_bits = -np.log2(mult / K)
    esc_digit_bits = -np.log2(esc_mult / K)
    evict = keep_bits > esc_digit_bits + esc_raw_bits
    esc_count = tail + in_c[evict].sum()
    stream_bits = ((in_c[~evict] * keep_bits[~evict]).sum()
                   + esc_count * esc_digit_bits)
    return float(stream_bits / total), float(esc_count / total)


def fingerprint(a, params: DtansParams = PAPER,
                sample_cap: int = SAMPLE_CAP) -> Fingerprint:
    """Fingerprint a `repro.sparse.formats.CSR` matrix."""
    m, n = a.shape
    indptr = np.asarray(a.indptr, dtype=np.int64)
    indices = np.asarray(a.indices, dtype=np.int64)
    row_nnz = np.diff(indptr)
    nnz = int(row_nnz.sum())
    vb = int(a.values.dtype.itemsize)
    value_bits = vb * 8
    esc_raw_value = max(32, value_bits)

    if nnz == 0:
        fp0 = Fingerprint(
            rows=m, cols=n, nnz=0, value_bytes=vb, row_nnz_mean=0.0,
            row_nnz_cv=0.0, row_nnz_max=0, bandwidth=0, sell_padded_nnz=0,
            segment_pad_symbols=0, n_segments=0, nonempty_rows=0,
            delta_entropy_bits=0.0, value_entropy_bits=0.0,
            distinct_deltas=0, distinct_values=0, content_checksum=0,
            delta_stream_bits=0.0,
            value_stream_bits=0.0, merged_stream_bits=0.0,
            delta_escape_frac=0.0, value_escape_frac=0.0,
            row_nnz_rle=_pack_rle(np.zeros(m, dtype=np.int64)))
        object.__setattr__(fp0, "_structure", (indptr, indices, (m, n)))
        return fp0

    mean = float(row_nnz.mean())
    cv = float(row_nnz.std() / mean) if mean > 0 else 0.0

    row_of = np.repeat(np.arange(m, dtype=np.int64), row_nnz)
    bandwidth = int(np.abs(indices - row_of).max())

    # SELL's padding feature is `Fingerprint.lockstep` evaluated at
    # SELL_SLICE_HEIGHT; arbitrary widths derive exactly from the
    # row-nnz RLE below (no fallback).
    sell_padded = lockstep_elems(row_nnz, SELL_SLICE_HEIGHT)
    rle = _pack_rle(row_nnz)

    ell = params.l
    syms_per_row = 2 * row_nnz
    seg_pad = int((-syms_per_row % ell)[row_nnz > 0].sum())
    n_segments = int(((syms_per_row + ell - 1) // ell).sum())
    nonempty_rows = int((row_nnz > 0).sum())

    deltas = _sample(delta_encode_rows(indptr, indices).astype(np.uint64),
                     sample_cap)
    vbits = _sample(_value_bit_patterns(np.ascontiguousarray(a.values)),
                    sample_cap)
    _, dcounts = np.unique(deltas, return_counts=True)
    _, vcounts = np.unique(vbits, return_counts=True)
    # Distribution features alone cannot tell e.g. values {4,-1} from
    # {8,-2}; a content checksum keeps cache keys discriminating.
    mix = np.uint64(0x9E3779B97F4A7C15)
    checksum = int((deltas * mix + np.uint64(1)).sum()
                   ^ (vbits * mix + np.uint64(3)).sum())

    d_bits, d_esc = codeable_bits(dcounts, params, esc_raw_bits=32)
    v_bits, v_esc = codeable_bits(vcounts, params,
                                  esc_raw_bits=esc_raw_value)
    # Shared-table mode merges both domains into one distribution. The
    # sample halves are equal-weight, matching the 1:1 (delta, value)
    # interleave of `encode_matrix`.
    _, mcounts = np.unique(np.concatenate([deltas, vbits]),
                           return_counts=True)
    m_bits, _ = codeable_bits(mcounts, params, esc_raw_bits=esc_raw_value)

    fp = Fingerprint(
        rows=m, cols=n, nnz=nnz, value_bytes=vb,
        row_nnz_mean=mean, row_nnz_cv=cv, row_nnz_max=int(row_nnz.max()),
        bandwidth=bandwidth, sell_padded_nnz=sell_padded,
        segment_pad_symbols=seg_pad, n_segments=n_segments,
        nonempty_rows=nonempty_rows,
        delta_entropy_bits=entropy_bits(dcounts),
        value_entropy_bits=entropy_bits(vcounts),
        distinct_deltas=int(dcounts.size),
        distinct_values=int(vcounts.size),
        content_checksum=checksum,
        delta_stream_bits=d_bits, value_stream_bits=v_bits,
        merged_stream_bits=m_bits,
        delta_escape_frac=d_esc, value_escape_frac=v_esc,
        row_nnz_rle=rle,
    )
    # Stash the CSR structure for lazy derived features that are too
    # expensive to compute eagerly (`block_nonempty`). Not a field:
    # excluded from equality and `key` (it is pure input content, which
    # checksum + RLE + the other features already fingerprint).
    object.__setattr__(fp, "_structure", (indptr, indices, (m, n)))
    return fp
