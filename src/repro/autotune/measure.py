"""Wall-clock kernel measurement and MachineModel calibration.

The cost model's constants (`spmv_ops_per_elem`, `row_seq_penalty`,
bandwidth terms) started life as educated guesses; SMASH and AlphaSparse
both show format choice flips with the *machine*, not just the matrix.
This module closes the loop three ways:

* **Timing harness** — `spmv_runner` builds a zero-arg callable that
  runs one ``y = A x`` through the registered kernel path of any
  candidate (format, config); `time_kernel` times it with warmup,
  ``block_until_ready`` and a median-of-k repeat. Kernels run in Pallas
  interpret mode by default so the harness works on CPU CI hosts;
  on-accelerator callers pass ``interpret=False`` for compiled numbers.
* **Measured refinement** — `search.select(budget=k, measure=True)`
  calls `measure_candidate` on the top-k candidates so the final argmin
  ranks *measured* seconds, not modeled ones, and the measurement flows
  into ``Decision.measured_time`` and the persistent cache.
* **Calibration** — `calibrate` times a small synthetic sweep across
  the format families and least-squares-fits the MachineModel constants
  to the measurements. Fitted models persist as *named machine
  profiles* (`save_profile` / `load_profile`, JSON beside the decision
  cache); `MachineModel.signature()` carries the constants into every
  decision-cache key, so loading a different profile can never serve
  decisions tuned for another machine.

Measured seconds and modeled seconds are different currencies (interpret
mode on a CPU host is many orders of magnitude off the v5e roofline);
they are never compared across candidates — measurement re-ranks only
among measured candidates, and calibration exists precisely to bring the
model into the measured currency.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro import obs
from repro.autotune.cache import atomic_merge_json, default_cache_path
from repro.autotune.cost_model import (V5E, Candidate, MachineModel,
                                       candidate_time, spmm_bytes)
from repro.autotune.fingerprint import fingerprint
from repro.core.params import PAPER, DtansParams
from repro.sparse.registry import get_format, parse_config

#: Timing defaults: one warmup call (compilation / trace caching), then
#: a median over this many timed calls.
DEFAULT_WARMUP = 1
DEFAULT_REPEATS = 3

_PROFILE_ENV = "REPRO_MACHINE_PROFILES"


# --------------------------------------------------------------------------
# Timing harness
# --------------------------------------------------------------------------


#: rel-IQR (IQR / median) above which a timing is flagged noisy — the
#: threshold the calibration down-weighting and the
#: ``autotune.timing.noisy`` counter share.
NOISY_REL_IQR = obs.metrics.NOISY_REL_IQR


class TimingSample(float):
    """A median wall-clock time that also carries its dispersion.

    Subclasses ``float`` (the value IS the median), so every existing
    call site — candidate ranking, ``Decision.measured_time``, JSON
    serialization — keeps working on the scalar, while dispersion-aware
    consumers (calibration's down-weighting, the noisy-timing counter)
    read ``.iqr`` / ``.min`` / ``.n`` off the same object.
    """

    __slots__ = ("iqr", "min", "n")

    def __new__(cls, median: float, *, iqr: float = 0.0,
                min: float | None = None, n: int = 1) -> "TimingSample":
        self = float.__new__(cls, median)
        self.iqr = float(iqr)
        self.min = float(median if min is None else min)
        self.n = int(n)
        return self

    @classmethod
    def from_samples(cls, samples) -> "TimingSample":
        xs = np.asarray(samples, dtype=np.float64)
        if xs.size == 0:
            raise ValueError("need at least one timing sample")
        q25, med, q75 = np.percentile(xs, (25, 50, 75))
        return cls(float(med), iqr=float(q75 - q25),
                   min=float(xs.min()), n=int(xs.size))

    @property
    def median(self) -> float:
        return float(self)

    @property
    def rel_iqr(self) -> float:
        """IQR / median — scale-free dispersion; 0 for n == 1."""
        m = float(self)
        return self.iqr / m if m > 0 else 0.0

    @property
    def noisy(self) -> bool:
        """True when the spread across repeats rivals the median itself
        — a measurement calibration should not take at face value."""
        return self.rel_iqr > NOISY_REL_IQR


def time_kernel(fn, *, warmup: int = DEFAULT_WARMUP,
                repeats: int = DEFAULT_REPEATS) -> TimingSample:
    """Median wall-clock seconds of ``fn()`` (a device computation).

    ``fn`` returns a jax array (or pytree of them); every call is fenced
    with ``block_until_ready`` so dispatch-async time is not mistaken
    for kernel time. The first ``warmup`` calls absorb compilation and
    trace caching; the median of ``repeats`` timed calls resists
    scheduler noise better than the mean.

    Returns a `TimingSample` — a float (the median; existing call sites
    are unchanged) carrying ``iqr``, ``min`` and ``n``. Each call also
    records the dispersion in the default metrics registry
    (``autotune.timing.rel_iqr`` histogram; noisy timings bump
    ``autotune.timing.noisy``).
    """
    import jax
    if repeats < 1:
        raise ValueError("repeats must be >= 1")
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    ts = TimingSample.from_samples(samples)
    reg = obs.default_registry()
    reg.counter("autotune.timings").add(1)
    reg.histogram("autotune.timing.rel_iqr").observe(ts.rel_iqr)
    if ts.noisy:
        reg.counter("autotune.timing.noisy").add(1)
    return ts


def _default_x(a, batch: int = 1) -> np.ndarray:
    rng = np.random.default_rng(0xA0)
    shape = (a.shape[1],) if batch == 1 else (a.shape[1], batch)
    return rng.standard_normal(shape).astype(a.values.dtype)


def spmv_runner(a, fmt: str, *, params: DtansParams = PAPER,
                x: np.ndarray | None = None, batch: int = 1,
                interpret: bool = True,
                artifacts: dict | None = None, **knobs):
    """Zero-arg callable running one ``y = A x`` through the registered
    kernel path of (format, config); feed it to `time_kernel`.

    Registry-generic: ``**knobs`` is the format's own knob surface
    (``lane_width=32``, ``group_size=8``, ``block_shape=(4, 4)``, ...);
    None values and knobs the format does not declare are dropped, so a
    caller may pass a candidate's full knob set. `FormatSpec.pack`
    builds the runnable artifact (``artifacts`` memoizes expensive
    encodes under `FormatSpec.artifact_key`, shared with the exhaustive
    oracle — a benchmark that already ran the oracle times kernels
    without re-encoding) and `FormatSpec.runner` binds it to the
    format's ``spmv_fn`` (``ops.spmv`` for the dtANS families,
    ``ops.sell_spmv`` / ``ops.rgcsr_spmv`` / ``ops.bcsr_spmv`` for the
    plain kernels, the XLA scatter-add SpMV for the kernel-less
    row-sequential formats, and a jit'd dense ``A @ x`` — calibration's
    bandwidth anchor).

    ``batch > 1`` drives the format's multi-RHS path instead
    (`FormatSpec.spmm_runner` — the fused SpMM kernels where the format
    has one, a per-column fallback otherwise); ``x`` must then be
    (n, batch) when given.
    """
    try:
        spec = get_format(fmt)
    except ValueError as e:
        raise ValueError(f"no registered SpMV runner for format "
                         f"{fmt!r}") from e
    if batch < 1:
        raise ValueError(f"batch must be >= 1; got {batch}")
    x = _default_x(a, batch) if x is None else x
    if batch > 1:
        # Validate the rhs BEFORE pack: a shape mistake must not cost
        # a full entropy encode first.
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != batch:
            raise ValueError(f"batch={batch} needs x of shape "
                             f"({a.shape[1]}, {batch}); got {x.shape}")
    packed = spec.pack(a, params=params, artifacts=artifacts,
                       **spec.filter_knobs(knobs))
    if batch == 1:
        return spec.runner(packed, x, interpret=interpret)
    return spec.spmm_runner(packed, x, interpret=interpret)


def measure_config(a, fmt: str, *, params: DtansParams = PAPER,
                   x: np.ndarray | None = None, batch: int = 1,
                   interpret: bool = True,
                   warmup: int = DEFAULT_WARMUP,
                   repeats: int = DEFAULT_REPEATS,
                   artifacts: dict | None = None,
                   **knobs) -> TimingSample:
    """Measured median seconds of one (format, config) SpMV — or, with
    ``batch > 1``, one multi-RHS SpMM pass — on ``a`` (``**knobs`` as
    in `spmv_runner`). Returns `time_kernel`'s `TimingSample` (a float
    carrying dispersion)."""
    fn = spmv_runner(a, fmt, params=params, x=x, batch=batch,
                     interpret=interpret, artifacts=artifacts, **knobs)
    return time_kernel(fn, warmup=warmup, repeats=repeats)


def parse_config_name(name: str) -> dict:
    """Invert the canonical config names (`FormatSpec.encode_knobs`)
    into `measure_config` keyword arguments via the registry's
    `decode_knobs` — e.g. ``"rgcsr_dtans[G=8,shared]"`` ->
    ``{"fmt": "rgcsr_dtans", "group_size": 8, "shared_table": True}``.
    Raises ValueError for unregistered formats or unknown components.
    """
    spec, knobs = parse_config(name)
    return {"fmt": spec.name, **knobs}


def measure_named(a, config_name: str, *, params: DtansParams = PAPER,
                  x: np.ndarray | None = None, batch: int = 1,
                  interpret: bool = True,
                  warmup: int = DEFAULT_WARMUP,
                  repeats: int = DEFAULT_REPEATS,
                  artifacts: dict | None = None) -> TimingSample:
    """`measure_config` addressed by canonical config name — how the
    benchmarks time the exhaustive oracle's pick."""
    return measure_config(a, **parse_config_name(config_name),
                          params=params, x=x, batch=batch,
                          interpret=interpret,
                          warmup=warmup, repeats=repeats,
                          artifacts=artifacts)


def measure_candidate(a, cand: Candidate, *, params: DtansParams = PAPER,
                      x: np.ndarray | None = None, batch: int = 1,
                      interpret: bool = True,
                      warmup: int = DEFAULT_WARMUP,
                      repeats: int = DEFAULT_REPEATS,
                      artifacts: dict | None = None) -> TimingSample:
    """`measure_config` keyed off a cost-model `Candidate` (the
    candidate's knobs tuple carries the full configuration)."""
    return measure_config(a, cand.fmt, params=params, x=x, batch=batch,
                          interpret=interpret, warmup=warmup,
                          repeats=repeats, artifacts=artifacts,
                          **cand.knobs_dict())


# --------------------------------------------------------------------------
# Calibration: fit MachineModel constants to measured kernel times
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CalibrationPoint:
    """One (matrix, config, batch) measurement with its model features."""

    matrix: str
    config_name: str
    fmt: str
    nbytes: int
    work_elems: int
    measured: float          # seconds
    modeled_before: float    # seconds under the base (hand-tuned) model
    modeled_after: float = float("nan")   # filled in after the fit
    batch: int = 1           # right-hand sides of the measured pass
    # Dispersion of the measurement (`TimingSample`): IQR across the
    # timed repeats and the weight the fit gave this row (noisy rows
    # are down-weighted, never discarded).
    measured_iqr: float = 0.0
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    model: MachineModel
    err_before: float        # mean |modeled - measured| / measured
    err_after: float
    points: tuple            # CalibrationPoint per measurement

    def to_dict(self) -> dict:
        return {
            "model": self.model.to_dict(),
            "err_before": self.err_before,
            "err_after": self.err_after,
            "points": [dataclasses.asdict(p) for p in self.points],
        }


def _calibration_suite(small: bool = True) -> dict:
    """Small deterministic sweep spanning the structure axes the model's
    work terms distinguish: regular (banded/stencil), irregular (ER),
    skewed row lengths (the lock-step penalty case) and a low-entropy
    quantized NN weight (the decode-term case)."""
    from repro.sparse.formats import CSR
    from repro.sparse.prune import codebook_quantize, magnitude_prune
    from repro.sparse.random_graphs import banded, erdos_renyi, stencil_2d
    f = 1 if small else 2
    rng = np.random.default_rng(21)
    w = (rng.standard_normal((256 * f, 256 * f)) / 16).astype(np.float32)
    out = {
        "banded": banded(1500 * f, 5),
        "stencil": stencil_2d(28 * f),
        "er": erdos_renyi(900 * f, 8, rng),
        "nn": codebook_quantize(magnitude_prune(w, 0.85), bits=6),
    }
    skew = np.zeros((400 * f, 300 * f), dtype=np.float64)
    lens = np.minimum(rng.zipf(1.7, size=skew.shape[0]), skew.shape[1])
    for i, k in enumerate(lens):
        cols = rng.choice(skew.shape[1], size=int(k), replace=False)
        skew[i, cols] = np.round(rng.standard_normal(int(k))) + 0.5
    out["skew"] = CSR.from_dense(skew)
    return {k: CSR(v.indptr, v.indices, v.values.astype(np.float32),
                   v.shape) if v.values.dtype != np.float32 else v
            for k, v in out.items()}


#: Canonical config names measured per sweep matrix — one
#: representative per work-term family. Parsed through the registry, so
#: every knob a row depends on (the SELL slice height included) comes
#: from the config itself, never a hard-coded constant that could drift
#: from what the runner actually packed.
CALIBRATION_CONFIGS = (
    "csr",
    "sell",
    "rgcsr[G=8]",
    "dtans[w=32,shared]",
    "rgcsr_dtans[G=8,shared]",
)


def _clamped_lstsq(A: np.ndarray, t: np.ndarray,
                   fallback: np.ndarray) -> np.ndarray:
    """Least squares with non-negativity by clamp-and-refit: columns
    whose coefficient comes out non-positive are pinned to their
    ``fallback`` (base-model) value and the rest re-fit on the residual.
    Five columns, so the loop is at most five rounds."""
    beta = np.array(fallback, dtype=np.float64)
    free = np.ones(A.shape[1], dtype=bool)
    for _ in range(A.shape[1]):
        if not free.any():
            break
        resid = t - A[:, ~free] @ beta[~free]
        sol, *_ = np.linalg.lstsq(A[:, free], resid, rcond=None)
        bad = sol <= 0
        beta[free] = np.where(bad, fallback[free], sol)
        if not bad.any():
            break
        idx = np.flatnonzero(free)
        free[idx[bad]] = False
    return beta


#: Batched design rows: each calibration config is measured once per
#: batch size, so the fit sees rows where contraction work scales with
#: B while decode work does not — exactly the split the batched cost
#: model prices. B=1 keeps the classic SpMV rows; B=8 is large enough
#: to separate the per-RHS terms without slowing CI measurably.
CALIBRATION_BATCHES = (1, 8)


def calibrate(matrices: dict | None = None, *, base: MachineModel = V5E,
              name: str | None = None, warm: bool = True,
              configs: tuple = CALIBRATION_CONFIGS,
              batches: tuple = CALIBRATION_BATCHES,
              params: DtansParams = PAPER, interpret: bool = True,
              warmup: int = DEFAULT_WARMUP,
              repeats: int = DEFAULT_REPEATS,
              small: bool = True) -> CalibrationResult:
    """Fit MachineModel constants from a measured microbench sweep.

    Each (matrix, config, batch) measurement contributes one row of a
    linear system

        t = miss_bytes/hbm_bw + hit_bytes/cache_bw
            + (B * lockstep_work * c_ls + B * rowseq_work * c_rs
               + decode_work * c_dec)

    whose five coefficients map back to ``hbm_bw``, ``cache_bw``,
    ``spmv_ops_per_elem``, ``row_seq_penalty`` and
    ``decode_ops_per_nnz`` (``vpu_rate`` and ``cache_bytes`` stay at the
    base model's datasheet values — they are not separately identifiable
    from end-to-end times). Rows are weighted by their measurement's
    dispersion (`TimingSample`: weight = 1 / (1 + IQR/median)), so a
    noisy timing informs the fit less than a clean one; per-row IQR and
    weight land in the `CalibrationPoint`. Coefficients the data cannot pin down
    positively fall back to the base model's value. The ``batches``
    sweep (default ``(1, 8)``) measures every config through both the
    single-vector and the fused multi-RHS kernel path, giving the fit
    rows where the contraction terms scale but the decode term does not.

    Returns a `CalibrationResult`; ``result.model`` is ready for
    ``select(machine=...)`` and `save_profile`.
    """
    mats = _calibration_suite(small=small) if matrices is None else matrices
    points: list[CalibrationPoint] = []
    feats: list[list[float]] = []
    meas: list[float] = []
    weights: list[float] = []

    for mname, a in mats.items():
        fp = fingerprint(a, params=params)
        enc: dict = {}
        for cfg_name in configs:
            spec, knobs = parse_config(cfg_name)
            nbytes = spec.nbytes_constructed(a, params=params,
                                             artifacts=enc, **knobs)
            # The design-matrix row IS the spec's cost-term split — the
            # same knobs the runner packed with (the SELL slice height
            # comes from the config, not a module constant).
            terms = spec.cost_terms(fp, **knobs)
            for B in batches:
                t_meas = measure_config(
                    a, spec.name, params=params, batch=B,
                    interpret=interpret, warmup=warmup,
                    repeats=repeats, artifacts=enc, **knobs)
                moved = spmm_bytes(nbytes, fp.cols, fp.rows,
                                   fp.value_bytes, B)
                hit = min(moved, base.cache_bytes) if warm else 0.0
                feats.append([
                    moved - hit,          # 1/hbm_bw
                    hit,                  # 1/cache_bw
                    terms.lockstep * B,   # c_ls
                    terms.rowseq * B,     # c_rs
                    terms.decode,         # c_dec (once per pass)
                ])
                meas.append(t_meas)
                # Down-weight noisy measurements (`TimingSample`
                # dispersion): a row whose repeats disagree by its own
                # median should not pull the fit as hard as a clean one.
                rel = t_meas.rel_iqr if isinstance(t_meas, TimingSample) \
                    else 0.0
                weights.append(1.0 / (1.0 + rel))
                t_before = candidate_time(fp, spec.name, nbytes,
                                          warm=warm, machine=base,
                                          batch=B, **knobs)
                points.append(CalibrationPoint(
                    matrix=mname, config_name=spec.encode_knobs(knobs),
                    fmt=spec.name, nbytes=int(nbytes),
                    work_elems=int(terms.work_elems), measured=t_meas,
                    modeled_before=t_before, batch=int(B),
                    measured_iqr=float(getattr(t_meas, "iqr", 0.0)),
                    weight=weights[-1]))

    A = np.asarray(feats, dtype=np.float64)
    t = np.asarray(meas, dtype=np.float64)
    w = np.asarray(weights, dtype=np.float64)
    fallback = np.array([
        1.0 / base.hbm_bw,
        1.0 / base.cache_bw,
        base.spmv_ops_per_elem / base.vpu_rate,
        base.spmv_ops_per_elem * base.row_seq_penalty / base.vpu_rate,
        base.decode_ops_per_nnz / base.vpu_rate,
    ])
    # Weighted least squares by row scaling: minimizing
    # sum_i w_i (A_i beta - t_i)^2 is the plain lstsq of (sqrt(w) A,
    # sqrt(w) t). Predictions / errors below use the UNWEIGHTED rows.
    sw = np.sqrt(w)[:, None]
    beta = _clamped_lstsq(A * sw, t * sw[:, 0], fallback)

    hbm_bw = 1.0 / beta[0]
    cache_bw = max(1.0 / beta[1], hbm_bw)   # cache never slower than HBM
    ops_per_elem = beta[2] * base.vpu_rate
    fitted = MachineModel(
        name=name or f"{base.name}-calibrated",
        hbm_bw=hbm_bw, cache_bw=cache_bw, cache_bytes=base.cache_bytes,
        vpu_rate=base.vpu_rate,
        decode_ops_per_nnz=beta[4] * base.vpu_rate,
        spmv_ops_per_elem=ops_per_elem,
        row_seq_penalty=max(beta[3] / beta[2], 1.0),
    )

    pred_after = A @ beta
    done = []
    err_b, err_a = [], []
    for p, t_after in zip(points, pred_after):
        done.append(dataclasses.replace(p, modeled_after=float(t_after)))
        err_b.append(abs(p.modeled_before - p.measured) / p.measured)
        err_a.append(abs(t_after - p.measured) / p.measured)
    return CalibrationResult(model=fitted,
                             err_before=float(np.mean(err_b)),
                             err_after=float(np.mean(err_a)),
                             points=tuple(done))


# --------------------------------------------------------------------------
# Named machine profiles (JSON beside the decision cache)
# --------------------------------------------------------------------------


def default_profiles_path() -> str:
    """``$REPRO_MACHINE_PROFILES`` if set, else ``machine_profiles.json``
    next to the decision cache."""
    env = os.environ.get(_PROFILE_ENV)
    if env:
        return env
    return os.path.join(os.path.dirname(default_cache_path()),
                        "machine_profiles.json")


def save_profile(model: MachineModel, *, meta: dict | None = None,
                 path: str | os.PathLike | None = None) -> str:
    """Persist ``model`` under its name; returns the profile file path.

    Concurrent savers merge (read + update + atomic rename, same
    discipline as the decision cache); saving raises on an unwritable
    path — losing a profile silently would quietly serve decisions
    tuned for the wrong constants.
    """
    p = os.fspath(path) if path is not None else default_profiles_path()
    entry = {"model": model.to_dict(), "meta": dict(meta or {}),
             "signature": model.signature()}
    atomic_merge_json(p, {model.name: entry}, strict=True)
    return p


def load_profile(name: str, *,
                 path: str | os.PathLike | None = None) -> MachineModel:
    """Load a named profile; raises KeyError when absent."""
    p = os.fspath(path) if path is not None else default_profiles_path()
    try:
        with open(p) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        raise KeyError(f"no machine profiles at {p}: {e}") from e
    if name not in data:
        raise KeyError(f"no machine profile {name!r} in {p} "
                       f"(have: {sorted(data)})")
    return MachineModel.from_dict(data[name]["model"])


def list_profiles(path: str | os.PathLike | None = None) -> dict:
    """name -> profile entry (empty when the file is absent/corrupt)."""
    p = os.fspath(path) if path is not None else default_profiles_path()
    try:
        with open(p) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, ValueError):
        return {}
