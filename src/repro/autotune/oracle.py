"""Exhaustive exact-size oracle — the AlphaSparse stand-in.

Constructs/encodes EVERY candidate configuration of every selectable
format registered in `repro.sparse.registry` for a matrix and evaluates
the same `cost_model.candidate_time` the selector uses, but with
byte-exact sizes everywhere (the selector works from fingerprint
estimates for the entropy-coded families). The argmin is the paper-
Fig. 9 "best format per matrix" that AlphaSparse pays hours of tuning
for; `select()`'s regret is measured against it.

This is the single oracle shared by benchmarks/bench_format_selection.py
and tests/test_autotune.py — selector and oracle iterate one registry
and evaluate one formula, so a cost-model or registry edit can never
make them disagree by accident, only by genuinely changing a modeled
argmin (which the decision-snapshot test then surfaces). A format
registered through the registry joins the oracle with no edit here.
"""

from __future__ import annotations

from repro.autotune.cost_model import (V5E, MachineModel, candidate_time,
                                       merge_knob_overrides)
from repro.autotune.fingerprint import fingerprint
from repro.core.params import PAPER, DtansParams
from repro.sparse.registry import format_names, get_format


def oracle_times(a, *, warm: bool = True, machine: MachineModel = V5E,
                 params: DtansParams = PAPER,
                 formats: tuple | None = None,
                 batch: int = 1,
                 n_shards: int | tuple = 1,
                 knob_overrides: dict | None = None,
                 lane_widths: tuple | None = None,
                 group_sizes: tuple | None = None,
                 block_shapes: tuple | None = None,
                 encode_cache: dict | None = None) -> dict[str, float]:
    """config_name -> exact-size modeled seconds, for every candidate.

    ``batch`` prices a multi-RHS SpMM pass exactly as `select(batch=)`
    does (same `candidate_time`), so selector-vs-oracle regret is
    meaningful at every batch size. ``n_shards`` — an int or a tuple of
    counts — additionally prices each configuration for k-device
    sharded passes, keyed ``"<config>@S<k>"`` for k > 1 (the bare
    config name stays the single-chip entry, matching
    `select(mesh=)`'s leaderboard spelling). ``knob_overrides`` narrows
    any knob domain by name, third-party specs included; the three
    named keywords remain as sugar, exactly as in `select`.

    ``encode_cache`` (any mutable mapping) memoizes the expensive dtANS
    encodes across repeated calls (e.g. warm and cold evaluation of the
    same matrix) under `FormatSpec.artifact_key` —
    `repro.autotune.measure.spmv_runner` and
    `search.select(artifacts=...)` share the same convention, so a
    measurement pass after an oracle run never re-encodes. (Legacy
    caches holding bare byte counts are transparently re-encoded.)
    """
    fp = fingerprint(a, params=params)
    enc = encode_cache if encode_cache is not None else {}
    overrides = merge_knob_overrides(knob_overrides,
                                     lane_widths=lane_widths,
                                     group_sizes=group_sizes,
                                     block_shapes=block_shapes)
    if formats is None:
        formats = format_names(selectable=True)
    ks = ((int(n_shards),) if isinstance(n_shards, int)
          else tuple(int(k) for k in n_shards))
    times: dict[str, float] = {}
    for fmt in formats:
        spec = get_format(fmt)
        for knobs in spec.knob_grid(fp, overrides):
            b = spec.nbytes_constructed(a, params=params, artifacts=enc,
                                        **knobs)
            name = spec.encode_knobs(knobs)
            for k in ks:
                key = name if k == 1 else f"{name}@S{k}"
                times[key] = candidate_time(
                    fp, fmt, b, warm=warm, machine=machine, batch=batch,
                    n_shards=k, **knobs)
    return times


def oracle_best(a, **kwargs) -> tuple[str, float, dict[str, float]]:
    """(best config_name, its modeled time, all times) for matrix ``a``."""
    times = oracle_times(a, **kwargs)
    if not times:
        raise ValueError(
            "no admitted candidate configuration for the requested "
            "formats on this matrix (matrix-adaptive knob grids pruned "
            "every sweep point)")
    best = min(times, key=times.get)
    return best, times[best], times
