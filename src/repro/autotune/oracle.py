"""Exhaustive exact-size oracle — the AlphaSparse stand-in.

Constructs/encodes EVERY candidate configuration of every format family
for a matrix and evaluates the same `cost_model.spmv_time` the selector
uses, but with byte-exact sizes everywhere (the selector works from
fingerprint estimates for the entropy-coded families). The argmin is the
paper-Fig. 9 "best format per matrix" that AlphaSparse pays hours of
tuning for; `select()`'s regret is measured against it.

This is the single oracle shared by benchmarks/bench_format_selection.py
and tests/test_autotune.py — selector and oracle evaluate one formula
(`cost_model.candidate_time`), so a cost-model edit can never make them
disagree by accident, only by genuinely changing a modeled argmin (which
the decision-snapshot test then surfaces).
"""

from __future__ import annotations

from repro.autotune.cost_model import (DTANS_LANE_WIDTHS,
                                       DTANS_SHARED_TABLE, V5E,
                                       MachineModel, candidate_time,
                                       dtans_config_name,
                                       rgcsr_config_name,
                                       rgcsr_dtans_config_name)
from repro.autotune.fingerprint import fingerprint
from repro.core.params import PAPER, DtansParams
from repro.sparse.formats import COO, SELL
from repro.sparse.rgcsr import RGCSR_GROUP_SIZES, rgcsr_nbytes_exact


def oracle_times(a, *, warm: bool = True, machine: MachineModel = V5E,
                 params: DtansParams = PAPER,
                 lane_widths: tuple = DTANS_LANE_WIDTHS,
                 group_sizes: tuple = RGCSR_GROUP_SIZES,
                 encode_cache: dict | None = None) -> dict[str, float]:
    """config_name -> exact-size modeled seconds, for every candidate.

    ``encode_cache`` (any mutable mapping) memoizes the expensive dtANS
    encodes across repeated calls (e.g. warm and cold evaluation of the
    same matrix); keys are (family, width/G, shared), values the encoded
    matrices themselves — `repro.autotune.measure.spmv_runner` and
    `search.select(artifacts=...)` share the same convention, so a
    measurement pass after an oracle run never re-encodes. (Legacy
    caches holding bare byte counts are transparently re-encoded.)
    """
    from repro.core.csr_dtans import encode_matrix
    from repro.core.rgcsr_dtans import encode_rgcsr_matrix

    fp = fingerprint(a, params=params)
    enc = encode_cache if encode_cache is not None else {}
    times: dict[str, float] = {}

    def t(fmt, nbytes, lane_width=None, group_size=None):
        return candidate_time(fp, fmt, nbytes, warm=warm, machine=machine,
                              lane_width=lane_width, group_size=group_size)

    times["csr"] = t("csr", a.nbytes)
    times["coo"] = t("coo", COO.from_csr(a).nbytes)
    times["sell"] = t("sell", SELL.from_csr(a).nbytes)
    rnnz = a.row_nnz()
    vb = a.values.dtype.itemsize
    for g in group_sizes:
        times[rgcsr_config_name(g)] = t(
            "rgcsr", rgcsr_nbytes_exact(rnnz, g, vb), group_size=g)
    for w in lane_widths:
        for shared in DTANS_SHARED_TABLE:
            key = ("dtans", w, shared)
            mat = enc.get(key)
            if not hasattr(mat, "nbytes"):   # miss or legacy int entry
                mat = encode_matrix(a, params=params, lane_width=w,
                                    shared_table=shared)
                enc[key] = mat
            times[dtans_config_name(w, shared)] = t(
                "dtans", mat.nbytes, lane_width=w)
    for g in group_sizes:
        key = ("rgcsr_dtans", g, True)
        mat = enc.get(key)
        if not hasattr(mat, "nbytes"):
            mat = encode_rgcsr_matrix(a, group_size=g, params=params,
                                      shared_table=True)
            enc[key] = mat
        times[rgcsr_dtans_config_name(g, True)] = t(
            "rgcsr_dtans", mat.nbytes, group_size=g)
    return times


def oracle_best(a, **kwargs) -> tuple[str, float, dict[str, float]]:
    """(best config_name, its modeled time, all times) for matrix ``a``."""
    times = oracle_times(a, **kwargs)
    best = min(times, key=times.get)
    return best, times[best], times
