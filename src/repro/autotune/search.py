"""Candidate search: the `select()` entry point of the autotuner.

``select(csr)`` fingerprints the matrix, enumerates every selectable
format registered in `repro.sparse.registry` under the machine cost
model, optionally *refines* the top candidates by actually constructing
them (exact bytes instead of entropy estimates), and returns the
modeled-argmin `Decision`. Two cache layers make repeat calls cheap:

  * a per-process identity memo — a warm ``select`` on the same CSR
    object is a dict lookup (~1 us; below 1% of one modeled SpMVM pass
    for serving-scale matrices with >= ~100 MB working sets, and 5-6
    orders of magnitude below re-running the search — on tiny matrices
    the modeled pass itself is tens of ns, so amortize there);
  * the persistent `DecisionCache` keyed by fingerprint hash + machine
    constants + knobs — a new process serving the same matrix skips the
    search (paper Fig. 9's per-matrix tuning at microseconds, not
    AlphaSparse-hours).

The ``budget`` knob bounds the expensive part: 0 = estimates only
(default, pure fingerprint arithmetic), k > 0 = encode/construct the k
best candidates for exact sizes before the final argmin. Adding
``measure=True`` upgrades that refinement pass from exact *sizes* to
exact *times*: the top-k candidates are packed and their real kernels
wall-clock timed (`repro.autotune.measure`), the argmin ranks measured
seconds, and the winning measurement lands in ``Decision.measured_time``
next to its ``modeled_time``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import weakref

from repro import obs
from repro.autotune.cache import DecisionCache, default_cache
from repro.autotune.cost_model import (V5E, Candidate, MachineModel,
                                       candidate_time, candidates,
                                       merge_knob_overrides,
                                       render_knob_overrides)
from repro.autotune.fingerprint import Fingerprint, fingerprint
from repro.core.params import PAPER, DtansParams
from repro.sparse.registry import (KnobbedConfigMixin, format_names,
                                   get_format)

#: Selectable format families at import time (the function defaults use
#: the live registry, so formats registered later still join).
ALL_FORMATS = format_names(selectable=True)


def _knobs_from_json(v) -> tuple:
    """JSON lists -> the canonical knobs tuple (block shapes become
    tuples again)."""
    return tuple((k, tuple(x) if isinstance(x, list) else x)
                 for k, x in v)


@dataclasses.dataclass(frozen=True)
class Decision(KnobbedConfigMixin):
    """Outcome of one format selection (JSON round-trippable).

    ``knobs`` is the canonical ``((name, value), ...)`` configuration
    tuple of the winning format — the registry's generic replacement
    for per-format fields; `lane_width` / `shared_table` /
    `group_size` / `block_shape` come from `KnobbedConfigMixin`.
    """

    fmt: str
    knobs: tuple
    nbytes: int
    modeled_time: float
    exact_size: bool
    warm: bool
    machine: str
    fingerprint_key: str
    refined: bool
    # Number of right-hand sides the selection was priced for (the
    # SpMM batch; 1 = the classic single-vector SpMV regime).
    batch: int = 1
    # Devices the winning plan runs on (1 = single-chip; > 1 = the
    # row-sharded shard_map path priced with `collective_time`).
    # `select(mesh=)` sweeps shard counts and this is its answer to
    # "does this matrix want 1, 4, or 16 chips?".
    n_shards: int = 1
    # Median wall-clock seconds of the winner's real kernel when the
    # selection ran with ``measure=True``; None for modeled-only runs.
    # Modeled and measured seconds are different currencies (interpret
    # mode vs the machine model) — compare measured against measured.
    measured_time: float | None = None
    # (config_name, nbytes, modeled_time, measured_time | None) of the
    # best few candidates, best first — kept for regret reporting and
    # debugging.
    leaderboard: tuple = ()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["knobs"] = [list(kv) for kv in self.knobs]
        d["leaderboard"] = [list(row) for row in self.leaderboard]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Decision":
        """Raises ValueError on schema drift (old/foreign cache files);
        `select` treats that as a cache miss and recomputes. Fields with
        defaults (``measured_time``, ``leaderboard``) may be absent — a
        cache written before a field existed stays valid. ``knobs`` is
        required: pre-registry caches carrying per-format fields fail
        here and recompute."""
        fields = {f.name for f in dataclasses.fields(cls)}
        required = {f.name for f in dataclasses.fields(cls)
                    if f.default is dataclasses.MISSING
                    and f.default_factory is dataclasses.MISSING}
        if not required <= set(d):
            raise ValueError(f"missing decision fields: "
                             f"{sorted(required - set(d))}")
        d = {k: v for k, v in d.items() if k in fields}
        d["knobs"] = _knobs_from_json(d["knobs"])
        d["leaderboard"] = tuple(tuple(row) for row in
                                 d.get("leaderboard", ()))
        try:
            return cls(**d)
        except TypeError as e:
            raise ValueError(f"bad cached decision: {e}") from e


def _decision_event(dec: "Decision", *, source: str) -> None:
    """One selection outcome into the obs layer: a counter per source
    (``search`` = computed fresh, ``cache`` = served from the
    persistent decision cache) and — when a trace sink is configured —
    an ``autotune.decision`` event carrying the pick with its
    modeled-vs-measured time, so selector behaviour is inspectable from
    a serving trace, not just benchmark regret tables."""
    obs.default_registry().counter(
        f"autotune.decisions.{source}").add(1)
    obs.event("autotune.decision", source=source, fmt=dec.fmt,
              config=dec.config_name, nbytes=dec.nbytes,
              batch=dec.batch, warm=dec.warm, machine=dec.machine,
              modeled_time=dec.modeled_time,
              measured_time=(None if dec.measured_time is None
                             else float(dec.measured_time)))


#: id(matrix) -> (weakref-to-matrix, config key, Decision). The weakref
#: guards against id() reuse after garbage collection.
_memo: dict = {}


def clear_memo() -> None:
    _memo.clear()


def _refine(a, cand: Candidate, fp: Fingerprint, *, warm: bool,
            machine: MachineModel, params: DtansParams,
            artifacts: dict, batch: int = 1) -> Candidate:
    """Replace an estimated candidate size with the constructed truth.

    Registry-generic: `FormatSpec.nbytes_constructed` builds/encodes
    the configuration; ``artifacts`` memoizes expensive artifacts under
    `FormatSpec.artifact_key`, shared with the oracle and the
    measurement pass so nothing re-encodes."""
    if cand.exact_size:
        return cand
    spec = get_format(cand.fmt)
    kn = cand.knobs_dict()
    b = spec.nbytes_constructed(a, params=params, artifacts=artifacts,
                                **kn)
    t = candidate_time(fp, cand.fmt, b, warm=warm, machine=machine,
                       batch=batch, n_shards=cand.n_shards, **kn)
    return dataclasses.replace(cand, nbytes=int(b), modeled_time=t,
                               exact_size=True)


def shard_counts(mesh=None, n_shards=None) -> tuple:
    """Shard counts one selection sweeps: an explicit ``n_shards`` pins
    a single count, a mesh sweeps the powers of two up to its ``model``
    axis (1, 2, 4, ... — the counts a mesh can actually host), and
    neither means the classic single-chip search ``(1,)``."""
    if n_shards is not None:
        if int(n_shards) < 1:
            raise ValueError(f"n_shards must be >= 1; got {n_shards}")
        return (int(n_shards),)
    if mesh is not None:
        from repro.launch.mesh import model_axis_size
        msize = model_axis_size(mesh)
        ks, k = [], 1
        while k <= msize:
            ks.append(k)
            k *= 2
        return tuple(ks)
    return (1,)


def select(a, *, machine: MachineModel = V5E, warm: bool = True,
           formats: tuple | None = None, budget: int = 0,
           batch: int = 1,
           mesh=None, n_shards: int | None = None,
           measure: bool = False, measure_warmup: int = 1,
           measure_repeats: int = 3, interpret: bool = True,
           params: DtansParams = PAPER,
           knob_overrides: dict | None = None,
           lane_widths: tuple | None = None,
           group_sizes: tuple | None = None,
           block_shapes: tuple | None = None,
           cache: DecisionCache | None = None,
           use_cache: bool = True,
           artifacts: dict | None = None) -> Decision:
    """Pick the modeled- (or measured-) fastest format for matrix ``a``.

    Args:
      a: `repro.sparse.formats.CSR` matrix.
      machine: chip model of the cost model.
      warm: model a cache-resident (True) or streaming (False) workload.
      formats: candidate format families to consider; None = every
        selectable family in `repro.sparse.registry` (a format
        registered there joins the sweep with no edit here).
      budget: number of top estimated candidates to construct for exact
        sizes before the final argmin (0 = fingerprint estimates only).
      batch: number of right-hand sides the workload contracts per pass
        (the SpMM batch). Matrix bytes and entropy-decode work are paid
        once per pass, x/y bytes and contraction work per RHS — so the
        winning format can flip as B grows (decode overhead amortizes).
        Part of both cache keys.
      mesh: price every candidate at every power-of-two shard count up
        to the mesh ``model`` axis (`shard_counts`) and let the argmin
        decide how many chips the matrix wants — the winner's count
        lands in ``Decision.n_shards``. Only the model axis SIZE enters
        the search (and the cache keys); the mesh object itself is
        never stored.
      n_shards: pin the sweep to exactly one shard count instead
        (overrides ``mesh``); ``None`` + no mesh = the classic
        single-chip search.
      measure: with ``budget > 0``, additionally wall-clock time the
        top-``budget`` candidates' real kernels
        (`repro.autotune.measure`, at this ``batch``) and rank them by
        measured seconds; the winner always comes from the measured
        head (modeled tail times are a different currency). The winning
        measurement lands in ``Decision.measured_time``.
      measure_warmup / measure_repeats: timing harness knobs
        (median-of-``measure_repeats`` after ``measure_warmup`` calls).
      interpret: run measured kernels in Pallas interpret mode (CPU CI
        fallback); pass ``False`` on an accelerator host.
      knob_overrides: generic knob-domain overrides, ``{knob name ->
        domain tuple}`` — narrows/extends ANY format's sweep (third-
        party specs' knobs included) without a new named keyword.
        Entries for knobs a format does not declare are ignored by that
        format.
      lane_widths / group_sizes / block_shapes: legacy sugar for the
        three built-in override knobs (deprecated in favor of
        ``knob_overrides``; kept working — the named form wins when
        both spell the same knob). None (default) sweeps each format's
        own `FormatSpec.knob_domains` — built-in AND third-party
        formats alike, matching what the exhaustive oracle enumerates.
      cache: decision cache; ``None`` uses the process default
        (persistent on disk). Pass ``DecisionCache(path=None)`` for a
        memory-only cache.
      use_cache: disable both cache layers (for measurement).
      artifacts: optional mutable mapping memoizing encoded matrices
        under `FormatSpec.artifact_key`; callers that already encoded
        candidates (benchmarks, the oracle) pass theirs to skip
        re-encoding. Never part of the cache key.
    """
    if measure and budget <= 0:
        raise ValueError("measure=True requires budget > 0 (only the "
                         "refined head is packed and timed)")
    if batch < 1:
        raise ValueError(f"batch must be >= 1; got {batch}")
    ks = shard_counts(mesh, n_shards)
    if measure and ks != (1,):
        raise ValueError("measure=True is single-device only (the "
                         "timing harness wall-clocks one chip's "
                         "kernels); drop mesh=/n_shards= or measure "
                         "at shards=1")
    if formats is None:
        formats = format_names(selectable=True)
    cache = cache if cache is not None else default_cache()

    overrides = merge_knob_overrides(knob_overrides,
                                     lane_widths=lane_widths,
                                     group_sizes=group_sizes,
                                     block_shapes=block_shapes)
    ko = render_knob_overrides(overrides)
    # The requested formats' LIVE knob domains enter both cache keys: a
    # release (or in-process re-registration) that changes a format's
    # default sweep must invalidate decisions that never priced the new
    # sweep points.
    doms = ";".join(
        f"{f}:" + ",".join(f"{k}=" + "|".join(map(str, v))
                           for k, v in get_format(f).knob_domains.items())
        for f in formats)
    # The cache object is part of the memo key: a repeat select with a
    # *different* cache must consult (and populate) that cache, not
    # short-circuit on the memo.
    cfg = (machine, warm, tuple(formats), int(budget), int(batch), ks,
           ko, doms, params, cache, bool(measure), int(measure_warmup),
           int(measure_repeats), bool(interpret))
    if use_cache:
        hit = _memo.get(id(a))
        if hit is not None and hit[0]() is a and hit[1] == cfg:
            obs.default_registry().counter("autotune.memo_hits").add(1)
            return hit[2]

    fp = fingerprint(a, params=params)
    pp = params
    key_parts = [fp.key(), machine.signature(), f"warm={int(warm)}",
                 ",".join(formats), f"budget={int(budget)}",
                 f"batch={int(batch)}",
                 "ko:" + ko,
                 "doms:" + hashlib.sha1(doms.encode()).hexdigest()[:12],
                 f"w{pp.w_bits}k{pp.k_bits}l{pp.l}o{pp.o}"
                 f"f{pp.f}m{pp.m_bits}"]
    if ks != (1,):
        # Sharded searches key separately; the classic single-chip key
        # is unchanged, so existing cache files stay valid.
        key_parts.append("shards:" + ",".join(map(str, ks)))
    if measure:
        # Measured decisions key separately from modeled ones (and by
        # harness knobs): the currencies must never be mixed by a
        # cache hit.
        key_parts.append(f"meas:w{int(measure_warmup)}"
                         f"r{int(measure_repeats)}i{int(interpret)}")
    key = "|".join(key_parts)
    if use_cache:
        raw = cache.get(key)
        if raw is not None:
            try:
                dec = Decision.from_dict(raw)
            except ValueError:
                dec = None          # schema drift -> recompute
            if dec is not None:
                _memo[id(a)] = (weakref.ref(a), cfg, dec)
                _decision_event(dec, source="cache")
                return dec

    cands = []
    for k in ks:
        cands.extend(candidates(fp, machine=machine, warm=warm,
                                params=params, formats=tuple(formats),
                                batch=batch, n_shards=k,
                                knob_overrides=overrides))
    cands.sort(key=lambda cand: cand.modeled_time)
    if not cands:
        # Possible since FormatSpec.admit: e.g. bcsr_dtans's fill-in
        # guard prunes every block shape on scatter-structured
        # matrices. Diagnosable error beats IndexError.
        raise ValueError(
            f"no admitted candidate configuration for formats "
            f"{tuple(formats)} on this matrix (matrix-adaptive knob "
            f"grids pruned every sweep point; widen `formats` or the "
            f"knob overrides)")
    refined = False
    if budget > 0:
        arts = artifacts if artifacts is not None else {}
        head = [_refine(a, c, fp, warm=warm, machine=machine,
                        params=params, artifacts=arts, batch=batch)
                for c in cands[:budget]]
        refined = any(h is not c for h, c in zip(head, cands))
        if measure:
            from repro.autotune.measure import measure_candidate
            head = [dataclasses.replace(
                        h, measured_time=measure_candidate(
                            a, h, params=params, interpret=interpret,
                            warmup=measure_warmup, batch=batch,
                            repeats=measure_repeats, artifacts=arts))
                    for h in head]
            refined = True
            # Measured head ranks by wall clock; the unmeasured tail
            # keeps its modeled order *behind* the head — a modeled
            # tail time is not comparable to a measured second, so the
            # tail can never outrank a measured candidate.
            head.sort(key=lambda c: c.measured_time)
            cands = head + cands[budget:]
        else:
            cands = sorted(head + cands[budget:],
                           key=lambda c: c.modeled_time)

    best = cands[0]
    dec = Decision(
        fmt=best.fmt, knobs=best.knobs, nbytes=best.nbytes,
        modeled_time=best.modeled_time, exact_size=best.exact_size,
        warm=warm, machine=machine.name, fingerprint_key=fp.key(),
        refined=refined, batch=int(batch), n_shards=best.n_shards,
        measured_time=best.measured_time,
        # Sharded rows spell the oracle's "<config>@S<k>" key so regret
        # tables line up; single-chip rows keep the bare config name.
        leaderboard=tuple((c.config_name if c.n_shards == 1
                           else f"{c.config_name}@S{c.n_shards}",
                           c.nbytes, c.modeled_time,
                           c.measured_time) for c in cands[:5]),
    )
    if use_cache:
        cache.put(key, dec.to_dict())
        if len(_memo) > 4096:  # drop entries whose matrix was collected
            for k in [k for k, v in _memo.items() if v[0]() is None]:
                del _memo[k]
        _memo[id(a)] = (weakref.ref(a), cfg, dec)
    _decision_event(dec, source="search")
    return dec


def choose_dtans_config(a, *, machine: MachineModel = V5E,
                        warm: bool = True, budget: int = 0,
                        batch: int = 1,
                        mesh=None, n_shards: int | None = None,
                        measure: bool = False, interpret: bool = True,
                        params: DtansParams = PAPER,
                        cache: DecisionCache | None = None,
                        use_cache: bool = True,
                        artifacts: dict | None = None) -> Decision:
    """Best entropy-coded configuration only: the ``decodes=True``
    families of the registry (CSR-dtANS lane width x table sharing,
    group-aligned RGCSR-dtANS, block-aligned BCSR-dtANS, ...).

    Used by `repro.serving.sparse_linear.SparseLinear`'s ``auto=True``
    path, where the family must decode on the fly but the knobs are
    free. Every such family runs the same decode kernels, so the
    serving stack is indifferent to which one wins. ``measure=True``
    (with ``budget > 0``) times the candidates' real kernels, exactly
    as in `select`.
    """
    return select(a, machine=machine, warm=warm,
                  formats=format_names(selectable=True, decodes=True),
                  budget=budget, batch=batch, mesh=mesh,
                  n_shards=n_shards, measure=measure,
                  interpret=interpret, params=params, cache=cache,
                  use_cache=use_cache, artifacts=artifacts)
