"""Architecture registry: exact assigned configs + reduced smoke configs.

``get(name)`` returns the full config; ``get_smoke(name)`` a reduced config
of the same family for CPU smoke tests. ``--arch <id>`` in the launchers
resolves through this registry.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "smollm-135m",
    "yi-9b",
    "llama3-405b",
    "granite-34b",
    "mamba2-130m",
    "zamba2-7b",
    "internvl2-1b",
    "qwen3-moe-30b-a3b",
    "granite-moe-3b-a800m",
    "seamless-m4t-large-v2",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE


def all_configs():
    return {a: get(a) for a in ARCH_IDS}
