"""Granite-34B-Code [arXiv:2405.04324]: GPT-BigCode arch, MQA (kv=1),
non-gated GELU MLP.
88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense", n_layers=88, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152, mlp_gated=False)

SMOKE = CONFIG.with_(n_layers=2, d_model=96, n_heads=6, n_kv_heads=1,
                     d_ff=256, vocab=128, dtype="float32", remat=False)
