"""Granite-MoE-3B-A800M [hf:ibm-granite]: 40 experts, top-8.
32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155,
    n_experts=40, top_k=8)

SMOKE = CONFIG.with_(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
                     d_ff=32, vocab=128, n_experts=5, top_k=2,
                     dtype="float32", remat=False)
