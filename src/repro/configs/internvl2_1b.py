"""InternVL2-1B [arXiv:2404.16821]: InternViT + Qwen2-0.5B LM backbone.
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The ViT frontend is
a STUB: input_specs() provides precomputed patch embeddings (B, P, d)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151655,
    frontend="vision", n_frontend_tokens=256, rope_theta=1000000.0)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=128, vocab=128, n_frontend_tokens=8,
                     dtype="float32", remat=False)
