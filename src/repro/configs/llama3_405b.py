"""Llama-3.1-405B [arXiv:2407.21783]: GQA, 128k vocab.
126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256,
    rope_theta=500000.0)

SMOKE = CONFIG.with_(n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
                     d_ff=384, vocab=256, dtype="float32", remat=False)
