"""Mamba2-130M [arXiv:2405.21060]: SSD (state-space duality), attn-free.
24L d_model=768 vocab=50280, ssm_state=128; sub-quadratic -> runs
long_500k."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    subquadratic=True, tie_embeddings=True)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, ssm_state=16, ssm_headdim=16,
                     ssm_chunk=8, vocab=128, dtype="float32", remat=False)
