"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 128 experts, top-8.
48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
head_dim=128 (explicit, not d_model/n_heads)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=768, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, rope_theta=1000000.0)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=32, vocab=128, head_dim=16, n_experts=8, top_k=2,
                     dtype="float32", remat=False)
