"""SeamlessM4T-large-v2 [arXiv:2308.11596]: enc-dec, multimodal.
24L d_model=1024 16H (kv=16, MHA) d_ff=8192 vocab=256206. Interpreted as
24 encoder + 24 decoder layers; the speech frontend is a STUB providing
precomputed frame embeddings (B, S, d)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206,
    n_enc_layers=24, n_dec_layers=24, frontend="speech",
    n_frontend_tokens=2048)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                     d_ff=128, vocab=128, n_enc_layers=2, n_dec_layers=2,
                     n_frontend_tokens=12, dtype="float32", remat=False)
