"""Zamba2-7B [arXiv:2411.15242]: Mamba2 stack + shared attention blocks.
81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Shared attn applied every 6 SSM layers (13 applications)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    attn_every=6, subquadratic=True)

SMOKE = CONFIG.with_(n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
                     d_ff=128, vocab=128, ssm_state=16, ssm_headdim=16,
                     ssm_chunk=8, attn_every=3, dtype="float32", remat=False)
