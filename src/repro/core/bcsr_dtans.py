"""BCSR-dtANS: blocked CSR index layout under dtANS entropy coding.

The entropy pipeline is exactly `repro.core.csr_dtans.encode_matrix` on
the *block-filled* matrix (`repro.sparse.bcsr.block_fill_csr`): every
nonempty r x c block's in-bounds cells become explicit entries, so
within a block the per-row column deltas degenerate to runs of 1 and
the fill-in zeros collapse onto a single value symbol — both nearly
free under the coding table. The interleave width equals the block
height r, so every decode slice IS one block row: slice boundaries and
block-row boundaries coincide, exactly as `RGCSRdtANS` aligns slices
with row groups.

What changes vs `CSRdtANS` is only the *metadata accounting*: all rows
of a block row store the same length (c cells per block), so per-row
4-byte lengths are replaced by one 16-bit block count per block row.
Because `BCSRdtANS` IS a `CSRdtANS` (same streams, tables and slice
layout), the whole downstream stack — `decode_matrix`, `spmv_gold`,
`kernels.pack.pack_matrix` and both Pallas kernels — runs on it
unchanged; `decode_matrix` reconstructs the block-filled matrix, whose
SpMV equals the original's (fill-in cells are zero). This is the
paper's entropy layer composing with a *registered index layout* it was
never hand-wired to — the seam `repro.sparse.registry` exists to prove.
"""

from __future__ import annotations

import dataclasses

from repro.core.csr_dtans import CSRdtANS, encode_matrix
from repro.core.params import PAPER, DtansParams
from repro.sparse.bcsr import block_fill_csr, count_nonempty_blocks
from repro.sparse.formats import CSR
from repro.sparse.rgcsr import local_indptr_bytes


@dataclasses.dataclass
class BCSRdtANS(CSRdtANS):
    """Block-aligned CSR-dtANS (one interleave slice per block row)."""

    block_shape: tuple = (4, 4)
    n_blocks: int = 0

    @property
    def n_block_rows(self) -> int:
        return self.n_slices

    @property
    def block_count_bytes(self) -> int:
        """Bytes per stored per-block-row block count (16-bit unless a
        block row holds 2**16 or more blocks)."""
        c = self.block_shape[1]
        mx = int(self.row_nnz.max()) if self.row_nnz.size else 0
        return local_indptr_bytes(-(-mx // c))

    @property
    def nbytes(self) -> int:
        """Byte-exact size: CSR-dtANS accounting with the per-row
        4-byte lengths replaced by one block count per block row."""
        base = CSRdtANS.nbytes.fget(self)
        return (base - self.shape[0] * 4
                + self.n_block_rows * self.block_count_bytes)


def encode_bcsr_matrix(a: CSR, block_shape: tuple = (4, 4),
                       params: DtansParams = PAPER,
                       shared_table: bool = True) -> BCSRdtANS:
    """Compress a CSR matrix into BCSR-dtANS (slice width == r)."""
    r, c = block_shape
    filled = block_fill_csr(a, block_shape)
    n_blocks = count_nonempty_blocks(a.indptr, a.indices, a.shape,
                                     block_shape)
    base = encode_matrix(filled, params=params, lane_width=r,
                         shared_table=shared_table)
    fields = {f.name: getattr(base, f.name)
              for f in dataclasses.fields(CSRdtANS)}
    return BCSRdtANS(block_shape=(r, c), n_blocks=n_blocks, **fields)
