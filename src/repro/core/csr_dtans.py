"""CSR-dtANS: the paper's entropy-coded sparse-matrix format (Section IV).

Pipeline (Fig. 1): CSR -> per-row delta-encoding of column indices ->
(delta, value)-interleaved symbol stream per row -> dtANS entropy coding ->
per-slice consumption-order interleaving of ``lane_width`` row streams.

Paper-faithful configuration: ONE coding table shared by the delta and value
domains (matches the 64 KB / 48 KB constant table budget of Fig. 6), slice
width 32 (GPU warp). TPU-native default: slice width 128 (VPU lanes).
`shared_table=False` builds separate per-domain tables — a beyond-paper
variant evaluated in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delta import delta_encode_rows
from repro.core.dtans import EncodedStream, encode_scalar
from repro.core.dtans_vec import (InterleavedSlice, StackedTables,
                                  decode_lanes,
                                  interleave_slice_with_pattern)
from repro.core.params import PAPER, DtansParams
from repro.core.tables import CodingTable, build_table
from repro.sparse.formats import CSR

DELTA, VALUE = 0, 1  # domain ids


def _value_bits(dtype: np.dtype) -> int:
    return np.dtype(dtype).itemsize * 8


def _to_bits(values: np.ndarray) -> np.ndarray:
    dt = values.dtype
    if dt == np.float64:
        return values.view(np.uint64)
    if dt == np.float32:
        return values.view(np.uint32).astype(np.uint64)
    raise TypeError(f"unsupported value dtype {dt}")


def _from_bits(bits: np.ndarray, dtype: np.dtype) -> np.ndarray:
    if np.dtype(dtype) == np.float64:
        return bits.view(np.float64)
    if np.dtype(dtype) == np.float32:
        return bits.astype(np.uint32).view(np.float32)
    raise TypeError(f"unsupported value dtype {dtype}")


@dataclasses.dataclass
class CSRdtANS:
    params: DtansParams
    pattern: np.ndarray            # (l,) table index per in-segment position
    domain: np.ndarray             # (l,) DELTA/VALUE per position
    tables: list[CodingTable]
    stacked: StackedTables
    lane_width: int
    shape: tuple[int, int]
    dtype: np.dtype
    stream: np.ndarray             # uint64 (<2^32), all slices concatenated
    slice_offsets: np.ndarray      # (nslices+1,)
    esc_streams: list[np.ndarray]  # per table, uint64
    esc_offsets: np.ndarray        # (nslices+1, T)
    row_nnz: np.ndarray            # (m,)
    esc_count_by_domain: np.ndarray  # (2,) [delta, value] escapes

    @property
    def nnz(self) -> int:
        return int(self.row_nnz.sum())

    @property
    def n_slices(self) -> int:
        return int(self.slice_offsets.size - 1)

    @property
    def nbytes(self) -> int:
        """Byte-exact size, paper accounting (Fig. 6):
        tables + 4-byte stream words + escaped raws + one 4-byte length per
        row + per-slice offsets."""
        vb = self.dtype.itemsize
        b = sum(t.nbytes(vb) for t in self.tables)
        b += int(self.stream.size) * 4
        b += int(self.esc_count_by_domain[DELTA]) * 4
        b += int(self.esc_count_by_domain[VALUE]) * vb
        b += self.shape[0] * 4                      # per-row n
        b += (self.n_slices + 1) * 8                # stream offsets
        b += (self.n_slices + 1) * 4 * len(self.tables)  # escape offsets
        return b


def encode_matrix(a: CSR, params: DtansParams = PAPER,
                  lane_width: int = 128,
                  shared_table: bool = True) -> CSRdtANS:
    """Compress a CSR matrix into CSR-dtANS."""
    l = params.l
    if l % 2 != 0:
        raise ValueError("l must be even: (delta, value) pairs per nonzero")
    m, _ = a.shape
    deltas = delta_encode_rows(a.indptr, a.indices).astype(np.uint64)
    vbits = _to_bits(np.ascontiguousarray(a.values))
    value_bits = _value_bits(a.values.dtype)

    domain = np.tile(np.asarray([DELTA, VALUE]), l // 2)
    if shared_table:
        pattern = np.zeros(l, dtype=np.int64)
        syms, counts = np.unique(np.concatenate([deltas, vbits]),
                                 return_counts=True)
        tables = [build_table(syms, counts, params,
                              esc_raw_bits=max(32, value_bits))]
    else:
        pattern = np.tile(np.asarray([0, 1]), l // 2).astype(np.int64)
        ds, dc = np.unique(deltas, return_counts=True)
        vs, vc = np.unique(vbits, return_counts=True)
        tables = [build_table(ds, dc, params, esc_raw_bits=32),
                  build_table(vs, vc, params, esc_raw_bits=value_bits)]
    T = len(tables)

    n_slices = (m + lane_width - 1) // lane_width
    stream_chunks, esc_chunks = [], [[] for _ in range(T)]
    slice_offsets = np.zeros(n_slices + 1, dtype=np.int64)
    esc_offsets = np.zeros((n_slices + 1, T), dtype=np.int64)
    esc_by_domain = np.zeros(2, dtype=np.int64)
    row_nnz = np.diff(a.indptr).astype(np.int64)

    for s in range(n_slices):
        r0, r1 = s * lane_width, min((s + 1) * lane_width, m)
        encs: list[EncodedStream] = []
        for i in range(r0, r1):
            lo, hi = int(a.indptr[i]), int(a.indptr[i + 1])
            u = np.empty(2 * (hi - lo), dtype=np.uint64)
            u[0::2] = deltas[lo:hi]
            u[1::2] = vbits[lo:hi]
            enc = encode_scalar(u, params, tables, pattern)
            if enc.esc_mask is not None and enc.esc_mask.any():
                em = enc.esc_mask
                pos_dom = domain[np.arange(em.size) % l]
                esc_by_domain[DELTA] += int((em & (pos_dom == DELTA)).sum())
                esc_by_domain[VALUE] += int((em & (pos_dom == VALUE)).sum())
            encs.append(enc)
        sl = interleave_slice_with_pattern(encs, params, pattern, T)
        stream_chunks.append(sl.stream)
        slice_offsets[s + 1] = slice_offsets[s] + sl.stream.size
        for t in range(T):
            esc_chunks[t].append(sl.esc_streams[t])
            esc_offsets[s + 1, t] = (esc_offsets[s, t]
                                     + sl.esc_streams[t].size)

    return CSRdtANS(
        params=params, pattern=pattern, domain=domain, tables=tables,
        stacked=StackedTables.stack(tables), lane_width=lane_width,
        shape=a.shape, dtype=a.values.dtype,
        stream=(np.concatenate(stream_chunks) if stream_chunks
                else np.zeros(0, dtype=np.uint64)),
        slice_offsets=slice_offsets,
        esc_streams=[(np.concatenate(c) if c else np.zeros(0, np.uint64))
                     for c in esc_chunks],
        esc_offsets=esc_offsets,
        row_nnz=row_nnz,
        esc_count_by_domain=esc_by_domain,
    )


def _decode_slice(mat: CSRdtANS, s: int) -> tuple[np.ndarray, np.ndarray,
                                                  np.ndarray]:
    """Decode slice ``s`` -> (cols, vals, mask), each (lanes, max_nnz)."""
    m = mat.shape[0]
    r0, r1 = s * mat.lane_width, min((s + 1) * mat.lane_width, m)
    ns = 2 * mat.row_nnz[r0:r1]
    sl = InterleavedSlice(
        stream=mat.stream[mat.slice_offsets[s]:mat.slice_offsets[s + 1]],
        esc_streams=[mat.esc_streams[t][mat.esc_offsets[s, t]:
                                        mat.esc_offsets[s + 1, t]]
                     for t in range(len(mat.tables))],
        ns=ns,
    )
    out = decode_lanes(sl, mat.params, mat.stacked, mat.pattern)
    if out.shape[1] == 0:
        z = np.zeros((r1 - r0, 0))
        return z.astype(np.int64), z.astype(mat.dtype), z.astype(bool)
    deltas = out[:, 0::2]
    vbits = out[:, 1::2]
    nnz = mat.row_nnz[r0:r1][:, None]
    mask = np.arange(deltas.shape[1])[None, :] < nnz
    cols = np.cumsum(np.where(mask, deltas, 0), axis=1).astype(np.int64)
    vals = _from_bits(vbits.copy(), mat.dtype)
    return cols, np.where(mask, vals, 0).astype(mat.dtype), mask


def decode_matrix(mat: CSRdtANS) -> CSR:
    """Lossless reconstruction of the original CSR matrix."""
    m, n = mat.shape
    indptr = np.zeros(m + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(mat.row_nnz)
    indices = np.zeros(int(mat.row_nnz.sum()), dtype=np.int64)
    values = np.zeros(indices.size, dtype=mat.dtype)
    for s in range(mat.n_slices):
        r0 = s * mat.lane_width
        cols, vals, mask = _decode_slice(mat, s)
        for i in range(cols.shape[0]):
            lo, hi = indptr[r0 + i], indptr[r0 + i + 1]
            indices[lo:hi] = cols[i, :hi - lo]
            values[lo:hi] = vals[i, :hi - lo]
    return CSR(indptr=indptr, indices=indices, values=values,
               shape=mat.shape)


def spmv_gold(mat: CSRdtANS, x: np.ndarray,
              y: np.ndarray | None = None) -> np.ndarray:
    """Gold y = A x + y via on-the-fly decode (numpy, lock-step lanes)."""
    m, n = mat.shape
    assert x.shape == (n,)
    out = np.zeros(m, dtype=mat.dtype) if y is None else y.copy()
    for s in range(mat.n_slices):
        r0 = s * mat.lane_width
        cols, vals, mask = _decode_slice(mat, s)
        if cols.shape[1] == 0:
            continue
        contrib = np.where(mask, vals * x[np.minimum(cols, n - 1)], 0)
        out[r0:r0 + cols.shape[0]] += contrib.sum(axis=1).astype(mat.dtype)
    return out
