"""Delta-encoding of CSR column indices (paper Section IV-A).

Rows are delta-encoded separately: within a row with ascending column
indices c_0 < c_1 < ... the stored symbols are
    d_0 = c_0,   d_i = c_i - c_{i-1}  (i >= 1).
This typically collapses structured sparsity (diagonals, blocks, stencils,
random-graph adjacency) onto a low-entropy distribution of small deltas
(Fig. 4 of the paper; reproduced in benchmarks/bench_delta_entropy.py).
"""

from __future__ import annotations

import numpy as np


def delta_encode_rows(indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """CSR column indices -> per-row deltas (same layout as ``indices``)."""
    indices = np.asarray(indices, dtype=np.int64)
    deltas = np.empty_like(indices)
    deltas[1:] = indices[1:] - indices[:-1]
    deltas[indptr[:-1][np.diff(indptr) > 0]] = \
        indices[indptr[:-1][np.diff(indptr) > 0]]
    return deltas


def delta_decode_rows(indptr: np.ndarray, deltas: np.ndarray) -> np.ndarray:
    """Inverse of :func:`delta_encode_rows`."""
    deltas = np.asarray(deltas, dtype=np.int64)
    out = np.cumsum(deltas)
    # subtract the running total at each row start to restart the cumsum
    starts = indptr[:-1][np.diff(indptr) > 0]
    carry = np.zeros_like(deltas)
    carry[starts] = out[starts] - deltas[starts]
    carry = np.maximum.accumulate(carry)
    return out - carry
