"""dtANS scalar codec — the paper's Algorithm 3 and its exact time reversal.

This is the *gold* reference implementation: Python big-int state, one stream,
no vectorization. `repro.core.dtans_vec` (lane-vectorized numpy) and
`repro.kernels.dtans_spmv` (Pallas) are validated against it.

Decoder state (Section IV-D): ``o`` words w_1..w_o, digit accumulator ``d``
and its radix ``r`` (invariant d < r; r < W at every segment boundary).
Per segment of ``l`` symbols:
  1. unpack(w_1..w_o) -> l slots (mixed-radix rewrite, i_1 least significant);
  2. for each slot: emit symbol, push returned digit: d = d*base + digit,
     r = r*base  (escaped slots additionally consume one raw symbol from the
     escape stream of their domain);
  3. refill: for k = 1..f (conditional): if r >= W extract w_k = d mod W,
     d //= W, r //= W; else pop w_k from v. For k = f+1..o pop w_k from v.
     The refill is skipped entirely for the last segment (Section IV-F,
     "Efficient handling of end of row").

Encoding runs the exact op sequence in reverse (Section IV-E):
  * a forward *base pass* fixes r's trajectory — and hence every
    extract-vs-pop branch — from the symbol sequence alone (the branch only
    depends on bases, which are per-symbol constants);
  * a backward *digit pass* starts from d = 0, inverts each op
    (pop -> prepend word; extract -> d = d*W + w; push -> digit = d mod base,
    d //= base, choosing the slot for (symbol, digit)), and emits the stream
    back-to-front. The ANS invariant d < r forces d == 0 at the stream head,
    which is exactly the decoder's initial state.

Multiple tables: ``pattern[k]`` selects the table of position k within a
segment (CSR-dtANS interleaves delta/value symbols; the paper-faithful
configuration uses ONE table shared by both domains — pattern all zeros —
matching the 64 KB table budget in Fig. 6; two separate tables are our
beyond-paper variant, see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.params import DtansParams
from repro.core.tables import CodingTable


@dataclasses.dataclass
class EncodedStream:
    """One encoded symbol sequence (a matrix row, for CSR-dtANS).

    ``branch`` and ``esc_mask`` describe the decoder's deterministic
    consumption schedule; the slice interleaver uses them to lay words of
    many lanes into one stream in exactly the order a lock-step decoder
    claims them (paper Section II-A "Interleaving for warps").
    """
    words: np.ndarray          # (n_words,) uint32-valued (stored uint64)
    esc: list[np.ndarray]      # per-table escape symbols, consumption order
    n: int                     # number of real (un-padded) symbols
    branch: np.ndarray = None  # (nseg, f) bool: True = extract (no pop)
    esc_mask: np.ndarray = None  # (nseg*l,) bool: position consumed escape

    @property
    def n_words(self) -> int:
        return int(self.words.size)


def _pad(u: np.ndarray, l: int, tables: list[CodingTable],
         pattern: np.ndarray) -> np.ndarray:
    """Pad tail to a multiple of l with cheap in-table symbols (IV-F)."""
    n = u.size
    if n % l == 0 and n > 0:
        return u
    if n == 0:
        return u  # zero symbols: encoded as empty stream, handled by caller
    n_pad = l - (n % l)
    pads = []
    for i in range(n_pad):
        t = tables[pattern[(n + i) % l]]
        try:
            pads.append(t.pad_symbol)
        except ValueError:
            # all-escape table: pad with the last real symbol; it roundtrips
            # through the escape stream and is dropped by the decoder.
            pads.append(int(u[-1]))
    return np.concatenate([u, np.asarray(pads, dtype=np.uint64)])


def encode_scalar(u: np.ndarray, params: DtansParams,
                  tables: list[CodingTable],
                  pattern: np.ndarray | None = None) -> EncodedStream:
    """Encode symbol sequence ``u`` (uint64) into a dtANS word stream."""
    W, K, l, o, f = params.W, params.K, params.l, params.o, params.f
    if not params.exact_unpack:
        # With K^l > W^o, not every slot combination is representable in o
        # words; supporting that needs constrained digit choice. The paper's
        # production parameters have equality, so we require it.
        raise NotImplementedError("encoder requires K^l == W^o")
    u = np.asarray(u, dtype=np.uint64)
    n = int(u.size)
    if pattern is None:
        pattern = np.zeros(l, dtype=np.int64)
    pattern = np.asarray(pattern, dtype=np.int64)
    assert pattern.size == l
    if n == 0:
        return EncodedStream(words=np.zeros(0, dtype=np.uint64),
                             esc=[np.zeros(0, dtype=np.uint64)
                                  for _ in tables], n=0)
    up = _pad(u, l, tables, pattern)
    nseg = up.size // l

    # ---- base pass (forward): branch schedule ----------------------------
    bases = np.empty(up.size, dtype=np.int64)
    is_esc = np.empty(up.size, dtype=bool)
    for k in range(up.size):
        t = tables[pattern[k % l]]
        sym = int(up[k])
        if t.in_table(sym):
            bases[k] = t.base_of(sym)
            is_esc[k] = False
        else:
            bases[k] = t.esc_base
            is_esc[k] = True
            if t.esc_base <= 0:
                raise ValueError("symbol not in table and no escape slot")
    branch = np.zeros((nseg, f), dtype=bool)  # True = extract (not pop)
    r = 1
    for j in range(nseg):
        for k in range(l):
            r *= int(bases[j * l + k])
        if j < nseg - 1:
            for k in range(f):
                if r >= W:
                    branch[j, k] = True
                    r //= W

    # ---- digit pass (backward) -------------------------------------------
    d = 0
    v_rev: list[int] = []                       # words, reversed order
    esc_rev: list[list[int]] = [[] for _ in tables]
    w_next: list[int] | None = None             # w^{(j+1)} packed at step j+1
    for j in range(nseg - 1, -1, -1):
        if j < nseg - 1:
            assert w_next is not None
            for k in range(o - 1, -1, -1):      # reverse refill order
                wk = w_next[k]
                if k >= f or not branch[j, k]:
                    v_rev.append(wk)            # reverse of pop = prepend
                else:
                    d = d * W + wk              # reverse of extract
        # reverse pushes, k = l-1 .. 0
        slots = [0] * l
        for k in range(l - 1, -1, -1):
            idx = j * l + k
            t = tables[pattern[k]]
            b = int(bases[idx])
            g = d % b
            d //= b
            if is_esc[idx]:
                slots[k] = t.esc_first + g
                esc_rev[pattern[k]].append(int(up[idx]))
            else:
                slots[k] = t.slot_of(int(up[idx]), g)
        # pack slots -> words w^{(j)}   (i_1 = slots[0] least significant)
        N = 0
        for k in range(l - 1, -1, -1):
            N = N * K + slots[k]
        w = [(N >> ((o - 1 - k) * params.w_bits)) % W for k in range(o)]
        w_next = w
    assert d == 0, "ANS invariant violated: d != 0 at stream head"
    words = list(w_next) + v_rev[::-1]
    return EncodedStream(
        words=np.asarray(words, dtype=np.uint64),
        esc=[np.asarray(e[::-1], dtype=np.uint64) for e in esc_rev],
        n=n,
        branch=branch,
        esc_mask=is_esc,
    )


def decode_scalar(enc: EncodedStream, params: DtansParams,
                  tables: list[CodingTable],
                  pattern: np.ndarray | None = None) -> np.ndarray:
    """Algorithm 3: decode ``enc`` back into its symbol sequence."""
    W, K, l, o, f = params.W, params.K, params.l, params.o, params.f
    if pattern is None:
        pattern = np.zeros(l, dtype=np.int64)
    pattern = np.asarray(pattern, dtype=np.int64)
    n = enc.n
    if n == 0:
        return np.zeros(0, dtype=np.uint64)
    nseg = (n + l - 1) // l
    v = enc.words
    pos = o
    w = [int(v[k]) for k in range(o)]
    d, r = 0, 1
    esc_pos = [0] * len(tables)
    out = np.empty(nseg * l, dtype=np.uint64)
    for j in range(nseg):
        N = 0
        for k in range(o):
            N = N * W + w[k]
        for k in range(l):
            slot = (N >> (k * params.k_bits)) % K
            t = tables[pattern[k]]
            if t.slot_is_esc[slot]:
                ti = int(pattern[k])
                out[j * l + k] = enc.esc[ti][esc_pos[ti]]
                esc_pos[ti] += 1
            else:
                out[j * l + k] = t.slot_symbol[slot]
            b = int(t.slot_base[slot])
            d = d * b + int(t.slot_digit[slot])
            r *= b
        if j < nseg - 1:
            for k in range(f):
                if r >= W:
                    w[k] = d % W
                    d //= W
                    r //= W
                else:
                    w[k] = int(v[pos])
                    pos += 1
            for k in range(f, o):
                w[k] = int(v[pos])
                pos += 1
    return out[:n]


def encoded_bits(enc: EncodedStream, params: DtansParams,
                 esc_bits_per_table: list[int] | None = None) -> int:
    """Size in bits of the encoded stream (words + escapes), excluding
    tables and the 4-byte length word (accounted at the matrix level)."""
    bits = enc.n_words * params.w_bits
    for ti, e in enumerate(enc.esc):
        per = esc_bits_per_table[ti] if esc_bits_per_table else 32
        bits += int(e.size) * per
    return bits
