"""Lane-vectorized dtANS decode + consumption-order stream interleaving.

This is the numpy twin of the Pallas kernel (`repro.kernels.dtans_spmv`) and
the production host-side decode path. A *slice* of ``lanes`` independent
streams (one matrix row per lane, paper: 32 GPU threads; here: 128 TPU
vector lanes) is decoded in lock step. All lanes share ONE word stream laid
out in *consumption order*: at every load point, the lanes that need a word
claim consecutive positions, ordered by lane id — the TPU translation of the
paper's ``__ballot_sync``+``popc`` prefix-sum claim (DESIGN.md §2).

Arithmetic: decoder state d (and radix r) live in three 32-bit limbs held in
uint64 containers — the vector analogue of the paper's
"word-size multiplication + __umul_hi" trick. Digits are first accumulated
in groups whose radix product fits 32 bits (paper: "accumulate 4 returned
digits into a 4-byte digit/base pair"), then folded into the limb state.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dtans import EncodedStream
from repro.core.params import DtansParams
from repro.core.tables import CodingTable

_M32 = np.uint64(0xFFFFFFFF)


@dataclasses.dataclass
class StackedTables:
    """Table arrays stacked over domains, gather-ready for kernels."""
    symbol: np.ndarray   # (T, K) uint64
    digit: np.ndarray    # (T, K) uint32
    base: np.ndarray     # (T, K) uint32
    is_esc: np.ndarray   # (T, K) bool

    @classmethod
    def stack(cls, tables: list[CodingTable]) -> "StackedTables":
        return cls(
            symbol=np.stack([t.slot_symbol for t in tables]),
            digit=np.stack([t.slot_digit for t in tables]),
            base=np.stack([t.slot_base for t in tables]),
            is_esc=np.stack([t.slot_is_esc for t in tables]),
        )

    @property
    def T(self) -> int:
        return self.symbol.shape[0]


@dataclasses.dataclass
class InterleavedSlice:
    """One slice's interleaved streams (the CSR-dtANS on-device layout)."""
    stream: np.ndarray        # (n_words,) uint64 (< 2^32), claim order
    esc_streams: list[np.ndarray]  # per-table uint64, claim order
    ns: np.ndarray            # (lanes,) int64 — symbols per lane


def interleave_slice(encs: list[EncodedStream], params: DtansParams,
                     n_tables: int) -> InterleavedSlice:
    """Merge per-lane encoded streams into one claim-ordered stream.

    Claim schedule (must mirror ``decode_lanes`` exactly):
      - initial load: for k = 0..o-1, every live lane pops, lane-ascending;
      - per segment j (lock step), refill for k = 0..o-1: lanes active in
        segment j+1... (i.e. lanes with j < nseg-1) pop unless the branch
        schedule says extract; lane-ascending within each k;
      - escape words: claimed at (segment, position k, lane) order.
    """
    l, o, f = params.l, params.o, params.f
    lanes = len(encs)
    ns = np.asarray([e.n for e in encs], dtype=np.int64)
    nsegs = (ns + l - 1) // l
    max_nseg = int(nsegs.max()) if lanes else 0
    cursors = [0] * lanes
    out: list[int] = []

    def pop(i: int) -> None:
        e = encs[i]
        out.append(int(e.words[cursors[i]]))
        cursors[i] += 1

    # initial load, k-major, lane-ascending
    for _ in range(o):
        for i in range(lanes):
            if ns[i] > 0:
                pop(i)
    # per-segment refills (segment j refills for consumption at j+1)
    for j in range(max_nseg):
        for k in range(o):
            for i in range(lanes):
                if j >= nsegs[i] - 1:   # lane done (or within last segment)
                    continue
                if k < f and encs[i].branch[j, k]:
                    continue            # extracted from state, no pop
                pop(i)
    for i in range(lanes):
        assert cursors[i] == encs[i].n_words, (
            f"lane {i}: {cursors[i]} != {encs[i].n_words}")
    return InterleavedSlice(
        stream=np.asarray(out, dtype=np.uint64),
        esc_streams=[np.zeros(0, dtype=np.uint64) for _ in range(n_tables)],
        ns=ns,
    )


def interleave_slice_with_pattern(
        encs: list[EncodedStream], params: DtansParams,
        pattern: np.ndarray, n_tables: int) -> InterleavedSlice:
    """Like ``interleave_slice`` but also interleaves escape streams
    according to ``pattern`` (table index per in-segment position)."""
    base = interleave_slice([_strip_esc(e) for e in encs], params, n_tables)
    l = params.l
    lanes = len(encs)
    ns = base.ns
    nsegs = (ns + l - 1) // l
    max_nseg = int(nsegs.max()) if lanes else 0
    esc_out: list[list[int]] = [[] for _ in range(n_tables)]
    esc_cursors = np.zeros((lanes, n_tables), dtype=np.int64)
    for j in range(max_nseg):
        for k in range(l):
            t = int(pattern[k])
            for i in range(lanes):
                if j >= nsegs[i]:
                    continue
                e = encs[i]
                if e.esc_mask is None or not e.esc_mask[j * l + k]:
                    continue
                esc_out[t].append(int(e.esc[t][esc_cursors[i, t]]))
                esc_cursors[i, t] += 1
    return InterleavedSlice(
        stream=base.stream,
        esc_streams=[np.asarray(e, dtype=np.uint64) for e in esc_out],
        ns=ns,
    )


def _strip_esc(e: EncodedStream) -> EncodedStream:
    return EncodedStream(words=e.words, esc=[], n=e.n, branch=e.branch,
                         esc_mask=None)


# ---------------------------------------------------------------------------
# Vectorized lock-step decode
# ---------------------------------------------------------------------------

def decode_lanes(sl: InterleavedSlice, params: DtansParams,
                 st: StackedTables, pattern: np.ndarray) -> np.ndarray:
    """Decode an interleaved slice; returns (lanes, max_n_padded) uint64.

    Positions beyond each lane's ``ns`` are padding garbage (mirrors the
    device kernel, which masks them in the SpMVM accumulation).
    """
    W_bits, K_bits = params.w_bits, params.k_bits
    W = np.uint64(params.W)
    Wm1 = np.uint64(params.W - 1)
    Km1 = np.uint64(params.K - 1)
    l, o, f = params.l, params.o, params.f
    lanes = sl.ns.size
    ns = sl.ns
    nsegs = (ns + l - 1) // l
    max_nseg = int(nsegs.max()) if lanes else 0
    if max_nseg == 0:
        return np.zeros((lanes, 0), dtype=np.uint64)

    stream = sl.stream
    cursor = 0
    esc_cursor = [0] * st.T

    # digit-group size: product of <=g bases stays < 2^32
    g = max(1, 32 // params.m_bits)

    w = np.zeros((lanes, o), dtype=np.uint64)
    live = ns > 0
    for k in range(o):
        take = live
        cnt = int(take.sum())
        idx = cursor + np.cumsum(take) - 1
        w[take, k] = stream[idx[take]]
        cursor += cnt

    d = np.zeros((3, lanes), dtype=np.uint64)   # limbs, little-endian
    r = np.zeros((3, lanes), dtype=np.uint64)
    r[0] = 1

    out = np.zeros((lanes, max_nseg * l), dtype=np.uint64)

    for j in range(max_nseg):
        active = j < nsegs
        # ---- unpack: slot_k = bits [k*K_bits, (k+1)*K_bits) of
        # N = w_0 * W^(o-1) + ... + w_{o-1}; little-endian word view:
        wle = w[:, ::-1]  # wle[:,0] least significant
        for k in range(l):
            lo = k * K_bits
            wi, sh = lo // W_bits, lo % W_bits
            pair = wle[:, wi].copy()
            if wi + 1 < o:
                pair = pair | (wle[:, wi + 1] << np.uint64(W_bits))
            slot = (pair >> np.uint64(sh)) & Km1
            t = int(pattern[k])
            sym = st.symbol[t][slot]
            esc = st.is_esc[t][slot] & active
            if esc.any():
                take = esc
                cnt = int(take.sum())
                idx = esc_cursor[t] + np.cumsum(take) - 1
                sym = sym.copy()
                sym[take] = sl.esc_streams[t][idx[take]]
                esc_cursor[t] += cnt
            out[:, j * l + k] = sym
            # stash digit/base for grouped accumulation below
            if k == 0:
                digs = np.zeros((l, lanes), dtype=np.uint64)
                bass = np.ones((l, lanes), dtype=np.uint64)
            digs[k] = np.where(active, st.digit[t][slot].astype(np.uint64), 0)
            bass[k] = np.where(active, st.base[t][slot].astype(np.uint64), 1)

        # ---- push digits in groups of g, then fold into limb state
        for g0 in range(0, l, g):
            gacc = np.zeros(lanes, dtype=np.uint64)
            racc = np.ones(lanes, dtype=np.uint64)
            for k in range(g0, min(g0 + g, l)):
                gacc = gacc * bass[k] + digs[k]
                racc = racc * bass[k]
            # d = d * racc + gacc ; r = r * racc  (3-limb multiply-add)
            d = _limb_mul_add(d, racc, gacc)
            r = _limb_mul_add(r, racc, np.zeros(lanes, dtype=np.uint64))

        # ---- refill (skipped for lanes in their last segment)
        refill = active & (j < nsegs - 1)
        if not refill.any():
            continue
        for k in range(o):
            if k < f:
                cond = _limb_ge_w(r, W_bits) & refill      # extract
                wk = d[0] & Wm1
                d = np.where(cond, _limb_shr(d, W_bits), d)
                r = np.where(cond, _limb_shr(r, W_bits), r)
                popl = refill & ~cond
            else:
                cond = np.zeros(lanes, dtype=bool)
                wk = np.zeros(lanes, dtype=np.uint64)
                popl = refill
            if popl.any():
                cnt = int(popl.sum())
                idx = cursor + np.cumsum(popl) - 1
                wk = wk.copy()
                wk[popl] = stream[idx[popl]]
                cursor += cnt
            w[:, k] = np.where(refill, wk, w[:, k])
    return out


def _limb_mul_add(d: np.ndarray, m: np.ndarray, a: np.ndarray) -> np.ndarray:
    """(3, lanes) limb state: d*m + a, with m <= 2^32, a < 2^32."""
    M32 = _M32
    t0 = d[0] * m + a
    l0 = t0 & M32
    c0 = t0 >> np.uint64(32)
    t1 = d[1] * m + c0
    l1 = t1 & M32
    c1 = t1 >> np.uint64(32)
    t2 = d[2] * m + c1
    return np.stack([l0, l1, t2 & M32])


def _limb_ge_w(r: np.ndarray, w_bits: int) -> np.ndarray:
    """r >= 2^w_bits on (3, lanes) limbs (w_bits <= 32)."""
    hi = (r[1] > 0) | (r[2] > 0)
    if w_bits == 32:
        return hi
    return hi | (r[0] >> np.uint64(w_bits) > 0)


def _limb_shr(d: np.ndarray, w_bits: int) -> np.ndarray:
    """d >> w_bits on (3, lanes) limbs."""
    M32 = _M32
    sh = np.uint64(w_bits)
    full0 = d[0] | (d[1] << np.uint64(32))
    full1 = d[1] | (d[2] << np.uint64(32))
    return np.stack([(full0 >> sh) & M32, (full1 >> sh) & M32, d[2] >> sh])
