"""Entropy / cross-entropy utilities (paper Section III-B, eqs. (1)-(2))."""

from __future__ import annotations

import numpy as np


def entropy_bits(counts: np.ndarray) -> float:
    """Shannon entropy H(P) in bits/symbol of an empirical distribution.

    ``counts`` are raw occurrence counts (not normalized); zeros are ignored.
    """
    c = np.asarray(counts, dtype=np.float64)
    c = c[c > 0]
    if c.size == 0:
        return 0.0
    p = c / c.sum()
    return float(-(p * np.log2(p)).sum())


def cross_entropy_bits(counts: np.ndarray, mults: np.ndarray, K: int) -> float:
    """Cross entropy H(P, P') in bits/symbol where P'(s) = mults[s] / K.

    This is the achievable bits/symbol of a (d)tANS table assigning
    ``mults[s]`` of the ``K`` slots to symbol ``s`` (paper eq. (2)). Symbols
    with count > 0 must have mult > 0 (else H' is infinite).
    """
    c = np.asarray(counts, dtype=np.float64)
    m = np.asarray(mults, dtype=np.float64)
    sel = c > 0
    if not sel.any():
        return 0.0
    if (m[sel] <= 0).any():
        return float("inf")
    p = c[sel] / c[sel].sum()
    q = m[sel] / float(K)
    return float(-(p * np.log2(q)).sum())


def stream_entropy_bits(symbols: np.ndarray) -> float:
    """Empirical entropy of a raw symbol stream."""
    _, counts = np.unique(np.asarray(symbols), return_counts=True)
    return entropy_bits(counts)
