"""dtANS parameter set (Section IV of the paper).

The paper's production choice for CSR-dtANS:
  W = 2^32  (stream word = one GPU/TPU 32-bit register)
  K = 2^12  (coding-table slots; table fits in shared memory / VMEM)
  l = 8     (symbols per segment = 4 nonzeros x (delta, value))
  o = 3     (words consumed per segment, K^l == W^o)
  M = 2^8   (multiplicity cap, bounds per-segment radix growth)
  f = 2     (conditional loads per segment, M^l == W^f)

Constraints enforced (paper, Section IV-D):
  K^l >= W^o          (unpack surjective: every slot combination reachable)
  M^l <= W^f <= W^o   (all returned digits absorbable by f extractions)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DtansParams:
    w_bits: int = 32  # log2(W)
    k_bits: int = 12  # log2(K)
    l: int = 8        # symbols per segment
    o: int = 3        # words per segment
    f: int = 2        # conditional loads per segment
    m_bits: int = 8   # log2(M)

    @property
    def W(self) -> int:
        return 1 << self.w_bits

    @property
    def K(self) -> int:
        return 1 << self.k_bits

    @property
    def M(self) -> int:
        return 1 << self.m_bits

    def __post_init__(self) -> None:
        if not (0 < self.f <= self.o):
            raise ValueError(f"need 0 < f <= o, got f={self.f}, o={self.o}")
        if self.K ** self.l < self.W ** self.o:
            raise ValueError(
                f"unpack not surjective: K^l = {self.K}^{self.l} < W^o = "
                f"{self.W}^{self.o}")
        if self.M ** self.l > self.W ** self.f:
            raise ValueError(
                f"digit overflow possible: M^l = {self.M}^{self.l} > W^f = "
                f"{self.W}^{self.f}")
        if self.m_bits > self.k_bits:
            raise ValueError("M cannot exceed K")

    @property
    def exact_unpack(self) -> bool:
        """True iff pack/unpack is a bijection (no code-space waste)."""
        return self.K ** self.l == self.W ** self.o


# Paper production parameters (CSR-dtANS, Section IV-D).
PAPER = DtansParams(w_bits=32, k_bits=12, l=8, o=3, f=2, m_bits=8)

# Tiny parameters from the worked example in Section IV-D (word = 2 bits,
# K = 8, M = 4, l = 2, o = 3, f = 2). Used in unit tests.
TOY = DtansParams(w_bits=2, k_bits=3, l=2, o=3, f=2, m_bits=2)
