"""RGCSR-dtANS: row-grouped CSR with dtANS entropy coding.

The entropy pipeline is exactly `repro.core.csr_dtans.encode_matrix` —
per-row column-delta + value symbol streams, dtANS coding, consumption-
order interleaving — but the interleave width equals the row-group size
G, so every decode slice IS one row group: slice boundaries and group
boundaries coincide, a decode program never straddles a group, and a
slice's stream length tracks its own longest row instead of the longest
row among ``lane_width`` neighbours (the skew behaviour row-grouped CSR
formats exist for; see `repro.sparse.rgcsr` for the two source papers).

What changes vs `CSRdtANS` is only the *metadata accounting*:

* per-row lengths are group-local (a row's nnz, bounded by its group's
  total), stored in 16-bit entries whenever no row reaches 2**16
  nonzeros — 2 bytes/row instead of CSR-dtANS's 4;
* per-slice stream/escape offsets are per *group*, so there are
  ``ceil(m/G)`` of them instead of ``ceil(m/128)`` — the small-G
  overhead the autotuner trades against skew localization.

Because `RGCSRdtANS` IS a `CSRdtANS` (same streams, tables and slice
layout), the whole downstream stack — `decode_matrix`, `spmv_gold`,
`kernels.pack.pack_matrix` and both Pallas kernels — runs on it
unchanged; group alignment is a property of how it was encoded.
"""

from __future__ import annotations

import dataclasses

from repro.core.csr_dtans import CSRdtANS, encode_matrix
from repro.core.params import PAPER, DtansParams
from repro.sparse.formats import CSR
from repro.sparse.rgcsr import local_indptr_bytes


@dataclasses.dataclass
class RGCSRdtANS(CSRdtANS):
    """Group-aligned CSR-dtANS (one interleave slice per row group)."""

    group_size: int = 32

    @property
    def n_groups(self) -> int:
        return self.n_slices

    @property
    def row_len_bytes(self) -> int:
        """Bytes per stored group-local row length (16-bit when no row
        has 2**16+ nonzeros, else 32-bit)."""
        mx = int(self.row_nnz.max()) if self.row_nnz.size else 0
        return local_indptr_bytes(mx)

    @property
    def nbytes(self) -> int:
        """Byte-exact size: CSR-dtANS accounting with group-local row
        lengths (2 B/row in the common case) and per-group offsets."""
        vb = self.dtype.itemsize
        b = sum(t.nbytes(vb) for t in self.tables)
        b += int(self.stream.size) * 4
        b += int(self.esc_count_by_domain[0]) * 4          # delta escapes
        b += int(self.esc_count_by_domain[1]) * vb         # value escapes
        b += self.shape[0] * self.row_len_bytes            # local row n
        b += (self.n_groups + 1) * 8                       # stream offsets
        b += (self.n_groups + 1) * 4 * len(self.tables)    # escape offsets
        return b


def encode_rgcsr_matrix(a: CSR, group_size: int = 32,
                        params: DtansParams = PAPER,
                        shared_table: bool = True) -> RGCSRdtANS:
    """Compress a CSR matrix into RGCSR-dtANS (slice width == G)."""
    base = encode_matrix(a, params=params, lane_width=group_size,
                         shared_table=shared_table)
    fields = {f.name: getattr(base, f.name)
              for f in dataclasses.fields(CSRdtANS)}
    return RGCSRdtANS(group_size=group_size, **fields)
