"""dtANS coding-table construction (paper Sections III-D, IV-C, IV-F).

A table assigns each in-table symbol a *multiplicity* (number of consecutive
slots), approximating the empirical distribution P by P'(s) = mult(s)/K while
respecting the dtANS cap ``mult(s) <= M`` (Section IV-C). Rare symbols can be
*escaped* (Section IV-F "Escaping rare values"): they share one ESC symbol in
the table and their raw bits go to a separate escape stream.

Slot layout: symbols occupy consecutive slots (digit = 0..mult-1); the ESC
symbol, if present, occupies the trailing slots. The paper additionally
permutes slots to avoid GPU shared-memory bank conflicts; VMEM has no
programmer-visible banking, so we keep the consecutive layout (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.entropy import cross_entropy_bits, entropy_bits
from repro.core.params import DtansParams


@dataclasses.dataclass
class CodingTable:
    """Immutable decode/encode tables for one symbol domain (or a merged one).

    Attributes:
      slot_symbol: (K,) uint64 — symbol decoded at each slot (raw bit pattern).
      slot_digit:  (K,) uint32 — digit returned at each slot.
      slot_base:   (K,) uint32 — radix (multiplicity of the slot's symbol).
      slot_is_esc: (K,) bool   — slot belongs to the escape symbol.
      first_slot:  dict symbol -> first slot index (encode-side inverse).
      esc_first:   first escape slot (or -1), esc_base its multiplicity.
      esc_raw_bits: bits emitted to the escape stream per escaped symbol.
      K, M: table size / multiplicity cap actually used.
    """

    slot_symbol: np.ndarray
    slot_digit: np.ndarray
    slot_base: np.ndarray
    slot_is_esc: np.ndarray
    first_slot: dict
    esc_first: int
    esc_base: int
    esc_raw_bits: int
    K: int
    M: int
    used_slots: int

    def base_of(self, sym: int) -> int:
        """Multiplicity of a symbol (esc multiplicity if escaped)."""
        fs = self.first_slot.get(int(sym), -1)
        if fs >= 0:
            return int(self.slot_base[fs])
        if self.esc_first < 0:
            raise KeyError(f"symbol {sym} not in table and no escape slot")
        return self.esc_base

    def in_table(self, sym: int) -> bool:
        return int(sym) in self.first_slot

    def slot_of(self, sym: int, digit: int) -> int:
        fs = self.first_slot.get(int(sym), -1)
        if fs >= 0:
            return fs + digit
        return self.esc_first + digit

    @property
    def pad_symbol(self) -> int:
        """A cheap in-table symbol used to pad tails (Section IV-F)."""
        if self.used_slots > 0 and not self.slot_is_esc[0]:
            # slot 0 belongs to the highest-multiplicity symbol (cheapest).
            return int(self.slot_symbol[0])
        raise ValueError("table has no non-escape symbol to pad with")

    def nbytes(self, value_bytes: int) -> int:
        """On-accelerator table bytes, paper's accounting (Fig. 6 caption):
        K x (symbol + digit + base) = K x (value_bytes + 4 + 4)."""
        return self.K * (value_bytes + 8)


def build_table(
    symbols: np.ndarray,
    counts: np.ndarray,
    params: DtansParams,
    esc_raw_bits: int = 32,
) -> CodingTable:
    """Build a coding table from empirical symbol counts.

    Chooses (a) which symbols live in the table vs. get escaped and (b) the
    multiplicity of each, minimizing expected bits:
        in-table symbol:  count * -log2(mult/K)
        escaped symbol:   count * (-log2(esc_mult/K) + esc_raw_bits)
    subject to  sum(mult) <= K,  1 <= mult <= M.

    Strategy (greedy, near-optimal, O(S log S)):
      1. keep the (K-1) most frequent symbols in-table at most, rest escape;
      2. water-fill multiplicities proportional to counts, capped at M;
      3. greedily move the worst in-table symbols to escape while that
         reduces expected bits (re-fitting the escape multiplicity);
      4. final exact rebalance of multiplicities by largest-gain increments.
    """
    symbols = np.asarray(symbols, dtype=np.uint64)
    counts = np.asarray(counts, dtype=np.int64)
    if symbols.shape != counts.shape or symbols.ndim != 1:
        raise ValueError("symbols/counts must be 1-D and same shape")
    if np.unique(symbols).size != symbols.size:
        raise ValueError("symbols must be unique")
    K, M = params.K, params.M
    order = np.argsort(-counts, kind="stable")
    symbols, counts = symbols[order], counts[order]
    S = symbols.size
    total = max(int(counts.sum()), 1)

    # --- step 1: initial split: at most K-1 in-table (reserve 1 slot for ESC
    # when anything escapes).
    n_in = min(S, K - 1) if S > K - 1 else S
    while True:
        in_counts = counts[:n_in]
        esc_count = int(counts[n_in:].sum())
        have_esc = esc_count > 0 or n_in < S
        # --- step 2: proportional multiplicities, capped, >= 1.
        budget = K
        mults = _waterfill(in_counts, esc_count if have_esc else 0, budget, M)
        in_mults, esc_mult = mults
        # --- step 3: evict in-table symbols whose escape cost is lower.
        # Cost comparison for the marginal (lowest-count) in-table symbol s:
        #   keep:   c_s * -log2(m_s/K)
        #   escape: c_s * (-log2(esc'/K) + esc_raw_bits)   (esc' >= max(1,esc))
        # Eviction also frees m_s slots for everyone else, so we accept any
        # eviction that does not increase the total expected bits.
        if n_in == 0:
            break
        c_s = int(in_counts[-1])
        m_s = int(in_mults[-1])
        esc_now = esc_mult if have_esc else 0
        keep_bits = c_s * -np.log2(m_s / K)
        esc_next = max(1, esc_now)  # at least one ESC slot after eviction
        esc_bits = c_s * (-np.log2(esc_next / K) + esc_raw_bits)
        # Freed slots get re-water-filled; approximate their value as the
        # current marginal gain of one slot (cheap, keeps this O(S)).
        if esc_bits < keep_bits and S > 1:
            n_in -= 1
            continue
        break

    in_counts = counts[:n_in]
    esc_count = int(counts[n_in:].sum())
    have_esc = n_in < S
    in_mults, esc_mult = _waterfill(
        in_counts, esc_count if have_esc else 0, K, M)
    if have_esc and esc_mult == 0:
        esc_mult = 1  # escape path must stay reachable

    # --- assemble slots -------------------------------------------------
    slot_symbol = np.zeros(K, dtype=np.uint64)
    slot_digit = np.zeros(K, dtype=np.uint32)
    slot_base = np.ones(K, dtype=np.uint32)
    slot_is_esc = np.zeros(K, dtype=bool)
    first_slot: dict = {}
    pos = 0
    for i in range(n_in):
        m = int(in_mults[i])
        if m <= 0:
            continue
        first_slot[int(symbols[i])] = pos
        slot_symbol[pos:pos + m] = symbols[i]
        slot_digit[pos:pos + m] = np.arange(m, dtype=np.uint32)
        slot_base[pos:pos + m] = m
        pos += m
    esc_first = -1
    if have_esc:
        esc_first = pos
        slot_symbol[pos:pos + esc_mult] = np.uint64(0)
        slot_digit[pos:pos + esc_mult] = np.arange(esc_mult, dtype=np.uint32)
        slot_base[pos:pos + esc_mult] = esc_mult
        slot_is_esc[pos:pos + esc_mult] = True
        pos += esc_mult
    # Unused trailing slots keep base=1/digit=0; the encoder never selects
    # them, so they are unreachable during decode.
    return CodingTable(
        slot_symbol=slot_symbol,
        slot_digit=slot_digit,
        slot_base=slot_base,
        slot_is_esc=slot_is_esc,
        first_slot=first_slot,
        esc_first=esc_first,
        esc_base=int(esc_mult) if have_esc else 0,
        esc_raw_bits=esc_raw_bits,
        K=K,
        M=M,
        used_slots=pos,
    )


def _waterfill(in_counts: np.ndarray, esc_count: int, budget: int,
               M: int) -> tuple[np.ndarray, int]:
    """Allocate multiplicities (1..M each) to in-table symbols + ESC.

    Proportional seed followed by exact greedy top-up: repeatedly grant one
    slot to the entity with the largest marginal bit saving
    c * (log2(m+1) - log2(m)). Returns (in_mults, esc_mult).
    """
    n = in_counts.size
    ext = np.concatenate([in_counts.astype(np.float64),
                          [float(esc_count)] if esc_count > 0 else []])
    ne = ext.size
    if ne == 0:
        return np.zeros(0, dtype=np.int64), 0
    if budget < ne:
        raise ValueError(f"table too small: K={budget} < symbols+esc={ne}")
    tot = ext.sum()
    seed = np.maximum(1, np.minimum(
        M, np.floor(budget * ext / max(tot, 1.0)).astype(np.int64)))
    # Trim overshoot from the smallest-count entries first.
    overshoot = int(seed.sum()) - budget
    if overshoot > 0:
        for i in range(ne - 1, -1, -1):
            cut = min(overshoot, int(seed[i]) - 1)
            seed[i] -= cut
            overshoot -= cut
            if overshoot == 0:
                break
    # Greedy top-up with a heap on marginal gain.
    import heapq
    free = budget - int(seed.sum())
    heap = []
    for i in range(ne):
        if seed[i] < M and ext[i] > 0:
            gain = ext[i] * (np.log2(seed[i] + 1) - np.log2(seed[i]))
            heap.append((-gain, i))
    heapq.heapify(heap)
    while free > 0 and heap:
        _, i = heapq.heappop(heap)
        if seed[i] >= M:
            continue
        seed[i] += 1
        free -= 1
        if seed[i] < M:
            gain = ext[i] * (np.log2(seed[i] + 1) - np.log2(seed[i]))
            heapq.heappush(heap, (-gain, i))
    if esc_count > 0:
        return seed[:n], int(seed[n])
    return seed, 0


def table_cross_entropy(table: CodingTable, symbols: np.ndarray,
                        counts: np.ndarray) -> float:
    """Achieved bits/symbol of ``table`` on the (symbols, counts) corpus,
    including escape-stream raw bits. Used by tests and benchmarks."""
    symbols = np.asarray(symbols, dtype=np.uint64)
    counts = np.asarray(counts, dtype=np.float64)
    total = counts.sum()
    if total == 0:
        return 0.0
    bits = 0.0
    for s, c in zip(symbols, counts):
        if table.in_table(int(s)):
            m = table.base_of(int(s))
            bits += c * -np.log2(m / table.K)
        else:
            bits += c * (-np.log2(table.esc_base / table.K)
                         + table.esc_raw_bits)
    return bits / total


__all__ = [
    "CodingTable", "build_table", "table_cross_entropy",
    "entropy_bits", "cross_entropy_bits",
]
