# Deterministic, shardable, resumable synthetic data pipeline.
