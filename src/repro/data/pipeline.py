"""Deterministic, shardable, resumable token pipeline.

Batches are a pure function of (seed, step, shard) via counter-based
Philox streams — no iterator state to checkpoint, so restart-from-step-N
reproduces the exact token stream (fault-tolerance requirement), and any
data shard can be regenerated on any host (elastic re-sharding).

Synthetic text: a Zipf unigram mixture with short Markov motifs, so models
actually have something learnable (examples/train_lm.py shows loss going
down) rather than uniform noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0     # vlm/encdec: embeddings per sample
    d_model: int = 0


class SyntheticTokens:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        v = cfg.vocab
        base = np.random.default_rng(
            np.random.Philox(key=np.uint64(cfg.seed)))
        # fixed Zipf unigram distribution + a motif table
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._p = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._motifs = base.integers(0, v, size=(64, 8))

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        key = np.uint64(self.cfg.seed) ^ (np.uint64(step) << np.uint64(20)) \
            ^ np.uint64(shard)
        return np.random.default_rng(np.random.Philox(key=key))

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> dict:
        """Batch for (step, shard): tokens (B_local, S+1) int32."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        bl = cfg.global_batch // num_shards
        rng = self._rng(step, shard)
        toks = rng.choice(cfg.vocab, size=(bl, cfg.seq_len + 1),
                          p=self._p).astype(np.int32)
        # paste motifs for local structure
        n_paste = max(1, cfg.seq_len // 64)
        for b in range(bl):
            ids = rng.integers(0, 64, size=n_paste)
            pos = rng.integers(0, cfg.seq_len - 8, size=n_paste)
            for i, p0 in zip(ids, pos):
                toks[b, p0:p0 + 8] = self._motifs[i] % cfg.vocab
        out = {"inputs": toks[:, :-1], "targets": toks[:, 1:],
               "mask": np.ones((bl, cfg.seq_len), dtype=np.float32)}
        if cfg.frontend_tokens:
            out["frontend"] = rng.standard_normal(
                (bl, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        return out
