"""BCSR SpMVM Pallas kernel (interpret-mode first, like sell_spmv).

One program per block row. The block row's blocks live in VMEM as a
(W, r, c) value tile plus a (W,) block-column vector (W = matrix-wide
max blocks per block row — address padding only, like `pack.py`'s
stream padding; padded slots carry block column -1 and zero values).
The kernel expands each block column into its c absolute columns,
gathers x once per block, and contracts the dense r x c tiles — no
per-element index arithmetic, which is the format's whole bargain: the
cost model charges BCSR plain lock-step work over the *filled* cells
(`Fingerprint.block_fill_elems`), with no row-sequential penalty and no
decode term.

Structure mirrors `sell_spmv.py` / `rgcsr_spmv.py`: a dataclass pack
product, a Pallas kernel over a 1-D block-row grid, and a pure-jnp
oracle for tests.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.tiling import blocked_spmm
from repro.sparse.bcsr import BCSR


@dataclasses.dataclass
class PackedBCSR:
    block_cols: np.ndarray  # (S, W) int32 block-column ids, -1 = padding
    values: np.ndarray      # (S, W, r, c)
    shape: tuple
    block_shape: tuple


def pack_bcsr(b: BCSR) -> PackedBCSR:
    r, c = b.block_shape
    S = b.n_block_rows
    per_row = np.diff(b.block_ptr)
    W = max(int(per_row.max()) if S else 0, 1)
    cols = np.full((S, W), -1, dtype=np.int32)
    vals = np.zeros((S, W, r, c), dtype=b.values.dtype)
    if b.n_blocks:
        # Vectorized scatter: each block lands at (its block row, its
        # position within that row).
        brow = np.repeat(np.arange(S, dtype=np.int64), per_row)
        pos = np.arange(b.n_blocks, dtype=np.int64) - b.block_ptr[brow]
        cols[brow, pos] = b.block_cols
        vals[brow, pos] = b.values
    return PackedBCSR(block_cols=cols, values=vals, shape=b.shape,
                      block_shape=b.block_shape)


def _bcsr_kernel(col_ref, val_ref, x_ref, y_ref):
    cols = col_ref[0]         # (W,)
    vals = val_ref[0]         # (W, r, c)
    x = x_ref[...]
    W, r, c = vals.shape
    n = x.shape[0]
    mask = cols >= 0
    # absolute columns per block: (W, c), clipped into x (padded slots
    # and out-of-bounds edge-block cells hold zero values, so the
    # clipped gather contributes nothing)
    colidx = jnp.maximum(cols, 0)[:, None] * c + \
        jax.lax.broadcasted_iota(jnp.int32, (W, c), 1)
    xg = jnp.take(x, jnp.clip(colidx, 0, n - 1), axis=0)   # (W, c)
    contrib = jnp.where(mask[:, None, None], vals * xg[:, None, :], 0)
    y_ref[0, :] = jnp.sum(contrib, axis=(0, 2))            # (r,)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bcsr_spmv_pallas(block_cols, val, x, interpret=True):
    S, W, r, c = val.shape
    n = x.shape[0]
    return pl.pallas_call(
        _bcsr_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, W), lambda s: (s, 0)),
            pl.BlockSpec((1, W, r, c), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((n,), lambda s: (0,)),
        ],
        out_specs=pl.BlockSpec((1, r), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, r), val.dtype),
        interpret=interpret,
    )(block_cols, val, x)


def _bcsr_spmm_kernel(col_ref, val_ref, x_ref, y_ref):
    cols = col_ref[0]         # (W,)
    vals = val_ref[0]         # (W, r, c)
    x = x_ref[...]            # (n, B)
    W, r, c = vals.shape
    n = x.shape[0]
    mask = cols >= 0
    colidx = jnp.maximum(cols, 0)[:, None] * c + \
        jax.lax.broadcasted_iota(jnp.int32, (W, c), 1)
    xg = jnp.take(x, jnp.clip(colidx, 0, n - 1), axis=0)   # (W, c, B)
    contrib = jnp.where(mask[:, None, None, None],
                        vals[..., None] * xg[:, None, :, :], 0)
    y_ref[0, :, :] = jnp.sum(contrib, axis=(0, 2))         # (r, B)


@functools.partial(jax.jit, static_argnames=("interpret", "bn",
                                             "tile_mode"))
def bcsr_spmm_pallas(block_cols, val, x, interpret=True, bn=None,
                     tile_mode="auto"):
    """Multi-RHS BCSR kernel: x is (n, B); returns (S, r, B) — each
    dense tile is gathered once and contracted against all B columns.
    ``bn`` column-tiles the B axis (`repro.kernels.tiling`); blocked
    output is bitwise equal to the untiled kernel."""
    S, W, r, c = val.shape
    mat_specs = [
        ((1, W), lambda s: (s, 0)),
        ((1, W, r, c), lambda s: (s, 0, 0, 0)),
    ]
    return blocked_spmm(_bcsr_spmm_kernel, (block_cols, val), mat_specs,
                        x, rows=r, out_dtype=val.dtype, grid_s=S, bn=bn,
                        tile_mode=tile_mode, interpret=interpret)


def bcsr_spmv_ref(block_cols: np.ndarray, val: np.ndarray, x: np.ndarray):
    """Pure-jnp oracle for the BCSR kernel ((S, r) output)."""
    x = jnp.asarray(x)
    S, W, r, c = val.shape
    n = x.shape[0]
    mask = block_cols >= 0
    colidx = jnp.maximum(block_cols, 0)[..., None] * c + \
        jax.lax.broadcasted_iota(jnp.int32, (S, W, c), 2)
    xg = jnp.take(x, jnp.clip(colidx, 0, n - 1), axis=0)   # (S, W, c)
    contrib = jnp.where(mask[..., None, None],
                        val * xg[:, :, None, :], 0)
    return jnp.sum(contrib, axis=(1, 3))                   # (S, r)
