"""Shared jnp building blocks for the dtANS decode kernels.

`segment_step` is the lock-step decode of ONE segment across all lanes —
the same function is traced by the pure-jnp oracle (ref.py) and by the
Pallas kernel bodies (dtans_spmv.py / dtans_decode.py), so the kernel and
its oracle cannot drift apart.

Integer story (paper Section IV-F "Positioning of checks"): the decoder
state d (and radix r) is held in three 32-bit limbs inside uint64 lanes.
Digits are accumulated in groups whose radix product fits in 32 bits
("accumulate 4 returned digits into a 4-byte digit/base pair"), then folded
into the limbs with one 64-bit multiply-add per limb — the TPU stand-in for
the paper's `mul.lo`/`__umul_hi` pair.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.params import DtansParams

_M32 = 0xFFFFFFFF  # python ints stay weak-typed: safe inside Pallas kernels


class DecodeArrays(NamedTuple):
    """Per-slice arrays, already loaded into VMEM/registers."""
    stream: jax.Array    # (Wmax,) uint64
    esc: jax.Array       # (T, Emax) uint64
    tab_symbol: jax.Array  # (T, K) uint64
    tab_digit: jax.Array   # (T, K) int32
    tab_base: jax.Array    # (T, K) int32
    tab_is_esc: jax.Array  # (T, K) int32
    ns: jax.Array        # (L,) int32
    nnz: jax.Array       # (L,) int32


class DecodeState(NamedTuple):
    w: jax.Array         # (L, o) uint64
    d: jax.Array         # (3, L) uint64 limbs
    r: jax.Array         # (3, L) uint64 limbs
    cursor: jax.Array    # () int32 — common stream cursor
    esc_cur: jax.Array   # (T,) int32
    col: jax.Array       # (L,) int64 — running column per lane
    nsegs: jax.Array     # (L,) int32


def _limb_mul_add(d, m, a):
    t0 = d[0] * m + a
    l0 = t0 & _M32
    c0 = t0 >> 32
    t1 = d[1] * m + c0
    l1 = t1 & _M32
    c1 = t1 >> 32
    t2 = d[2] * m + c1
    return jnp.stack([l0, l1, t2 & _M32])


def _limb_ge_w(r, w_bits: int):
    hi = (r[1] > 0) | (r[2] > 0)
    if w_bits == 32:
        return hi
    return hi | ((r[0] >> w_bits) > 0)


def _limb_shr(d, w_bits: int):
    sh = w_bits
    full0 = d[0] | (d[1] << 32)
    full1 = d[1] | (d[2] << 32)
    return jnp.stack([(full0 >> sh) & _M32, (full1 >> sh) & _M32,
                      d[2] >> sh])


def _claim(stream, cursor, take):
    """Consumption-order claim: lanes with ``take`` read consecutive words
    starting at ``cursor`` (vectorized ballot+popc, DESIGN.md §2)."""
    rank = jnp.cumsum(take.astype(jnp.int32)) - 1
    idx = cursor + rank
    idx = jnp.clip(idx, 0, stream.shape[0] - 1)
    words = jnp.take(stream, idx, axis=0)
    return words, cursor + jnp.sum(take, dtype=jnp.int32)


def init_state(arr: DecodeArrays, params: DtansParams) -> DecodeState:
    l, o = params.l, params.o
    L = arr.ns.shape[0]
    T = arr.esc.shape[0]
    nsegs = (arr.ns + (l - 1)) // l
    live = arr.ns > 0
    cursor = jnp.int32(0)
    w = jnp.zeros((L, o), dtype=jnp.uint64)
    for k in range(o):
        words, cursor = _claim(arr.stream, cursor, live)
        w = w.at[:, k].set(jnp.where(live, words, 0))
    return DecodeState(
        w=w,
        d=jnp.zeros((3, L), dtype=jnp.uint64),
        r=jnp.zeros((3, L), dtype=jnp.uint64).at[0].set(1),
        cursor=cursor,
        esc_cur=jnp.zeros((T,), dtype=jnp.int32),
        col=jnp.zeros((L,), dtype=jnp.int64),
        nsegs=nsegs,
    )


def segment_step(j, state: DecodeState, arr: DecodeArrays,
                 params: DtansParams, pattern: tuple):
    """Decode segment ``j`` on all lanes.

    Returns (new_state, cols, vals_bits, valid):
      cols      (l//2, L) int64  — absolute column index per nonzero
      vals_bits (l//2, L) uint64 — raw value bit patterns
      valid     (l//2, L) bool   — nonzero exists (tail masking)
    """
    W_bits, K_bits = params.w_bits, params.k_bits
    l, o, f = params.l, params.o, params.f
    Km1 = params.K - 1
    Wm1 = params.W - 1
    active = j < state.nsegs

    # ---- unpack + table lookups (static unroll over l positions) --------
    wle = state.w[:, ::-1]  # little-endian word view
    syms, digs, bass = [], [], []
    esc_cur = state.esc_cur
    for k in range(l):
        lo = k * K_bits
        wi, sh = lo // W_bits, lo % W_bits
        pair = wle[:, wi]
        if wi + 1 < o:
            pair = pair | (wle[:, wi + 1] << W_bits)
        slot = (pair >> sh) & Km1
        t = pattern[k]
        sym = jnp.take(arr.tab_symbol[t], slot, axis=0)
        is_esc = (jnp.take(arr.tab_is_esc[t], slot, axis=0) > 0) & active
        rank = jnp.cumsum(is_esc.astype(jnp.int32)) - 1
        eidx = jnp.clip(esc_cur[t] + rank, 0, arr.esc.shape[1] - 1)
        esym = jnp.take(arr.esc[t], eidx, axis=0)
        sym = jnp.where(is_esc, esym, sym)
        esc_cur = esc_cur.at[t].add(jnp.sum(is_esc, dtype=jnp.int32))
        dig = jnp.where(active, jnp.take(arr.tab_digit[t], slot, axis=0), 0)
        bas = jnp.where(active, jnp.take(arr.tab_base[t], slot, axis=0), 1)
        syms.append(sym)
        digs.append(dig.astype(jnp.uint64))
        bass.append(bas.astype(jnp.uint64))

    # ---- positions: even = delta, odd = value bits -----------------------
    cols, vals_bits, valid = [], [], []
    col = state.col
    for i in range(l // 2):
        q = j * (l // 2) + i                      # nonzero index in row
        ok = (q < arr.nnz) & active
        col = col + jnp.where(ok, syms[2 * i].astype(jnp.int64), 0)
        cols.append(col)
        vals_bits.append(syms[2 * i + 1])
        valid.append(ok)

    # ---- fold digits into limb state (groups fit 32 bits) ---------------
    d, r = state.d, state.r
    g = max(1, 32 // params.m_bits)
    for g0 in range(0, l, g):
        gacc = jnp.zeros_like(syms[0])
        racc = jnp.ones_like(syms[0])
        for k in range(g0, min(g0 + g, l)):
            gacc = gacc * bass[k] + digs[k]
            racc = racc * bass[k]
        d = _limb_mul_add(d, racc, gacc)
        r = _limb_mul_add(r, racc, jnp.zeros_like(racc))

    # ---- refill ----------------------------------------------------------
    refill = active & (j < state.nsegs - 1)
    w = state.w
    cursor = state.cursor
    for k in range(o):
        if k < f:
            cond = _limb_ge_w(r, W_bits) & refill
            wk = d[0] & Wm1
            d = jnp.where(cond, _limb_shr(d, W_bits), d)
            r = jnp.where(cond, _limb_shr(r, W_bits), r)
            popl = refill & ~cond
        else:
            cond = jnp.zeros_like(refill)
            wk = jnp.zeros_like(state.w[:, 0])
            popl = refill
        popped, cursor = _claim(arr.stream, cursor, popl)
        wk = jnp.where(popl, popped, wk)
        w = w.at[:, k].set(jnp.where(refill, wk, w[:, k]))

    new_state = DecodeState(w=w, d=d, r=r, cursor=cursor, esc_cur=esc_cur,
                            col=col, nsegs=state.nsegs)
    return (new_state, jnp.stack(cols), jnp.stack(vals_bits),
            jnp.stack(valid))


def bits_to_value(bits: jax.Array, dtype) -> jax.Array:
    """Reinterpret raw uint64 symbol bits as float32/float64 values."""
    if dtype == jnp.float64:
        return jax.lax.bitcast_convert_type(bits, jnp.float64)
    if dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(
            bits.astype(jnp.uint32), jnp.float32)
    raise TypeError(f"unsupported dtype {dtype}")
