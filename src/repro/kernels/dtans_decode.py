"""Decode-only dtANS Pallas kernel (the library's "decompression kernel").

Same lock-step machinery as the fused SpMVM kernel but materializes
(columns, values) per row instead of contracting against x. Output is the
padded (S, L, max_nnz) layout; cols == -1 marks tail padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.params import DtansParams
from repro.kernels.common import (DecodeArrays, bits_to_value, init_state,
                                  segment_step)


def _decode_kernel(stream_ref, esc_ref, ns_ref, nnz_ref, sym_ref, dig_ref,
                   base_ref, isesc_ref, cols_ref, vals_ref, *,
                   params: DtansParams, pattern: tuple, max_nseg: int,
                   out_dtype):
    arr = DecodeArrays(
        stream=stream_ref[0, :],
        esc=esc_ref[:, 0, :],
        tab_symbol=sym_ref[...],
        tab_digit=dig_ref[...],
        tab_base=base_ref[...],
        tab_is_esc=isesc_ref[...],
        ns=ns_ref[0, :],
        nnz=nnz_ref[0, :],
    )
    state = init_state(arr, params)
    h = params.l // 2

    def body(j, state):
        state, cols, vbits, valid = segment_step(j, state, arr, params,
                                                 pattern)
        vals = bits_to_value(vbits, out_dtype)
        cols_blk = jnp.where(valid, cols, -1).astype(jnp.int32).T  # (L, h)
        vals_blk = jnp.where(valid, vals, 0).T
        idx = (pl.dslice(0, 1), slice(None), pl.dslice(j * h, h))
        pl.store(cols_ref, idx, cols_blk[None])
        pl.store(vals_ref, idx, vals_blk[None])
        return state

    jax.lax.fori_loop(0, max_nseg, body, state)


@functools.partial(jax.jit, static_argnames=(
    "params", "pattern", "max_nseg", "lane_width", "out_dtype", "interpret"))
def dtans_decode_pallas(stream, esc, ns, nnz, tabs, *, params, pattern,
                        max_nseg, lane_width, out_dtype, interpret=True):
    S, Wmax = stream.shape
    T, _, Emax = esc.shape
    K = params.K
    h = params.l // 2
    max_nnz = max_nseg * h
    kernel = functools.partial(_decode_kernel, params=params,
                               pattern=pattern, max_nseg=max_nseg,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, Wmax), lambda s: (s, 0)),
            pl.BlockSpec((T, 1, Emax), lambda s: (0, s, 0)),
            pl.BlockSpec((1, lane_width), lambda s: (s, 0)),
            pl.BlockSpec((1, lane_width), lambda s: (s, 0)),
            pl.BlockSpec((T, K), lambda s: (0, 0)),
            pl.BlockSpec((T, K), lambda s: (0, 0)),
            pl.BlockSpec((T, K), lambda s: (0, 0)),
            pl.BlockSpec((T, K), lambda s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, lane_width, max_nnz), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, lane_width, max_nnz), lambda s: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, lane_width, max_nnz), jnp.int32),
            jax.ShapeDtypeStruct((S, lane_width, max_nnz), out_dtype),
        ],
        interpret=interpret,
    )(stream, esc, ns, nnz, *tabs)
