"""Fused dtANS-decode + SpMVM Pallas TPU kernel (the paper's Fig. 1 right).

Grid: one program per slice of ``lane_width`` rows (the TPU translation of
one GPU warp per 32-row slice). Per program, the kernel holds in VMEM:

  stream block   (1, Wmax)  x 8 B   — this slice's interleaved word stream
  escape block   (T, 1, Emax) x 8 B — this slice's escape streams
  coding tables  (T, K) x 20 B      — shared by every program (K = 4096
                                      -> 80 KB/table; fits v5e VMEM easily)
  x              (n,) x itemsize    — the dense input vector
  y block        (1, L) x itemsize  — output rows for this slice

The decode loop is `lax.fori_loop` over the matrix-wide max segment count;
lanes past their row's end are masked (same lock-step schedule as
`repro.core.dtans_vec.decode_lanes`). All gathers (stream claims, table
lookups, x[col]) are `jnp.take` over VMEM-resident blocks — the TPU
equivalent of the paper's shared-memory lookups + coalesced loads
(DESIGN.md §2 spells out the mapping and its costs).

Three static knobs grow the PR-5 kernels into the blocked/fused/
pipelined execution layer (docs/kernels.md has the full contract):

* ``shared_cols`` — the fused BCSR-dtANS contraction.  A block-filled
  encode (BCSR-dtANS at lane_width == r) gives every in-bounds lane of
  a slice the SAME column sequence, so the kernel gathers x once per
  decoded cell from lane 0's columns (``cols[:, 0]``) and broadcasts
  the ``(h, B)`` tile across the r lanes — an r x cut in gather traffic
  versus the generic ``(h, L, B)`` gather.  The contraction stays in
  multiply-where-sum form (NOT `lax.dot_general`, whose reduction tree
  differs in the last ulp), so fused output is bitwise identical to the
  generic path.
* ``pipeline`` — decode/contract overlap (the SMASH co-design point):
  the loop body decodes segment ``j+1`` BEFORE contracting segment
  ``j``, so the next segment's stream claims and table gathers have no
  data dependence on the in-flight contraction and can overlap it
  (software pipelining; Mosaic/the VLIW scheduler interleaves the two
  issue streams).  The contraction order per column is unchanged —
  bit-identical to the serial loop.  The prologue decodes segment 0;
  the final body iteration decodes one segment past the end, which is
  masked to a no-op (``segment_step`` is inactive-safe).
* ``bn`` — column tiling of the SpMM wrapper via
  `repro.kernels.tiling.blocked_spmm` (2-D ``(s, j)`` grid compiled,
  `lax.map` column loop in interpret mode), so x/y need never be VMEM-
  resident whole.

Validated with ``interpret=True`` (this container is CPU-only); the target
is TPU v5e. 64-bit lane arithmetic lowers to 32-bit pairs on TPU — the
native-width variant is a recorded perf iteration, not a correctness issue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.params import DtansParams
from repro.kernels.common import (DecodeArrays, bits_to_value, init_state,
                                  segment_step)
from repro.kernels.tiling import blocked_spmm


def _decode_contract(arr, params, pattern, max_nseg, acc0, contract,
                     pipeline: bool):
    """The shared decode loop: serial (decode j, contract j) or
    software-pipelined (decode j+1, then contract j — the decode of the
    next segment issues with no data dependence on the contraction in
    flight).  Contraction order is identical either way."""
    state = init_state(arr, params)
    if not pipeline:
        def body(j, carry):
            state, acc = carry
            state, cols, vbits, valid = segment_step(j, state, arr,
                                                     params, pattern)
            return state, contract(cols, vbits, valid, acc)

        _, acc = jax.lax.fori_loop(0, max_nseg, body, (state, acc0))
        return acc

    state, cols, vbits, valid = segment_step(0, state, arr, params,
                                             pattern)

    def body(j, carry):
        state, seg, acc = carry
        nstate, ncols, nvbits, nvalid = segment_step(j + 1, state, arr,
                                                     params, pattern)
        acc = contract(*seg, acc)
        return nstate, (ncols, nvbits, nvalid), acc

    _, _, acc = jax.lax.fori_loop(0, max_nseg, body,
                                  (state, (cols, vbits, valid), acc0))
    return acc


def _spmv_kernel(stream_ref, esc_ref, ns_ref, nnz_ref, sym_ref, dig_ref,
                 base_ref, isesc_ref, x_ref, y_ref, *, params: DtansParams,
                 pattern: tuple, max_nseg: int, out_dtype,
                 pipeline: bool = False, shared_cols: bool = False):
    arr = DecodeArrays(
        stream=stream_ref[0, :],
        esc=esc_ref[:, 0, :],
        tab_symbol=sym_ref[...],
        tab_digit=dig_ref[...],
        tab_base=base_ref[...],
        tab_is_esc=isesc_ref[...],
        ns=ns_ref[0, :],
        nnz=nnz_ref[0, :],
    )
    x = x_ref[...]
    n = x.shape[0]
    acc0 = jnp.zeros((arr.ns.shape[0],), dtype=out_dtype)

    def contract(cols, vbits, valid, acc):
        vals = bits_to_value(vbits, out_dtype)
        if shared_cols:
            # Block-filled encode: all in-bounds lanes share lane 0's
            # columns — gather once, broadcast across the r lanes.
            xg = jnp.take(x, jnp.clip(cols[:, 0], 0, n - 1), axis=0)
            contrib = jnp.where(valid, vals * xg[:, None], 0)
        else:
            xg = jnp.take(x, jnp.clip(cols, 0, n - 1), axis=0)
            contrib = jnp.where(valid, vals * xg, 0)
        return acc + jnp.sum(contrib, axis=0)

    y_ref[0, :] = _decode_contract(arr, params, pattern, max_nseg, acc0,
                                   contract, pipeline)


@functools.partial(jax.jit, static_argnames=(
    "params", "pattern", "max_nseg", "lane_width", "out_dtype",
    "interpret", "pipeline", "shared_cols"))
def dtans_spmv_pallas(stream, esc, ns, nnz, tabs, x, *, params, pattern,
                      max_nseg, lane_width, out_dtype, interpret=True,
                      pipeline=False, shared_cols=False):
    """pallas_call wrapper: returns per-slice row results (S, L)."""
    S, Wmax = stream.shape
    T, _, Emax = esc.shape
    K = params.K
    n = x.shape[0]
    kernel = functools.partial(_spmv_kernel, params=params, pattern=pattern,
                               max_nseg=max_nseg, out_dtype=out_dtype,
                               pipeline=pipeline, shared_cols=shared_cols)
    return pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, Wmax), lambda s: (s, 0)),      # stream slice
            pl.BlockSpec((T, 1, Emax), lambda s: (0, s, 0)),  # escapes
            pl.BlockSpec((1, lane_width), lambda s: (s, 0)),  # ns
            pl.BlockSpec((1, lane_width), lambda s: (s, 0)),  # nnz
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab symbol
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab digit
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab base
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab is_esc
            pl.BlockSpec((n,), lambda s: (0,)),              # x (whole)
        ],
        out_specs=pl.BlockSpec((1, lane_width), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, lane_width), out_dtype),
        interpret=interpret,
    )(stream, esc, ns, nnz, *tabs, x)


def _spmm_kernel(stream_ref, esc_ref, ns_ref, nnz_ref, sym_ref, dig_ref,
                 base_ref, isesc_ref, x_ref, y_ref, *, params: DtansParams,
                 pattern: tuple, max_nseg: int, out_dtype,
                 pipeline: bool = False, shared_cols: bool = False):
    """Fused decode + multi-RHS contraction: decode each segment ONCE,
    contract it against all B columns of x before the next segment —
    the amortization the batched cost model prices (decode work is per
    matrix, contraction work per right-hand side)."""
    arr = DecodeArrays(
        stream=stream_ref[0, :],
        esc=esc_ref[:, 0, :],
        tab_symbol=sym_ref[...],
        tab_digit=dig_ref[...],
        tab_base=base_ref[...],
        tab_is_esc=isesc_ref[...],
        ns=ns_ref[0, :],
        nnz=nnz_ref[0, :],
    )
    x = x_ref[...]                               # (n, B)
    n = x.shape[0]
    acc0 = jnp.zeros((arr.ns.shape[0], x.shape[1]), dtype=out_dtype)

    def contract(cols, vbits, valid, acc):
        vals = bits_to_value(vbits, out_dtype)               # (h, L)
        if shared_cols:
            # Fused BCSR-dtANS: one (h, B) gather from lane 0's columns
            # feeds all r lanes of the block row (r x fewer gathers).
            xg = jnp.take(x, jnp.clip(cols[:, 0], 0, n - 1),
                          axis=0)                            # (h, B)
            contrib = jnp.where(valid[..., None],
                                vals[..., None] * xg[:, None, :], 0)
        else:
            xg = jnp.take(x, jnp.clip(cols, 0, n - 1),
                          axis=0)                            # (h, L, B)
            contrib = jnp.where(valid[..., None],
                                vals[..., None] * xg, 0)
        return acc + jnp.sum(contrib, axis=0)

    y_ref[0, :, :] = _decode_contract(arr, params, pattern, max_nseg,
                                      acc0, contract, pipeline)


@functools.partial(jax.jit, static_argnames=(
    "params", "pattern", "max_nseg", "lane_width", "out_dtype",
    "interpret", "bn", "tile_mode", "pipeline", "shared_cols"))
def dtans_spmm_pallas(stream, esc, ns, nnz, tabs, x, *, params, pattern,
                      max_nseg, lane_width, out_dtype, interpret=True,
                      bn=None, tile_mode="auto", pipeline=False,
                      shared_cols=False):
    """Multi-RHS pallas_call wrapper: x is (n, B); returns (S, L, B).

    ``bn`` tiles the B axis into column blocks (None = untiled single
    tile, the PR-5 call); ``pipeline`` overlaps decode with
    contraction; ``shared_cols`` runs the fused block-decode
    contraction.  All three are bit-identity-preserving."""
    S, Wmax = stream.shape
    T, _, Emax = esc.shape
    K = params.K
    kernel = functools.partial(_spmm_kernel, params=params, pattern=pattern,
                               max_nseg=max_nseg, out_dtype=out_dtype,
                               pipeline=pipeline, shared_cols=shared_cols)
    mat_specs = [
        ((1, Wmax), lambda s: (s, 0)),           # stream slice
        ((T, 1, Emax), lambda s: (0, s, 0)),     # escapes
        ((1, lane_width), lambda s: (s, 0)),     # ns
        ((1, lane_width), lambda s: (s, 0)),     # nnz
        ((T, K), lambda s: (0, 0)),              # tab symbol
        ((T, K), lambda s: (0, 0)),              # tab digit
        ((T, K), lambda s: (0, 0)),              # tab base
        ((T, K), lambda s: (0, 0)),              # tab is_esc
    ]
    return blocked_spmm(kernel, (stream, esc, ns, nnz, *tabs), mat_specs,
                        x, rows=lane_width, out_dtype=out_dtype,
                        grid_s=S, bn=bn, tile_mode=tile_mode,
                        interpret=interpret)
