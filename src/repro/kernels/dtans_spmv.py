"""Fused dtANS-decode + SpMVM Pallas TPU kernel (the paper's Fig. 1 right).

Grid: one program per slice of ``lane_width`` rows (the TPU translation of
one GPU warp per 32-row slice). Per program, the kernel holds in VMEM:

  stream block   (1, Wmax)  x 8 B   — this slice's interleaved word stream
  escape block   (T, 1, Emax) x 8 B — this slice's escape streams
  coding tables  (T, K) x 20 B      — shared by every program (K = 4096
                                      -> 80 KB/table; fits v5e VMEM easily)
  x              (n,) x itemsize    — the dense input vector
  y block        (1, L) x itemsize  — output rows for this slice

The decode loop is `lax.fori_loop` over the matrix-wide max segment count;
lanes past their row's end are masked (same lock-step schedule as
`repro.core.dtans_vec.decode_lanes`). All gathers (stream claims, table
lookups, x[col]) are `jnp.take` over VMEM-resident blocks — the TPU
equivalent of the paper's shared-memory lookups + coalesced loads
(DESIGN.md §2 spells out the mapping and its costs).

Validated with ``interpret=True`` (this container is CPU-only); the target
is TPU v5e. 64-bit lane arithmetic lowers to 32-bit pairs on TPU — the
native-width variant is a recorded perf iteration, not a correctness issue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.params import DtansParams
from repro.kernels.common import (DecodeArrays, bits_to_value, init_state,
                                  segment_step)


def _spmv_kernel(stream_ref, esc_ref, ns_ref, nnz_ref, sym_ref, dig_ref,
                 base_ref, isesc_ref, x_ref, y_ref, *, params: DtansParams,
                 pattern: tuple, max_nseg: int, out_dtype):
    arr = DecodeArrays(
        stream=stream_ref[0, :],
        esc=esc_ref[:, 0, :],
        tab_symbol=sym_ref[...],
        tab_digit=dig_ref[...],
        tab_base=base_ref[...],
        tab_is_esc=isesc_ref[...],
        ns=ns_ref[0, :],
        nnz=nnz_ref[0, :],
    )
    x = x_ref[...]
    n = x.shape[0]
    state = init_state(arr, params)
    acc0 = jnp.zeros((arr.ns.shape[0],), dtype=out_dtype)

    def body(j, carry):
        state, acc = carry
        state, cols, vbits, valid = segment_step(j, state, arr, params,
                                                 pattern)
        vals = bits_to_value(vbits, out_dtype)
        xg = jnp.take(x, jnp.clip(cols, 0, n - 1), axis=0)
        return state, acc + jnp.sum(jnp.where(valid, vals * xg, 0), axis=0)

    _, acc = jax.lax.fori_loop(0, max_nseg, body, (state, acc0))
    y_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=(
    "params", "pattern", "max_nseg", "lane_width", "out_dtype", "interpret"))
def dtans_spmv_pallas(stream, esc, ns, nnz, tabs, x, *, params, pattern,
                      max_nseg, lane_width, out_dtype, interpret=True):
    """pallas_call wrapper: returns per-slice row results (S, L)."""
    S, Wmax = stream.shape
    T, _, Emax = esc.shape
    K = params.K
    n = x.shape[0]
    kernel = functools.partial(_spmv_kernel, params=params, pattern=pattern,
                               max_nseg=max_nseg, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, Wmax), lambda s: (s, 0)),      # stream slice
            pl.BlockSpec((T, 1, Emax), lambda s: (0, s, 0)),  # escapes
            pl.BlockSpec((1, lane_width), lambda s: (s, 0)),  # ns
            pl.BlockSpec((1, lane_width), lambda s: (s, 0)),  # nnz
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab symbol
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab digit
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab base
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab is_esc
            pl.BlockSpec((n,), lambda s: (0,)),              # x (whole)
        ],
        out_specs=pl.BlockSpec((1, lane_width), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, lane_width), out_dtype),
        interpret=interpret,
    )(stream, esc, ns, nnz, *tabs, x)


def _spmm_kernel(stream_ref, esc_ref, ns_ref, nnz_ref, sym_ref, dig_ref,
                 base_ref, isesc_ref, x_ref, y_ref, *, params: DtansParams,
                 pattern: tuple, max_nseg: int, out_dtype):
    """Fused decode + multi-RHS contraction: decode each segment ONCE,
    contract it against all B columns of x before the next segment —
    the amortization the batched cost model prices (decode work is per
    matrix, contraction work per right-hand side)."""
    arr = DecodeArrays(
        stream=stream_ref[0, :],
        esc=esc_ref[:, 0, :],
        tab_symbol=sym_ref[...],
        tab_digit=dig_ref[...],
        tab_base=base_ref[...],
        tab_is_esc=isesc_ref[...],
        ns=ns_ref[0, :],
        nnz=nnz_ref[0, :],
    )
    x = x_ref[...]                               # (n, B)
    n = x.shape[0]
    state = init_state(arr, params)
    acc0 = jnp.zeros((arr.ns.shape[0], x.shape[1]), dtype=out_dtype)

    def body(j, carry):
        state, acc = carry
        state, cols, vbits, valid = segment_step(j, state, arr, params,
                                                 pattern)
        vals = bits_to_value(vbits, out_dtype)               # (h, L)
        xg = jnp.take(x, jnp.clip(cols, 0, n - 1), axis=0)   # (h, L, B)
        contrib = jnp.where(valid[..., None], vals[..., None] * xg, 0)
        return state, acc + jnp.sum(contrib, axis=0)

    _, acc = jax.lax.fori_loop(0, max_nseg, body, (state, acc0))
    y_ref[0, :, :] = acc


@functools.partial(jax.jit, static_argnames=(
    "params", "pattern", "max_nseg", "lane_width", "out_dtype", "interpret"))
def dtans_spmm_pallas(stream, esc, ns, nnz, tabs, x, *, params, pattern,
                      max_nseg, lane_width, out_dtype, interpret=True):
    """Multi-RHS pallas_call wrapper: x is (n, B); returns (S, L, B)."""
    S, Wmax = stream.shape
    T, _, Emax = esc.shape
    K = params.K
    n, B = x.shape
    kernel = functools.partial(_spmm_kernel, params=params, pattern=pattern,
                               max_nseg=max_nseg, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, Wmax), lambda s: (s, 0)),      # stream slice
            pl.BlockSpec((T, 1, Emax), lambda s: (0, s, 0)),  # escapes
            pl.BlockSpec((1, lane_width), lambda s: (s, 0)),  # ns
            pl.BlockSpec((1, lane_width), lambda s: (s, 0)),  # nnz
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab symbol
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab digit
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab base
            pl.BlockSpec((T, K), lambda s: (0, 0)),          # tab is_esc
            pl.BlockSpec((n, B), lambda s: (0, 0)),          # x (whole)
        ],
        out_specs=pl.BlockSpec((1, lane_width, B), lambda s: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, lane_width, B), out_dtype),
        interpret=interpret,
    )(stream, esc, ns, nnz, *tabs, x)
