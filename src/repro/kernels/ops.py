"""Public jit'd entry points for the kernels.

`spmv` is the user-facing  y = A x + y  on a CSR-dtANS matrix: it packs the
format once (cached on the object), moves tensors to device, and dispatches
to the fused Pallas kernel (interpret=True on CPU hosts, compiled on TPU).

Every single-vector entry point has a multi-RHS sibling (`spmm`,
`sell_spmm`, `rgcsr_spmm`, `bcsr_spmm`): ``x`` is (n, B), the result
(m, B), and the matrix (for the dtANS family: the *decode*) is paid once
for all B columns — the batched serving path `SparseLinear.apply`
routes through. All eight share the ``(mat, x, y=None, *, interpret=)``
signature; B == 1 delegates to the single-vector kernel, so spmm results
at B=1 are bit-identical to spmv.

`spmv` / `spmm` additionally take ``mesh=`` / ``n_shards=``: with more
than one shard the matrix is row-partitioned along decode-slice
boundaries (`repro.sparse.shard`, cached on the object like the packed
artifact) and executed by `repro.kernels.shard_ops` — `shard_map` +
psum over the mesh ``model`` axis, or a sequential per-shard loop when
no mesh is given.  Results are bit-identical to the single-device
kernels at every shard count, and shards == 1 IS the single-device
path (no plan is built).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.csr_dtans import CSRdtANS
from repro.kernels import tiling
from repro.kernels.bcsr_spmv import (PackedBCSR, bcsr_spmm_pallas,
                                     bcsr_spmv_pallas)
from repro.kernels.dtans_decode import dtans_decode_pallas
from repro.kernels.dtans_spmv import dtans_spmm_pallas, dtans_spmv_pallas
from repro.kernels.pack import PackedMatrix, pack_matrix
from repro.kernels.rgcsr_spmv import (PackedRGCSR, rgcsr_spmm_pallas,
                                      rgcsr_spmv_pallas)
from repro.kernels.sell_spmv import (PackedSELL, sell_spmm_pallas,
                                     sell_spmv_pallas)

_PACK_CACHE_FIELD = "_packed_cache"
_OBS_NBYTES_FIELD = "_obs_nbytes"
_SHARD_PLAN_FIELD = "_shard_plans"


def _packed_nbytes(pm) -> int:
    """Total bytes of every ndarray field of a packed artifact — the
    matrix-side traffic one kernel pass DMAs (padded kernel-ready
    tensors, not the compressed wire size; the kernels move whole
    padded slices exactly like the paper's cache-line DMA). Memoized on
    the object: the hot path must not re-walk fields per call."""
    b = getattr(pm, _OBS_NBYTES_FIELD, None)
    if b is None:
        b = sum(int(v.nbytes) for v in vars(pm).values()
                if isinstance(v, np.ndarray))
        object.__setattr__(pm, _OBS_NBYTES_FIELD, b)
    return b


def _record_pass(kind: str, pm, n: int, m: int, batch: int,
                 itemsize: int, *, decodes: bool = False,
                 col_tiles: int = 1) -> None:
    """One SpMV/SpMM pass into the default metrics registry: call and
    byte counters (matrix once per pass, x/y per RHS) plus the
    batch-size histogram. `spmm` entry points delegate B == 1 to their
    spmv sibling, so exactly one record happens per pass.

    The byte counters are PER PASS, never per column tile: a blocked
    pass (``col_tiles > 1``) records x/y bytes exactly once — each RHS
    column still enters and leaves the chip once however the B axis is
    tiled — so tiled and untiled runs of the same workload stay
    byte-comparable.  The tile count itself lands in its own
    histogram (the re-streamed matrix traffic a tiled pass pays is
    what the cost model's ``col_tiles`` term prices)."""
    r = obs.default_registry()
    r.counter("kernels.spmm_calls").add(1)
    r.counter(f"kernels.{kind}_calls").add(1)
    if decodes:
        r.counter("kernels.decode_invocations").add(1)
    r.counter("kernels.matrix_bytes").add(_packed_nbytes(pm))
    r.counter("kernels.x_bytes").add(n * batch * itemsize)
    r.counter("kernels.y_bytes").add(m * batch * itemsize)
    r.histogram("kernels.batch_size").observe(batch)
    r.histogram("kernels.col_tiles").observe(col_tiles)


def _resolve_bn(n: int, rows: int, batch: int, itemsize: int,
                bn, vmem_budget) -> int | None:
    """Effective column-tile width of one SpMM pass: an explicit ``bn``
    wins (clamped to untiled when it covers the whole batch); otherwise
    the VMEM-budget auto choice (`repro.kernels.tiling.choose_bn`,
    ``vmem_budget=None`` = the default budget)."""
    if bn is not None:
        b = int(bn)
        if b < 1:
            raise ValueError(f"bn must be >= 1; got {bn}")
        return None if b >= batch else b
    return tiling.choose_bn(n, rows, batch, itemsize, vmem_budget)


def _n_tiles(batch: int, bn: int | None) -> int:
    return 1 if bn is None else -(-batch // bn)


def out_dtype(pm: PackedMatrix):
    """Accumulator dtype of the decode kernels for a packed matrix."""
    return jnp.float64 if pm.dtype == np.float64 else jnp.float32


_out_dtype = out_dtype   # backwards-compatible alias


def get_packed(mat: CSRdtANS) -> PackedMatrix:
    pm = getattr(mat, _PACK_CACHE_FIELD, None)
    if pm is None:
        pm = pack_matrix(mat)
        object.__setattr__(mat, _PACK_CACHE_FIELD, pm)
    return pm


def _tabs(pm: PackedMatrix):
    return (jnp.asarray(pm.tab_symbol), jnp.asarray(pm.tab_digit),
            jnp.asarray(pm.tab_base), jnp.asarray(pm.tab_is_esc))


def _resolve_shards(mesh, n_shards) -> int:
    """Shard count from the (mesh=, n_shards=) knobs: an explicit
    ``n_shards`` wins, else the mesh ``model`` axis, else 1."""
    if n_shards is not None:
        if int(n_shards) < 1:
            raise ValueError(f"n_shards must be >= 1; got {n_shards}")
        return int(n_shards)
    if mesh is not None:
        from repro.launch.mesh import model_axis_size
        return model_axis_size(mesh)
    return 1


def get_shard_plan(mat: CSRdtANS, n_shards: int):
    """The ``n_shards``-way shard plan for a CSR-dtANS matrix, built
    through the registry seam at the matrix's own encode knobs and
    cached on the object (one plan per shard count) like `get_packed`.
    Decode is lossless, so re-encoding each row block at the same
    ``lane_width`` reproduces the single-device decode values exactly."""
    plans = getattr(mat, _SHARD_PLAN_FIELD, None)
    if plans is None:
        plans = {}
        object.__setattr__(mat, _SHARD_PLAN_FIELD, plans)
    plan = plans.get(n_shards)
    if plan is None:
        from repro.core.csr_dtans import decode_matrix
        from repro.sparse.registry import get_format
        plan = get_format("dtans").shard(
            decode_matrix(mat), n_shards, params=mat.params,
            lane_width=mat.lane_width,
            shared_table=len(mat.tables) == 1)
        plans[n_shards] = plan
    return plan


def _sharded_dtans(mat, x, y, *, mesh, k, interpret, spmm: bool,
                   bn=None, pipeline: bool = False):
    from repro.kernels import shard_ops
    if not isinstance(mat, CSRdtANS):
        raise TypeError(
            "sharded spmv/spmm needs the CSRdtANS matrix (a bare packed "
            "artifact carries no bitstream to re-partition); pass the "
            "matrix object or shards=1")
    plan = get_shard_plan(mat, k)
    if spmm:
        return shard_ops.shard_spmm(plan, x, y=y, mesh=mesh,
                                    interpret=interpret, bn=bn,
                                    pipeline=pipeline)
    return shard_ops.shard_spmv(plan, x, y=y, mesh=mesh,
                                interpret=interpret, pipeline=pipeline)


def _resolve_fused(pm: PackedMatrix, fused) -> bool:
    """Whether this pass runs the shared-column (fused block-decode)
    contraction: ``fused=None`` follows the pack's ``shared_cols``
    flag (BCSR-dtANS encodes fuse, everything else doesn't);
    ``fused=False`` forces the generic path (the benchmark comparator);
    ``fused=True`` on a non-block-filled pack is an error — lanes with
    distinct columns cannot share lane 0's gather."""
    shared = bool(getattr(pm, "shared_cols", False))
    if fused is None:
        return shared
    if fused and not shared:
        raise ValueError(
            "fused=True needs a block-filled (shared-column) pack — "
            "only BCSR-dtANS encodes set PackedMatrix.shared_cols")
    return bool(fused)


def spmv(mat: CSRdtANS | PackedMatrix, x, y=None, *,
         interpret: bool = True, mesh=None, n_shards=None,
         pipeline: bool = False, fused=None) -> jax.Array:
    """y = A x + y with on-the-fly dtANS decoding (fused Pallas kernel).

    With ``mesh=`` (model axis > 1) or ``n_shards= > 1`` the matrix is
    row-partitioned along decode-slice boundaries and each device
    decodes only its shard (`repro.kernels.shard_ops`); results stay
    bit-identical to the single-device kernel.

    ``pipeline=True`` overlaps each segment's decode with the previous
    segment's contraction; ``fused`` selects the shared-column
    block-decode contraction (default: the pack's own ``shared_cols``
    flag).  Both preserve bit-identity (docs/kernels.md)."""
    k = _resolve_shards(mesh, n_shards)
    if k > 1:
        return _sharded_dtans(mat, x, y, mesh=mesh, k=k,
                              interpret=interpret, spmm=False,
                              pipeline=pipeline)
    pm = get_packed(mat) if isinstance(mat, CSRdtANS) else mat
    shared = _resolve_fused(pm, fused)
    dt = _out_dtype(pm)
    m, n = pm.shape
    _record_pass("dtans_spmv", pm, n, m, 1, pm.dtype.itemsize,
                 decodes=True)
    x = jnp.asarray(x, dtype=dt)
    acc = dtans_spmv_pallas(
        jnp.asarray(pm.stream), jnp.asarray(pm.esc), jnp.asarray(pm.ns),
        jnp.asarray(pm.nnz), _tabs(pm), x,
        params=pm.params, pattern=pm.pattern, max_nseg=pm.max_nseg,
        lane_width=pm.lane_width, out_dtype=dt, interpret=interpret,
        pipeline=pipeline, shared_cols=shared)
    out = acc.reshape(-1)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=dt)
    return out


def _check_rhs(x, n: int) -> None:
    if x.ndim != 2:
        raise ValueError(f"spmm expects x of shape (n, B); got {x.shape} "
                         f"(use spmv for a single 1-D vector)")
    if x.shape[0] != n:
        raise ValueError(f"spmm rhs has {x.shape[0]} rows; matrix has "
                         f"{n} columns")


def _empty_y(m: int, y, dt):
    """B == 0 result: a serving pool with zero active requests is a
    legal input and must not reach the kernels (a zero-size grid
    dimension is not)."""
    out = jnp.zeros((m, 0), dtype=dt)
    if y is not None:
        out = out + jnp.asarray(y, dtype=dt)
    return out


def spmm(mat: CSRdtANS | PackedMatrix, x, y=None, *,
         interpret: bool = True, mesh=None, n_shards=None,
         bn=None, vmem_budget=None, tile_mode: str = "auto",
         pipeline: bool = False, fused=None) -> jax.Array:
    """Y = A X + Y, X: (n, B) — decode once, contract all B columns in
    the fused kernel. B == 1 runs the single-vector `spmv` kernel, so
    the results are bit-identical to it.  ``mesh=`` / ``n_shards=``
    shard the rows across devices exactly as in `spmv`.

    Tiling knobs (docs/kernels.md): ``bn`` pins the column-tile width
    (None = auto from ``vmem_budget``, untiled when the whole batch
    fits); ``tile_mode`` picks the blocked schedule (``"grid"`` = 2-D
    pallas grid, ``"loop"`` = lax.map column loop, ``"auto"`` = loop
    under interpret / grid compiled); ``pipeline`` overlaps decode with
    contraction; ``fused`` selects the shared-column block-decode
    contraction.  Every combination is bit-identical to the untiled
    serial kernel — the conformance suite pins them with exact ==."""
    k = _resolve_shards(mesh, n_shards)
    if k > 1:
        return _sharded_dtans(mat, x, y, mesh=mesh, k=k,
                              interpret=interpret, spmm=True, bn=bn,
                              pipeline=pipeline)
    pm = get_packed(mat) if isinstance(mat, CSRdtANS) else mat
    shared = _resolve_fused(pm, fused)
    dt = _out_dtype(pm)
    m, n = pm.shape
    x = jnp.asarray(x, dtype=dt)
    _check_rhs(x, n)
    if x.shape[1] == 0:
        return _empty_y(m, y, dt)
    if x.shape[1] == 1:
        out = spmv(pm, x[:, 0], interpret=interpret, pipeline=pipeline,
                   fused=fused)[:, None]
    else:
        B = x.shape[1]
        bn_eff = _resolve_bn(n, pm.lane_width, B, pm.dtype.itemsize,
                             bn, vmem_budget)
        _record_pass("dtans_spmm", pm, n, m, B, pm.dtype.itemsize,
                     decodes=True, col_tiles=_n_tiles(B, bn_eff))
        acc = dtans_spmm_pallas(
            jnp.asarray(pm.stream), jnp.asarray(pm.esc), jnp.asarray(pm.ns),
            jnp.asarray(pm.nnz), _tabs(pm), x,
            params=pm.params, pattern=pm.pattern, max_nseg=pm.max_nseg,
            lane_width=pm.lane_width, out_dtype=dt, interpret=interpret,
            bn=bn_eff, tile_mode=tile_mode, pipeline=pipeline,
            shared_cols=shared)
        out = acc.reshape(-1, B)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=dt)
    return out


def decode(mat: CSRdtANS | PackedMatrix, *, interpret: bool = True):
    """Decompress to padded (S, L, max_nnz) (cols, vals); cols==-1 pads."""
    pm = get_packed(mat) if isinstance(mat, CSRdtANS) else mat
    dt = _out_dtype(pm)
    obs.default_registry().counter("kernels.decode_invocations").add(1)
    return dtans_decode_pallas(
        jnp.asarray(pm.stream), jnp.asarray(pm.esc), jnp.asarray(pm.ns),
        jnp.asarray(pm.nnz), _tabs(pm),
        params=pm.params, pattern=pm.pattern, max_nseg=pm.max_nseg,
        lane_width=pm.lane_width, out_dtype=dt, interpret=interpret)


def sell_spmv(ps: PackedSELL, x, y=None, *,
              interpret: bool = True) -> jax.Array:
    """Baseline SELL SpMVM: y = A x + y.

    Same ``(mat, x, y=None)`` signature as `spmv` / `rgcsr_spmv` — the
    timing harness (`repro.autotune.measure`) and the conformance suite
    drive all three entry points interchangeably."""
    m, _ = ps.shape
    _record_pass("sell_spmv", ps, ps.shape[1], m, 1,
                 ps.values.dtype.itemsize)
    acc = sell_spmv_pallas(jnp.asarray(ps.indices), jnp.asarray(ps.values),
                           jnp.asarray(x, dtype=ps.values.dtype),
                           interpret=interpret)
    out = acc.reshape(-1)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out


def sell_spmm(ps: PackedSELL, x, y=None, *, interpret: bool = True,
              bn=None, vmem_budget=None,
              tile_mode: str = "auto") -> jax.Array:
    """Multi-RHS SELL: Y = A X + Y, X: (n, B). Shares the `spmm`
    signature; B == 1 delegates to `sell_spmv` (bit-identical).
    ``bn`` / ``vmem_budget`` / ``tile_mode`` column-tile the B axis
    exactly as in `spmm` (bit-identical at every tile width)."""
    m, n = ps.shape
    x = jnp.asarray(x, dtype=ps.values.dtype)
    _check_rhs(x, n)
    if x.shape[1] == 0:
        return _empty_y(m, y, x.dtype)
    if x.shape[1] == 1:
        out = sell_spmv(ps, x[:, 0], interpret=interpret)[:, None]
    else:
        B = x.shape[1]
        bn_eff = _resolve_bn(n, ps.lane_width, B,
                             ps.values.dtype.itemsize, bn, vmem_budget)
        _record_pass("sell_spmm", ps, n, m, B,
                     ps.values.dtype.itemsize,
                     col_tiles=_n_tiles(B, bn_eff))
        acc = sell_spmm_pallas(jnp.asarray(ps.indices),
                               jnp.asarray(ps.values), x,
                               interpret=interpret, bn=bn_eff,
                               tile_mode=tile_mode)
        out = acc.reshape(-1, B)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out


def rgcsr_spmv(pr: PackedRGCSR, x, y=None, *,
               interpret: bool = True) -> jax.Array:
    """Row-grouped CSR SpMVM: y = A x + y (delta prefix-sum in kernel).

    Shares the `spmv` / `sell_spmv` signature; see `sell_spmv`."""
    m, _ = pr.shape
    _record_pass("rgcsr_spmv", pr, pr.shape[1], m, 1,
                 pr.values.dtype.itemsize)
    acc = rgcsr_spmv_pallas(jnp.asarray(pr.deltas), jnp.asarray(pr.values),
                            jnp.asarray(pr.nnz),
                            jnp.asarray(x, dtype=pr.values.dtype),
                            interpret=interpret)
    out = acc.reshape(-1)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out


def rgcsr_spmm(pr: PackedRGCSR, x, y=None, *, interpret: bool = True,
               bn=None, vmem_budget=None,
               tile_mode: str = "auto") -> jax.Array:
    """Multi-RHS RGCSR: Y = A X + Y, X: (n, B). Shares the `spmm`
    signature; B == 1 delegates to `rgcsr_spmv` (bit-identical).
    ``bn`` / ``vmem_budget`` / ``tile_mode`` column-tile the B axis
    exactly as in `spmm` (bit-identical at every tile width)."""
    m, n = pr.shape
    x = jnp.asarray(x, dtype=pr.values.dtype)
    _check_rhs(x, n)
    if x.shape[1] == 0:
        return _empty_y(m, y, x.dtype)
    if x.shape[1] == 1:
        out = rgcsr_spmv(pr, x[:, 0], interpret=interpret)[:, None]
    else:
        B = x.shape[1]
        bn_eff = _resolve_bn(n, pr.group_size, B,
                             pr.values.dtype.itemsize, bn, vmem_budget)
        _record_pass("rgcsr_spmm", pr, n, m, B,
                     pr.values.dtype.itemsize,
                     col_tiles=_n_tiles(B, bn_eff))
        acc = rgcsr_spmm_pallas(jnp.asarray(pr.deltas),
                                jnp.asarray(pr.values),
                                jnp.asarray(pr.nnz), x,
                                interpret=interpret, bn=bn_eff,
                                tile_mode=tile_mode)
        out = acc.reshape(-1, B)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out


def bcsr_spmv(pb: PackedBCSR, x, y=None, *,
              interpret: bool = True) -> jax.Array:
    """Blocked-CSR SpMVM: y = A x + y (dense r x c tiles in kernel).

    Shares the `spmv` / `sell_spmv` signature; see `sell_spmv`."""
    m, _ = pb.shape
    _record_pass("bcsr_spmv", pb, pb.shape[1], m, 1,
                 pb.values.dtype.itemsize)
    acc = bcsr_spmv_pallas(jnp.asarray(pb.block_cols),
                           jnp.asarray(pb.values),
                           jnp.asarray(x, dtype=pb.values.dtype),
                           interpret=interpret)
    out = acc.reshape(-1)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out


def bcsr_spmm(pb: PackedBCSR, x, y=None, *, interpret: bool = True,
              bn=None, vmem_budget=None,
              tile_mode: str = "auto") -> jax.Array:
    """Multi-RHS BCSR: Y = A X + Y, X: (n, B). Shares the `spmm`
    signature; B == 1 delegates to `bcsr_spmv` (bit-identical).
    ``bn`` / ``vmem_budget`` / ``tile_mode`` column-tile the B axis
    exactly as in `spmm` (bit-identical at every tile width)."""
    m, n = pb.shape
    x = jnp.asarray(x, dtype=pb.values.dtype)
    _check_rhs(x, n)
    if x.shape[1] == 0:
        return _empty_y(m, y, x.dtype)
    if x.shape[1] == 1:
        out = bcsr_spmv(pb, x[:, 0], interpret=interpret)[:, None]
    else:
        B = x.shape[1]
        bn_eff = _resolve_bn(n, pb.block_shape[0], B,
                             pb.values.dtype.itemsize, bn, vmem_budget)
        _record_pass("bcsr_spmm", pb, n, m, B,
                     pb.values.dtype.itemsize,
                     col_tiles=_n_tiles(B, bn_eff))
        acc = bcsr_spmm_pallas(jnp.asarray(pb.block_cols),
                               jnp.asarray(pb.values), x,
                               interpret=interpret, bn=bn_eff,
                               tile_mode=tile_mode)
        out = acc.reshape(-1, B)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out
