"""Public jit'd entry points for the kernels.

`spmv` is the user-facing  y = A x + y  on a CSR-dtANS matrix: it packs the
format once (cached on the object), moves tensors to device, and dispatches
to the fused Pallas kernel (interpret=True on CPU hosts, compiled on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr_dtans import CSRdtANS
from repro.kernels.bcsr_spmv import PackedBCSR, bcsr_spmv_pallas
from repro.kernels.dtans_decode import dtans_decode_pallas
from repro.kernels.dtans_spmv import dtans_spmv_pallas
from repro.kernels.pack import PackedMatrix, pack_matrix
from repro.kernels.rgcsr_spmv import PackedRGCSR, rgcsr_spmv_pallas
from repro.kernels.sell_spmv import PackedSELL, sell_spmv_pallas

_PACK_CACHE_FIELD = "_packed_cache"


def out_dtype(pm: PackedMatrix):
    """Accumulator dtype of the decode kernels for a packed matrix."""
    return jnp.float64 if pm.dtype == np.float64 else jnp.float32


_out_dtype = out_dtype   # backwards-compatible alias


def get_packed(mat: CSRdtANS) -> PackedMatrix:
    pm = getattr(mat, _PACK_CACHE_FIELD, None)
    if pm is None:
        pm = pack_matrix(mat)
        object.__setattr__(mat, _PACK_CACHE_FIELD, pm)
    return pm


def _tabs(pm: PackedMatrix):
    return (jnp.asarray(pm.tab_symbol), jnp.asarray(pm.tab_digit),
            jnp.asarray(pm.tab_base), jnp.asarray(pm.tab_is_esc))


def spmv(mat: CSRdtANS | PackedMatrix, x, y=None, *,
         interpret: bool = True) -> jax.Array:
    """y = A x + y with on-the-fly dtANS decoding (fused Pallas kernel)."""
    pm = get_packed(mat) if isinstance(mat, CSRdtANS) else mat
    dt = _out_dtype(pm)
    m, n = pm.shape
    x = jnp.asarray(x, dtype=dt)
    acc = dtans_spmv_pallas(
        jnp.asarray(pm.stream), jnp.asarray(pm.esc), jnp.asarray(pm.ns),
        jnp.asarray(pm.nnz), _tabs(pm), x,
        params=pm.params, pattern=pm.pattern, max_nseg=pm.max_nseg,
        lane_width=pm.lane_width, out_dtype=dt, interpret=interpret)
    out = acc.reshape(-1)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=dt)
    return out


def decode(mat: CSRdtANS | PackedMatrix, *, interpret: bool = True):
    """Decompress to padded (S, L, max_nnz) (cols, vals); cols==-1 pads."""
    pm = get_packed(mat) if isinstance(mat, CSRdtANS) else mat
    dt = _out_dtype(pm)
    return dtans_decode_pallas(
        jnp.asarray(pm.stream), jnp.asarray(pm.esc), jnp.asarray(pm.ns),
        jnp.asarray(pm.nnz), _tabs(pm),
        params=pm.params, pattern=pm.pattern, max_nseg=pm.max_nseg,
        lane_width=pm.lane_width, out_dtype=dt, interpret=interpret)


def sell_spmv(ps: PackedSELL, x, y=None, *,
              interpret: bool = True) -> jax.Array:
    """Baseline SELL SpMVM: y = A x + y.

    Same ``(mat, x, y=None)`` signature as `spmv` / `rgcsr_spmv` — the
    timing harness (`repro.autotune.measure`) and the conformance suite
    drive all three entry points interchangeably."""
    m, _ = ps.shape
    acc = sell_spmv_pallas(jnp.asarray(ps.indices), jnp.asarray(ps.values),
                           jnp.asarray(x, dtype=ps.values.dtype),
                           interpret=interpret)
    out = acc.reshape(-1)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out


def rgcsr_spmv(pr: PackedRGCSR, x, y=None, *,
               interpret: bool = True) -> jax.Array:
    """Row-grouped CSR SpMVM: y = A x + y (delta prefix-sum in kernel).

    Shares the `spmv` / `sell_spmv` signature; see `sell_spmv`."""
    m, _ = pr.shape
    acc = rgcsr_spmv_pallas(jnp.asarray(pr.deltas), jnp.asarray(pr.values),
                            jnp.asarray(pr.nnz),
                            jnp.asarray(x, dtype=pr.values.dtype),
                            interpret=interpret)
    out = acc.reshape(-1)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out


def bcsr_spmv(pb: PackedBCSR, x, y=None, *,
              interpret: bool = True) -> jax.Array:
    """Blocked-CSR SpMVM: y = A x + y (dense r x c tiles in kernel).

    Shares the `spmv` / `sell_spmv` signature; see `sell_spmv`."""
    m, _ = pb.shape
    acc = bcsr_spmv_pallas(jnp.asarray(pb.block_cols),
                           jnp.asarray(pb.values),
                           jnp.asarray(x, dtype=pb.values.dtype),
                           interpret=interpret)
    out = acc.reshape(-1)[:m]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out
