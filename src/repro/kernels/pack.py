"""Pack a CSRdtANS matrix into dense, kernel-ready tensors.

The production format stores one flat stream with per-slice offsets. The
Pallas kernel wants *static* block shapes, so we pad every slice's stream
(and escape stream) to the matrix-wide maximum and expose them as
(n_slices, max_*) tensors. The padding is address padding only — it is NOT
counted in the format's compressed size (CSRdtANS.nbytes), exactly like the
paper's kernels, which DMA whole cache lines regardless of stream length.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.csr_dtans import CSRdtANS
from repro.core.params import DtansParams


@dataclasses.dataclass
class PackedMatrix:
    """Kernel-ready CSR-dtANS. All arrays are numpy; ops.py moves to jnp."""
    stream: np.ndarray      # (S, Wmax) uint64 (< 2^32)
    esc: np.ndarray         # (T, S, Emax) uint64
    ns: np.ndarray          # (S, L) int32 — symbols per lane (2*nnz)
    nnz: np.ndarray         # (S, L) int32 — nonzeros per lane
    row_valid: np.ndarray   # (S, L) bool — lane maps to a real row
    tab_symbol: np.ndarray  # (T, K) uint64
    tab_digit: np.ndarray   # (T, K) int32
    tab_base: np.ndarray    # (T, K) int32
    tab_is_esc: np.ndarray  # (T, K) int32 (0/1)
    pattern: tuple          # static, length l
    params: DtansParams     # static
    shape: tuple
    dtype: np.dtype
    lane_width: int
    max_nseg: int           # static loop bound
    # Block-filled encode (BCSR-dtANS at lane_width == block height):
    # every in-bounds lane of a slice decodes the SAME column sequence,
    # so the fused shared-column contraction applies (ops.spmv/spmm
    # ``fused=`` knob; see dtans_spmv.py ``shared_cols``).
    shared_cols: bool = False

    @property
    def n_slices(self) -> int:
        return int(self.stream.shape[0])


def pack_matrix(mat: CSRdtANS) -> PackedMatrix:
    S = mat.n_slices
    L = mat.lane_width
    T = len(mat.tables)
    l = mat.params.l
    m = mat.shape[0]

    w_lens = np.diff(mat.slice_offsets)
    Wmax = max(int(w_lens.max()) if S else 0, 1)
    stream = np.zeros((S, Wmax), dtype=np.uint64)
    for s in range(S):
        lo, hi = mat.slice_offsets[s], mat.slice_offsets[s + 1]
        stream[s, :hi - lo] = mat.stream[lo:hi]

    e_lens = np.diff(mat.esc_offsets, axis=0)  # (S, T)
    Emax = max(int(e_lens.max()) if S else 0, 1)
    esc = np.zeros((T, S, Emax), dtype=np.uint64)
    for t in range(T):
        for s in range(S):
            lo, hi = mat.esc_offsets[s, t], mat.esc_offsets[s + 1, t]
            esc[t, s, :hi - lo] = mat.esc_streams[t][lo:hi]

    nnz = np.zeros((S, L), dtype=np.int32)
    row_valid = np.zeros((S, L), dtype=bool)
    for s in range(S):
        r0, r1 = s * L, min((s + 1) * L, m)
        nnz[s, :r1 - r0] = mat.row_nnz[r0:r1]
        row_valid[s, :r1 - r0] = True
    ns = 2 * nnz

    nsegs = (ns + l - 1) // l
    max_nseg = max(int(nsegs.max()) if S else 0, 1)

    return PackedMatrix(
        stream=stream,
        esc=esc,
        ns=ns.astype(np.int32),
        nnz=nnz,
        row_valid=row_valid,
        tab_symbol=mat.stacked.symbol.astype(np.uint64),
        tab_digit=mat.stacked.digit.astype(np.int32),
        tab_base=mat.stacked.base.astype(np.int32),
        tab_is_esc=mat.stacked.is_esc.astype(np.int32),
        pattern=tuple(int(p) for p in mat.pattern),
        params=mat.params,
        shape=mat.shape,
        dtype=np.dtype(mat.dtype),
        lane_width=L,
        max_nseg=max_nseg,
        # BCSRdtANS (the only block-filled encode) carries block_shape;
        # duck-typed so pack.py needs no core.bcsr_dtans import.
        shared_cols=getattr(mat, "block_shape", None) is not None,
    )
