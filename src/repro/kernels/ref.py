"""Pure-jnp oracles for the dtANS kernels (no Pallas).

`spmv_ref` / `decode_ref` vmap the shared lock-step segment decoder over
slices. They are themselves validated against the numpy gold path
(`repro.core.csr_dtans.spmv_gold`), which in turn is validated against the
scalar big-int codec — a three-deep oracle chain.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import (DecodeArrays, bits_to_value, init_state,
                                  segment_step)
from repro.kernels.pack import PackedMatrix


def _slice_spmv(stream, esc, ns, nnz, tabs, x, *, params, pattern,
                max_nseg, out_dtype):
    arr = DecodeArrays(stream=stream, esc=esc, tab_symbol=tabs[0],
                       tab_digit=tabs[1], tab_base=tabs[2],
                       tab_is_esc=tabs[3], ns=ns, nnz=nnz)
    state = init_state(arr, params)
    L = ns.shape[0]
    n = x.shape[0]
    acc0 = jnp.zeros((L,), dtype=out_dtype)

    def body(j, carry):
        state, acc = carry
        state, cols, vbits, valid = segment_step(j, state, arr, params,
                                                 pattern)
        vals = bits_to_value(vbits, out_dtype)
        xg = jnp.take(x, jnp.clip(cols, 0, n - 1), axis=0)
        acc = acc + jnp.sum(jnp.where(valid, vals * xg, 0), axis=0)
        return state, acc

    _, acc = jax.lax.fori_loop(0, max_nseg, body, (state, acc0))
    return acc


def _slice_decode(stream, esc, ns, nnz, tabs, *, params, pattern, max_nseg,
                  out_dtype):
    arr = DecodeArrays(stream=stream, esc=esc, tab_symbol=tabs[0],
                       tab_digit=tabs[1], tab_base=tabs[2],
                       tab_is_esc=tabs[3], ns=ns, nnz=nnz)
    state = init_state(arr, params)
    L = ns.shape[0]
    h = params.l // 2
    cols0 = jnp.zeros((L, max_nseg * h), dtype=jnp.int32)
    vals0 = jnp.zeros((L, max_nseg * h), dtype=out_dtype)

    def body(j, carry):
        state, cols_out, vals_out = carry
        state, cols, vbits, valid = segment_step(j, state, arr, params,
                                                 pattern)
        vals = bits_to_value(vbits, out_dtype)
        cols_blk = jnp.where(valid, cols, -1).astype(jnp.int32).T  # (L, h)
        vals_blk = jnp.where(valid, vals, 0).T
        cols_out = jax.lax.dynamic_update_slice(cols_out, cols_blk,
                                                (0, j * h))
        vals_out = jax.lax.dynamic_update_slice(vals_out, vals_blk,
                                                (0, j * h))
        return state, cols_out, vals_out

    _, cols, vals = jax.lax.fori_loop(0, max_nseg, body,
                                      (state, cols0, vals0))
    return cols, vals


def _tabs(pm: PackedMatrix):
    return (jnp.asarray(pm.tab_symbol), jnp.asarray(pm.tab_digit),
            jnp.asarray(pm.tab_base), jnp.asarray(pm.tab_is_esc))


@functools.partial(jax.jit, static_argnames=("params", "pattern",
                                             "max_nseg", "out_dtype"))
def _spmv_ref_jit(stream, esc, ns, nnz, tabs, x, y, *, params, pattern,
                  max_nseg, out_dtype):
    f = functools.partial(_slice_spmv, tabs=tabs, x=x, params=params,
                          pattern=pattern, max_nseg=max_nseg,
                          out_dtype=out_dtype)
    acc = jax.vmap(f)(stream, esc.transpose(1, 0, 2), ns, nnz)  # (S, L)
    return y + acc.reshape(-1)[:y.shape[0]]


def spmv_ref(pm: PackedMatrix, x: np.ndarray,
             y: np.ndarray | None = None) -> jax.Array:
    """Oracle y = A x + y with on-the-fly dtANS decode (pure jnp)."""
    out_dtype = jnp.float64 if pm.dtype == np.float64 else jnp.float32
    m, n = pm.shape
    if y is None:
        y = jnp.zeros((m,), dtype=out_dtype)
    return _spmv_ref_jit(
        jnp.asarray(pm.stream), jnp.asarray(pm.esc), jnp.asarray(pm.ns),
        jnp.asarray(pm.nnz), _tabs(pm), jnp.asarray(x, dtype=out_dtype),
        jnp.asarray(y, dtype=out_dtype),
        params=pm.params, pattern=pm.pattern, max_nseg=pm.max_nseg,
        out_dtype=out_dtype)


def decode_ref(pm: PackedMatrix) -> tuple[jax.Array, jax.Array]:
    """Oracle decompression: (cols, vals) as (S, L, max_nnz) padded arrays
    (cols == -1 marks padding)."""
    out_dtype = jnp.float64 if pm.dtype == np.float64 else jnp.float32
    f = functools.partial(_slice_decode, tabs=_tabs(pm), params=pm.params,
                          pattern=pm.pattern, max_nseg=pm.max_nseg,
                          out_dtype=out_dtype)
    return jax.jit(jax.vmap(f))(
        jnp.asarray(pm.stream), jnp.asarray(pm.esc).transpose(1, 0, 2),
        jnp.asarray(pm.ns), jnp.asarray(pm.nnz))
