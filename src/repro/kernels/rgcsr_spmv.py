"""RGCSR SpMVM Pallas kernel (interpret-mode first, like sell_spmv).

One program per row group of G rows. The group's delta streams live in
VMEM as a (G, Wg) block (Wg = matrix-wide max row nnz — address padding
only, not counted in `RGCSR.nbytes`, exactly like `pack.py`'s stream
padding); the kernel reconstructs absolute columns with a per-row prefix
sum over the deltas, gathers x, and reduces. Compared to the SELL
kernel, the in-kernel extra work is one add per stored element (the
delta prefix-sum) — the `spmv_ops_per_elem` the cost model charges —
while the *stored* bytes carry no per-slice padding.

Structure mirrors `sell_spmv.py`: a dataclass pack product, a Pallas
kernel over a 1-D group grid, and a pure-jnp oracle for tests.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.tiling import blocked_spmm
from repro.sparse.rgcsr import RGCSR


@dataclasses.dataclass
class PackedRGCSR:
    deltas: np.ndarray    # (S, G, Wg) int32 delta streams, 0 = padding
    values: np.ndarray    # (S, G, Wg)
    nnz: np.ndarray       # (S, G) int32 — real entries per row
    shape: tuple
    group_size: int


def pack_rgcsr(r: RGCSR) -> PackedRGCSR:
    m, _ = r.shape
    G = r.group_size
    S = r.n_groups
    rnnz = r.row_nnz()
    Wg = max(int(rnnz.max()) if m else 0, 1)
    deltas = np.zeros((S, G, Wg), dtype=np.int32)
    values = np.zeros((S, G, Wg), dtype=r.values.dtype)
    nnz = np.zeros((S, G), dtype=np.int32)
    for g in range(S):
        base = int(r.group_ptr[g])
        for i in range(min(G, m - g * G)):
            lo = base + int(r.local_indptr[g, i])
            hi = base + int(r.local_indptr[g, i + 1])
            deltas[g, i, :hi - lo] = r.delta_indices[lo:hi]
            values[g, i, :hi - lo] = r.values[lo:hi]
            nnz[g, i] = hi - lo
    return PackedRGCSR(deltas=deltas, values=values, nnz=nnz,
                       shape=r.shape, group_size=G)


def _rgcsr_kernel(delta_ref, val_ref, nnz_ref, x_ref, y_ref):
    d = delta_ref[0]          # (G, Wg)
    v = val_ref[0]
    nnz = nnz_ref[0]          # (G,)
    x = x_ref[...]
    cols = jnp.cumsum(d, axis=1)          # per-row delta prefix-sum
    mask = (jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
            < nnz[:, None])
    xg = jnp.take(x, jnp.clip(cols, 0, x.shape[0] - 1), axis=0)
    y_ref[0, :] = jnp.sum(jnp.where(mask, v * xg, 0), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rgcsr_spmv_pallas(deltas, val, nnz, x, interpret=True):
    S, G, Wg = deltas.shape
    n = x.shape[0]
    return pl.pallas_call(
        _rgcsr_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, G, Wg), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, G, Wg), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, G), lambda s: (s, 0)),
            pl.BlockSpec((n,), lambda s: (0,)),
        ],
        out_specs=pl.BlockSpec((1, G), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, G), val.dtype),
        interpret=interpret,
    )(deltas, val, nnz, x)


def rgcsr_spmv_ref(deltas: np.ndarray, val: np.ndarray, nnz: np.ndarray,
                   x: np.ndarray):
    """Pure-jnp oracle for the RGCSR kernel ((S, G) output)."""
    x = jnp.asarray(x)
    cols = jnp.cumsum(deltas, axis=2)
    mask = (jax.lax.broadcasted_iota(jnp.int32, deltas.shape, 2)
            < nnz[..., None])
    xg = jnp.take(x, jnp.clip(cols, 0, x.shape[0] - 1), axis=0)
    return jnp.sum(jnp.where(mask, val * xg, 0), axis=2)


def _rgcsr_spmm_kernel(delta_ref, val_ref, nnz_ref, x_ref, y_ref):
    d = delta_ref[0]          # (G, Wg)
    v = val_ref[0]
    nnz = nnz_ref[0]          # (G,)
    x = x_ref[...]            # (n, B)
    cols = jnp.cumsum(d, axis=1)          # per-row delta prefix-sum
    mask = (jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
            < nnz[:, None])
    xg = jnp.take(x, jnp.clip(cols, 0, x.shape[0] - 1), axis=0)  # (G, Wg, B)
    contrib = jnp.where(mask[..., None], v[..., None] * xg, 0)
    y_ref[0, :, :] = jnp.sum(contrib, axis=1)                    # (G, B)


@functools.partial(jax.jit, static_argnames=("interpret", "bn",
                                             "tile_mode"))
def rgcsr_spmm_pallas(deltas, val, nnz, x, interpret=True, bn=None,
                      tile_mode="auto"):
    """Multi-RHS RGCSR kernel: x is (n, B); returns (S, G, B). The
    delta prefix-sum runs once per group and feeds all B columns.
    ``bn`` column-tiles the B axis (`repro.kernels.tiling`); blocked
    output is bitwise equal to the untiled kernel."""
    S, G, Wg = deltas.shape
    mat_specs = [
        ((1, G, Wg), lambda s: (s, 0, 0)),
        ((1, G, Wg), lambda s: (s, 0, 0)),
        ((1, G), lambda s: (s, 0)),
    ]
    return blocked_spmm(_rgcsr_spmm_kernel, (deltas, val, nnz),
                        mat_specs, x, rows=G, out_dtype=val.dtype,
                        grid_s=S, bn=bn, tile_mode=tile_mode,
                        interpret=interpret)
