"""SELL-style SpMVM Pallas baseline kernel (uncompressed comparator).

One program per slice of ``lane_width`` rows; the slice's (padded) indices
and values live in VMEM as (L, Wg) blocks, x is gathered per column step.
This is the "fastest cuSPARSE format" stand-in used by the benchmark
harness to compare against the fused dtANS kernel under the same roofline
model (both kernels are memory-bound; the ratio of bytes moved predicts the
speedup, Section V-B of the paper).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.tiling import blocked_spmm
from repro.sparse.formats import CSR


@dataclasses.dataclass
class PackedSELL:
    indices: np.ndarray   # (S, L, Wg) int32, -1 = padding
    values: np.ndarray    # (S, L, Wg)
    shape: tuple
    lane_width: int


def pack_sell(a: CSR, lane_width: int = 128) -> PackedSELL:
    m, _ = a.shape
    L = lane_width
    S = (m + L - 1) // L
    rnnz = np.diff(a.indptr)
    Wg = max(int(rnnz.max()) if m else 0, 1)
    idx = np.full((S, L, Wg), -1, dtype=np.int32)
    val = np.zeros((S, L, Wg), dtype=a.values.dtype)
    for i in range(m):
        s, lane = divmod(i, L)
        lo, hi = a.indptr[i], a.indptr[i + 1]
        idx[s, lane, :hi - lo] = a.indices[lo:hi]
        val[s, lane, :hi - lo] = a.values[lo:hi]
    return PackedSELL(indices=idx, values=val, shape=a.shape,
                      lane_width=L)


def _sell_kernel(idx_ref, val_ref, x_ref, y_ref):
    idx = idx_ref[0]          # (L, Wg)
    val = val_ref[0]
    x = x_ref[...]
    mask = idx >= 0
    xg = jnp.take(x, jnp.clip(idx, 0, x.shape[0] - 1), axis=0)
    y_ref[0, :] = jnp.sum(jnp.where(mask, val * xg, 0), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sell_spmv_pallas(idx, val, x, interpret=True):
    S, L, Wg = idx.shape
    n = x.shape[0]
    return pl.pallas_call(
        _sell_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, L, Wg), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, L, Wg), lambda s: (s, 0, 0)),
            pl.BlockSpec((n,), lambda s: (0,)),
        ],
        out_specs=pl.BlockSpec((1, L), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, L), val.dtype),
        interpret=interpret,
    )(idx, val, x)


def sell_spmv_ref(idx: np.ndarray, val: np.ndarray, x: np.ndarray):
    """Pure-jnp oracle for the SELL kernel."""
    mask = idx >= 0
    xg = jnp.take(jnp.asarray(x), jnp.clip(idx, 0, x.shape[0] - 1), axis=0)
    return jnp.sum(jnp.where(mask, val * xg, 0), axis=2)


def _sell_spmm_kernel(idx_ref, val_ref, x_ref, y_ref):
    idx = idx_ref[0]          # (L, Wg)
    val = val_ref[0]
    x = x_ref[...]            # (n, B)
    mask = idx >= 0
    xg = jnp.take(x, jnp.clip(idx, 0, x.shape[0] - 1), axis=0)  # (L, Wg, B)
    contrib = jnp.where(mask[..., None], val[..., None] * xg, 0)
    y_ref[0, :, :] = jnp.sum(contrib, axis=1)                   # (L, B)


@functools.partial(jax.jit, static_argnames=("interpret", "bn",
                                             "tile_mode"))
def sell_spmm_pallas(idx, val, x, interpret=True, bn=None,
                     tile_mode="auto"):
    """Multi-RHS SELL kernel: x is (n, B); returns (S, L, B) — the
    slice's indices/values load once and contract all B columns.
    ``bn`` column-tiles the B axis (`repro.kernels.tiling`); blocked
    output is bitwise equal to the untiled kernel."""
    S, L, Wg = idx.shape
    mat_specs = [
        ((1, L, Wg), lambda s: (s, 0, 0)),
        ((1, L, Wg), lambda s: (s, 0, 0)),
    ]
    return blocked_spmm(_sell_spmm_kernel, (idx, val), mat_specs, x,
                        rows=L, out_dtype=val.dtype, grid_s=S, bn=bn,
                        tile_mode=tile_mode, interpret=interpret)
