"""Execute a `repro.sparse.shard.ShardPlan` — `jax.shard_map` over the
mesh ``model`` axis, or a sequential per-shard loop without devices.

The multi-device contract (ROADMAP item 2): each device holds ONE
shard's packed artifact (its row block's bitstream / index arrays),
decodes and contracts it against a broadcast ``x``, and the per-device
partial ``y``'s — disjoint row ranges, zero elsewhere — reduce via
``psum`` into the replicated result.  Per-shard packed tensors are
zero-padded to the fleet-wide max block shape and stacked on a leading
``n_shards`` axis sharded over ``model`` (the same address-padding-only
trick as `pack.py`: padded slices carry ``ns == 0`` / column ``-1`` /
``nnz == 0`` and decode to nothing, and a row mask kills any residue
before the reduction).

Bit-identity: a shard's kernel is EXACTLY the single-device kernel on
its row block — decode is lossless and each row accumulates in column
order regardless of its neighbours — and the psum adds the true row
values to zeros, so sharded results equal the single-device results at
every shard count (conformance-pinned at shards in {1, 2, 4}).

The sequential loop path (``mesh=None``, or a packed type without a
registered adapter) runs each shard through the family's own
single-device runner and concatenates rows — every registered format,
third-party specs included, has a sharded path; the four kernel-backed
families additionally get the collective path via the adapters below.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels.bcsr_spmv import (PackedBCSR, bcsr_spmm_pallas,
                                     bcsr_spmv_pallas)
from repro.kernels.dtans_spmv import dtans_spmm_pallas, dtans_spmv_pallas
from repro.kernels.pack import PackedMatrix
from repro.kernels.rgcsr_spmv import (PackedRGCSR, rgcsr_spmm_pallas,
                                      rgcsr_spmv_pallas)
from repro.kernels.sell_spmv import (PackedSELL, sell_spmm_pallas,
                                     sell_spmv_pallas)


def _pad_stack(arrs, fill=0):
    """Stack ndarrays on a new leading axis, zero-padding every
    dimension to the fleet-wide max (address padding only — the padded
    region is masked in-kernel, exactly like `pack.py`)."""
    nd = arrs[0].ndim
    shape = tuple(max(int(a.shape[i]) for a in arrs) for i in range(nd))
    out = np.full((len(arrs),) + shape, fill, dtype=arrs[0].dtype)
    for k, a in enumerate(arrs):
        out[k][tuple(slice(0, s) for s in a.shape)] = a
    return out


# --------------------------------------------------------------------------
# Per-family adapters: stack per-shard packs + run one shard's kernel.
# ``stack`` -> (arrays, static, rows_cap, out_dtype); ``run`` takes the
# device-local (leading-axis-stripped) arrays and x: (n, B), returns a
# (rows_cap, B) partial.  B == 1 routes through the spmv kernel, the
# same delegation `ops.spmm` makes, so sharded spmv stays bit-identical
# to the single-device spmv kernel.
# --------------------------------------------------------------------------


def _stack_dtans(packs):
    p0 = packs[0]
    for p in packs:
        if (p.lane_width != p0.lane_width or p.params != p0.params
                or tuple(p.pattern) != tuple(p0.pattern)
                or p.esc.shape[0] != p0.esc.shape[0]
                or p.shared_cols != p0.shared_cols):
            raise ValueError("dtans shards disagree on static layout "
                             "(lane_width / params / tables)")
    arrays = [_pad_stack([p.stream for p in packs]),
              _pad_stack([p.esc for p in packs]),
              _pad_stack([p.ns for p in packs]),
              _pad_stack([p.nnz for p in packs]),
              _pad_stack([p.tab_symbol for p in packs]),
              _pad_stack([p.tab_digit for p in packs]),
              _pad_stack([p.tab_base for p in packs]),
              _pad_stack([p.tab_is_esc for p in packs])]
    dt = jnp.float64 if p0.dtype == np.float64 else jnp.float32
    static = dict(params=p0.params, pattern=tuple(p0.pattern),
                  lane_width=int(p0.lane_width),
                  max_nseg=max(int(p.max_nseg) for p in packs),
                  out_dtype=dt, shared_cols=bool(p0.shared_cols))
    return arrays, static, arrays[0].shape[1] * p0.lane_width, dt


def _run_dtans(arrs, x, st, interpret, tile):
    stream, esc, ns, nnz, sym, dig, base, isesc = arrs
    tabs = (sym, dig, base, isesc)
    kw = dict(params=st["params"], pattern=st["pattern"],
              max_nseg=st["max_nseg"], lane_width=st["lane_width"],
              out_dtype=st["out_dtype"], interpret=interpret,
              pipeline=tile["pipeline"],
              shared_cols=st["shared_cols"])
    if x.shape[1] == 1:
        acc = dtans_spmv_pallas(stream, esc, ns, nnz, tabs, x[:, 0], **kw)
        return acc.reshape(-1)[:, None]
    acc = dtans_spmm_pallas(stream, esc, ns, nnz, tabs, x, bn=tile["bn"],
                            tile_mode=tile["tile_mode"], **kw)
    return acc.reshape(-1, x.shape[1])


def _stack_sell(packs):
    p0 = packs[0]
    L = p0.lane_width
    if any(p.lane_width != L for p in packs):
        raise ValueError("sell shards disagree on slice_height")
    arrays = [_pad_stack([p.indices for p in packs], fill=-1),
              _pad_stack([p.values for p in packs])]
    return arrays, {}, arrays[0].shape[1] * L, p0.values.dtype


def _run_sell(arrs, x, st, interpret, tile):
    idx, val = arrs
    if x.shape[1] == 1:
        return sell_spmv_pallas(idx, val, x[:, 0],
                                interpret=interpret).reshape(-1)[:, None]
    return sell_spmm_pallas(idx, val, x, interpret=interpret,
                            bn=tile["bn"], tile_mode=tile["tile_mode"]
                            ).reshape(-1, x.shape[1])


def _stack_rgcsr(packs):
    p0 = packs[0]
    G = p0.group_size
    if any(p.group_size != G for p in packs):
        raise ValueError("rgcsr shards disagree on group_size")
    arrays = [_pad_stack([p.deltas for p in packs]),
              _pad_stack([p.values for p in packs]),
              _pad_stack([p.nnz for p in packs])]
    return arrays, {}, arrays[0].shape[1] * G, p0.values.dtype


def _run_rgcsr(arrs, x, st, interpret, tile):
    deltas, val, nnz = arrs
    if x.shape[1] == 1:
        return rgcsr_spmv_pallas(deltas, val, nnz, x[:, 0],
                                 interpret=interpret
                                 ).reshape(-1)[:, None]
    return rgcsr_spmm_pallas(deltas, val, nnz, x, interpret=interpret,
                             bn=tile["bn"], tile_mode=tile["tile_mode"]
                             ).reshape(-1, x.shape[1])


def _stack_bcsr(packs):
    p0 = packs[0]
    if any(p.block_shape != p0.block_shape for p in packs):
        raise ValueError("bcsr shards disagree on block_shape")
    arrays = [_pad_stack([p.block_cols for p in packs], fill=-1),
              _pad_stack([p.values for p in packs])]
    r = p0.block_shape[0]
    return arrays, {}, arrays[0].shape[1] * r, p0.values.dtype


def _run_bcsr(arrs, x, st, interpret, tile):
    cols, val = arrs
    if x.shape[1] == 1:
        return bcsr_spmv_pallas(cols, val, x[:, 0],
                                interpret=interpret).reshape(-1)[:, None]
    return bcsr_spmm_pallas(cols, val, x, interpret=interpret,
                            bn=tile["bn"], tile_mode=tile["tile_mode"]
                            ).reshape(-1, x.shape[1])


#: packed-artifact type -> (stack, run).  A family (or third-party
#: spec) joins the collective path by registering here; everything else
#: falls back to the sequential loop.
SHARD_MAP_ADAPTERS = {
    PackedMatrix: (_stack_dtans, _run_dtans),
    PackedSELL: (_stack_sell, _run_sell),
    PackedRGCSR: (_stack_rgcsr, _run_rgcsr),
    PackedBCSR: (_stack_bcsr, _run_bcsr),
}


def supports_shard_map(plan) -> bool:
    """Whether this plan's packed artifacts have a collective-path
    adapter (the four kernel-backed families do)."""
    return bool(plan.shards) and type(plan.shards[0]) in \
        SHARD_MAP_ADAPTERS


# --------------------------------------------------------------------------
# Execution
# --------------------------------------------------------------------------


def _record_shard_pass(plan, batch: int, *, collective: bool) -> None:
    """One sharded pass into the default metrics registry: per-shard
    matrix bytes plus the collective count (one x broadcast + one y
    psum per collective pass) — the obs contract of the sharded path."""
    r = obs.default_registry()
    r.counter("kernels.shard_passes").add(1)
    r.counter("kernels.shard_matrix_bytes").add(plan.total_nbytes)
    r.histogram("kernels.n_shards").observe(plan.n_shards)
    for b in plan.shard_nbytes:
        r.histogram("kernels.shard_bytes").observe(int(b))
    if collective:
        r.counter("kernels.collectives.broadcast").add(1)
        r.counter("kernels.collectives.psum").add(1)


def _tile_opts(bn=None, tile_mode="auto", pipeline=False):
    """The per-shard tile/pipeline knobs threaded into the run
    adapters.  ``bn`` column-tiles each device's local kernel call
    (`repro.kernels.tiling`); ``pipeline`` double-buffers the dtANS
    decode (ignored by the plain families)."""
    return dict(bn=bn, tile_mode=tile_mode, pipeline=bool(pipeline))


def _loop_spmm(plan, x2, *, interpret: bool, tile):
    """Sequential fallback: every shard in turn on one device, rows
    concatenated — no mesh needed, every registered format supported.

    Kernel-backed families run through the SAME stacked adapters as the
    collective path: stacking pads each shard's tensors to the
    fleet-wide max, which equals the full-matrix pack's padded widths
    (the global max row/group/segment lives in some shard), so kernels
    that tree-reduce over the padded width axis (SELL/RGCSR) see the
    single-device reduction tree exactly — a shard's own narrower pack
    would round differently at the last ulp.  Other formats go through
    their registry `spmm_runner` per shard (their row results are
    width-independent)."""
    zero_dt = jnp.float64 if plan.dtype == np.float64 else jnp.float32
    blocks = []
    if supports_shard_map(plan):
        stack, run = SHARD_MAP_ADAPTERS[type(plan.shards[0])]
        arrays, static, rows_cap, dt = stack(plan.shards)
        dt = jnp.float64 if np.dtype(dt) == np.float64 else jnp.float32
        xj = jnp.asarray(x2, dtype=dt)
        for k in range(plan.n_shards):
            rows = plan.boundaries[k + 1] - plan.boundaries[k]
            if rows == 0:
                continue                  # empty shard: zero rows
            local = [jnp.asarray(a[k]) for a in arrays]
            blocks.append(run(local, xj, static, interpret, tile)[:rows])
    else:
        from repro.sparse.registry import get_format
        spec = get_format(plan.fmt)
        for k in range(plan.n_shards):
            if plan.boundaries[k + 1] == plan.boundaries[k]:
                continue
            blocks.append(jnp.asarray(spec.spmm_runner(
                plan.shards[k], x2, interpret=interpret)()))
    if not blocks:
        return jnp.zeros((0, x2.shape[1]), zero_dt)
    return jnp.concatenate(blocks, axis=0)


def _shard_map_spmm(plan, x2, mesh, *, interpret: bool, tile):
    """The collective path: stacked shard tensors sharded over the mesh
    ``model`` axis, x broadcast (replicated in-spec), per-device kernel,
    row-masked partials placed at each shard's row offset, psum."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    stack, run = SHARD_MAP_ADAPTERS[type(plan.shards[0])]
    arrays, static, rows_cap, dt = stack(plan.shards)
    dt = jnp.float64 if np.dtype(dt) == np.float64 else jnp.float32
    m, _ = plan.shape
    B = x2.shape[1]
    r0 = np.asarray(plan.boundaries[:-1], np.int32)
    rows = np.asarray(np.diff(np.asarray(plan.boundaries)), np.int32)
    m_pad = max(m, int(r0.max()) + rows_cap)
    xj = jnp.asarray(x2, dtype=dt)
    arrs = [jnp.asarray(a) for a in arrays]

    def body(r0_k, rows_k, x, *arrs_k):
        local = [a[0] for a in arrs_k]
        part = run(local, x, static, interpret, tile).astype(dt)
        lane = jax.lax.broadcasted_iota(jnp.int32, (rows_cap, 1), 0)
        part = jnp.where(lane < rows_k[0], part, 0)
        out = jnp.zeros((m_pad, B), dt)
        out = jax.lax.dynamic_update_slice(
            out, part, (r0_k[0], jnp.int32(0)))
        return jax.lax.psum(out, "model")

    specs = [P("model"), P("model"), P(None, None)] + \
        [P("model", *([None] * (a.ndim - 1))) for a in arrs]
    f = shard_map(body, mesh=mesh, in_specs=tuple(specs),
                  out_specs=P(None, None), check_rep=False)
    return f(jnp.asarray(r0), jnp.asarray(rows), xj, *arrs)[:m]


def _validate_mesh(plan, mesh):
    from repro.launch.mesh import model_axis_size
    k = model_axis_size(mesh)
    if k != plan.n_shards:
        raise ValueError(
            f"plan has {plan.n_shards} shards but the mesh model axis "
            f"holds {k} devices; build the plan with "
            f"n_shards=model_axis_size(mesh)")


def shard_spmm(plan, x, y=None, *, mesh=None, interpret: bool = True,
               bn=None, tile_mode: str = "auto",
               pipeline: bool = False) -> jax.Array:
    """Y = A X + Y from a shard plan, X: (n, B) — the sharded analogue
    of `ops.spmm`.  With a mesh (model axis == ``plan.n_shards``) and a
    kernel-backed family: `shard_map` + psum; otherwise the sequential
    per-shard loop.  Results are bit-identical to the single-device
    kernels either way.  ``bn`` / ``tile_mode`` column-tile each
    device's local kernel and ``pipeline`` double-buffers the dtANS
    decode — both pass straight into the per-shard kernels, so the
    sharded bit-identity contract is the single-device one."""
    m, n = plan.shape
    x2 = jnp.asarray(x)
    if x2.ndim != 2:
        raise ValueError(f"shard_spmm expects x of shape (n, B); got "
                         f"{x2.shape} (use shard_spmv for 1-D)")
    if x2.shape[0] != n:
        raise ValueError(f"shard_spmm rhs has {x2.shape[0]} rows; "
                         f"matrix has {n} columns")
    dt = jnp.float64 if plan.dtype == np.float64 else jnp.float32
    if x2.shape[1] == 0 or m == 0:
        out = jnp.zeros((m, x2.shape[1]), dt)
    else:
        collective = (mesh is not None and plan.n_shards > 1
                      and supports_shard_map(plan))
        if mesh is not None:
            _validate_mesh(plan, mesh)
        _record_shard_pass(plan, x2.shape[1], collective=collective)
        tile = _tile_opts(bn=bn, tile_mode=tile_mode, pipeline=pipeline)
        if collective:
            out = _shard_map_spmm(plan, x2, mesh, interpret=interpret,
                                  tile=tile)
        else:
            out = _loop_spmm(plan, x2, interpret=interpret, tile=tile)
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out


def shard_spmv(plan, x, y=None, *, mesh=None, interpret: bool = True,
               pipeline: bool = False) -> jax.Array:
    """y = A x + y from a shard plan, 1-D ``x`` — the sharded analogue
    of `ops.spmv`.  Routes through the spmv kernels (B == 1), so the
    result is bit-identical to the single-device `ops.spmv`."""
    x1 = jnp.asarray(x)
    if x1.ndim != 1:
        raise ValueError(f"shard_spmv expects 1-D x; got {x1.shape}")
    out = shard_spmm(plan, x1[:, None], mesh=mesh,
                     interpret=interpret, pipeline=pipeline)[:, 0]
    if y is not None:
        out = out + jnp.asarray(y, dtype=out.dtype)
    return out
