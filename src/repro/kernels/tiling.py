"""Column tiling of the multi-RHS (SpMM) kernels — the grid-blocked
execution layer.

The PR-5 SpMM kernels hold ALL B columns of x (and the slice's y rows)
in VMEM per program: fine for serving pools (B in the tens), a capacity
wall for training-shaped B in the thousands.  This module blocks the
RHS dimension into ``bn``-column tiles so one program touches an
``(n, bn)`` x tile and a ``(rows, bn)`` y tile:

* `choose_bn` picks the widest tile whose x+y columns fit a VMEM
  budget (`DEFAULT_VMEM_BYTES` x `TILE_FRACTION`), rounded down to the
  TPU lane width; ``None`` means the whole batch fits — the untiled
  kernel IS the fast path and tiling must not tax it.
* `blocked_spmm` drives a family's kernel over the column tiles in one
  of two equivalent schedules:

  - ``grid``: a 2-D pallas grid ``(slice s, column block j)`` — the
    TPU-native layout; matrix blocks re-index by ``s`` only, the x
    BlockSpec walks ``j``, and Mosaic's automatic block double-
    buffering prefetches tile ``j+1`` while ``j`` contracts.
  - ``loop``: ``lax.map`` over column tiles around the 1-D-grid
    pallas_call — the same blocked computation with J x fewer grid
    programs, which is what interpret mode (this CPU container) wants:
    its per-program emulation overhead scales with program count.

  ``tile_mode="auto"`` resolves to ``loop`` under ``interpret=True``
  and ``grid`` when compiled.

Bit-identity contract: tiling splits only the B axis.  Every output
column sees exactly the per-column arithmetic of the untiled kernel
(same decode, same gather, same accumulation order), so blocked
results are REQUIRED to be bitwise equal to the unblocked kernels at
every ``bn`` — the conformance suite pins both schedules with exact
``==``.  Ragged tails zero-pad x to ``J*bn`` columns and slice back.

The pure sizing helpers (`choose_bn` / `n_col_tiles`) are numpy-free
and jax-free so `repro.autotune.cost_model` can price tiling without
importing the kernel stack.
"""

from __future__ import annotations

#: Stand-in for one v5e core's usable VMEM (the real core has 128 MiB
#: CMEM + ~16 MiB VMEM-class scratch; the kernels' matrix blocks and
#: coding tables also live there, hence `TILE_FRACTION` below).
DEFAULT_VMEM_BYTES = 16 * 2 ** 20

#: Fraction of the VMEM budget the x/y column tiles may claim; the
#: rest holds the program's matrix block (stream + tables / indices).
TILE_FRACTION = 0.5

#: TPU lane width — tile widths snap down to a multiple of this when
#: they can, so the minor dimension stays register-aligned.
LANE = 128

#: Floor tile width: below this the per-tile overhead dwarfs the work.
MIN_BN = 8


def choose_bn(n: int, rows: int, batch: int, itemsize: int,
              vmem_bytes: int | float | None = None) -> int | None:
    """Widest column-tile width ``bn`` whose x tile ``(n, bn)`` plus y
    tile ``(rows, bn)`` fit the VMEM tile budget, or ``None`` when the
    whole batch fits (untiled is the fast path).  Pure arithmetic — no
    jax — shared by the kernels and the cost model."""
    if batch <= 0:
        return None
    budget = (vmem_bytes if vmem_bytes is not None
              else DEFAULT_VMEM_BYTES) * TILE_FRACTION
    per_col = (int(n) + int(rows)) * int(itemsize)
    if per_col <= 0:
        return None
    bn = int(budget // per_col)
    if bn >= batch:
        return None
    if bn >= LANE:
        bn = (bn // LANE) * LANE
    return max(bn, MIN_BN)


def n_col_tiles(n: int, rows: int, batch: int, itemsize: int,
                vmem_bytes: int | float | None = None) -> int:
    """Number of column tiles one SpMM pass runs at batch ``batch`` —
    the multiplier on per-tile matrix traffic and decode work that
    `cost_model.spmm_bytes` / `cost_model.work_time` charge."""
    bn = choose_bn(n, rows, batch, itemsize, vmem_bytes)
    return 1 if bn is None else -(-int(batch) // bn)


def resolve_tile_mode(tile_mode: str, interpret: bool) -> str:
    """``auto`` -> ``loop`` in interpret mode (program-count-bound),
    ``grid`` compiled (Mosaic double-buffers the 2-D grid's x tiles)."""
    if tile_mode == "auto":
        return "loop" if interpret else "grid"
    if tile_mode not in ("grid", "loop"):
        raise ValueError(f"tile_mode must be 'auto', 'grid' or 'loop'; "
                         f"got {tile_mode!r}")
    return tile_mode


def blocked_spmm(kernel, mat_args, mat_specs, x, *, rows: int,
                 out_dtype, grid_s: int, bn: int | None,
                 tile_mode: str = "auto", interpret: bool = True):
    """Run a family's SpMM kernel over ``bn``-column tiles of ``x``.

    ``mat_specs`` is a list of ``(block_shape, index_map)`` pairs for
    the matrix operands, with 1-D (slice-only) index maps — the helper
    lifts them to the 2-D grid itself.  ``rows`` is the per-program
    output row count (lane width / group size / block height), so the
    result is ``(grid_s, rows, B)`` exactly like the untiled wrappers.

    ``bn=None`` (or ``bn >= B``) is the untiled single-tile call — the
    same pallas_call the PR-5 kernels made, so the default path pays
    nothing for the tiling machinery.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    n, B = x.shape
    S = int(grid_s)

    def call(xt, bt):
        in_specs = [pl.BlockSpec(shape, fn) for shape, fn in mat_specs]
        in_specs.append(pl.BlockSpec((n, bt), lambda s: (0, 0)))
        return pl.pallas_call(
            kernel,
            grid=(S,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, rows, bt), lambda s: (s, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((S, rows, bt), out_dtype),
            interpret=interpret,
        )(*mat_args, xt)

    if bn is None or int(bn) >= B:
        return call(x, B)
    bn = int(bn)
    mode = resolve_tile_mode(tile_mode, interpret)
    J = -(-B // bn)
    if B % bn:
        x = jnp.pad(x, ((0, 0), (0, J * bn - B)))
    if mode == "loop":
        xt = jnp.moveaxis(x.reshape(n, J, bn), 1, 0)      # (J, n, bn)
        ys = jax.lax.map(lambda xj: call(xj, bn), xt)     # (J, S, rows, bn)
        return jnp.moveaxis(ys, 0, 2).reshape(S, rows, J * bn)[:, :, :B]
    # 2-D grid: lift the slice-only index maps to (s, j) arity; the x
    # spec walks the column blocks and the out spec scatters per tile.
    in_specs = [pl.BlockSpec(shape, (lambda f: lambda s, j: f(s))(fn))
                for shape, fn in mat_specs]
    in_specs.append(pl.BlockSpec((n, bn), lambda s, j: (0, j)))
    out = pl.pallas_call(
        kernel,
        grid=(S, J),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows, bn), lambda s, j: (s, 0, j)),
        out_shape=jax.ShapeDtypeStruct((S, rows, J * bn), out_dtype),
        interpret=interpret,
    )(*mat_args, x)
    return out[:, :, :B]
