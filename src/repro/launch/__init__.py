# Launch layer: mesh construction, sharding rules, dry-run, drivers.
