import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder CPU devices, proving the distribution config is coherent,
and dump memory/cost/collective analyses for EXPERIMENTS.md.

MUST be run as its own process (the XLA_FLAGS line above executes before
any jax import, including `from repro...`).

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh single --out experiments/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro import configs                                    # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.launch.roofline import (HBM_PER_CHIP, Roofline,    # noqa: E402
                                   collective_bytes, model_flops)
from repro.launch.steps import build_cell, cell_is_skipped    # noqa: E402
from repro.models.config import SHAPES                        # noqa: E402


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str | None = None, verbose: bool = True,
             save_hlo: bool = False, **policy) -> dict:
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "policy": {k: v for k, v in policy.items() if v is not None}}
    skip = cell_is_skipped(arch, shape_name)
    if skip:
        rec.update(status="skipped", reason=skip)
        _emit(rec, out_dir, verbose)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        t0 = time.time()
        cell = build_cell(arch, shape_name, mesh, **policy)
        lowered = cell.lower(mesh)
        t1 = time.time()
        lowered_text = lowered.as_text()
        if "f64[" in lowered_text or "s64[" in lowered_text:
            rec["dtype_leak"] = True  # x64 discipline violation (see tests)
        compiled = lowered.compile()
        t2 = time.time()

        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
        }
        live = (mem_rec["argument_bytes"] + mem_rec["output_bytes"]
                + mem_rec["temp_bytes"] - mem_rec["alias_bytes"])
        mem_rec["peak_live_bytes"] = int(live)
        mem_rec["fits_hbm"] = bool(live <= HBM_PER_CHIP)

        # XLA's cost_analysis() counts while-loop bodies once (verified in
        # tests/test_roofline.py); use the trip-count-aware walker instead.
        xla_costs = compiled.cost_analysis()
        if isinstance(xla_costs, (list, tuple)):  # newer jax: one per module
            xla_costs = xla_costs[0] if xla_costs else {}
        hlo_text = compiled.as_text()
        if save_hlo and out_dir:
            import gzip
            os.makedirs(out_dir, exist_ok=True)
            with gzip.open(os.path.join(
                    out_dir, f"{arch}__{shape_name}__{mesh_kind}.hlo.gz"),
                    "wt") as f:
                f.write(hlo_text)
        walked = hlo_analyze(hlo_text)
        flops = float(walked.flops)
        hbm = float(walked.bytes)
        coll = {"weighted": walked.coll_wire, "raw": walked.coll_raw,
                "counts": walked.coll_counts,
                "total_weighted": walked.collective_bytes,
                "total_raw": sum(walked.coll_raw.values())}
        roof = Roofline.from_costs(flops, hbm, coll["total_weighted"])
        mf = model_flops(cell.cfg, cell.shape, cell.kind)
        chips = mesh.devices.size
        rec.update(
            status="ok",
            kind=cell.kind,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            chips=chips,
            memory=mem_rec,
            flops_per_device=flops,
            hbm_bytes_per_device=hbm,
            collectives=coll,
            roofline=roof.to_dict(),
            model_flops_global=mf,
            model_flops_per_device=mf / chips,
            useful_flops_ratio=(mf / chips) / flops if flops else None,
            xla_cost_analysis={"flops": float(xla_costs.get("flops", 0.0)),
                               "bytes accessed": float(
                                   xla_costs.get("bytes accessed", 0.0))},
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a data point
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _emit(rec, out_dir, verbose)
    return rec


def _emit(rec, out_dir, verbose):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        if rec["status"] == "ok":
            m = rec["memory"]
            r = rec["roofline"]
            print(f"[OK] {rec['arch']} {rec['shape']} {rec['mesh']} "
                  f"compile={rec['compile_s']}s "
                  f"live={m['peak_live_bytes']/2**30:.2f}GiB "
                  f"fits={m['fits_hbm']} "
                  f"terms(c/m/x)={r['compute_s']:.3e}/{r['memory_s']:.3e}/"
                  f"{r['collective_s']:.3e}s dom={r['dominant']}",
                  flush=True)
        elif rec["status"] == "skipped":
            print(f"[SKIP] {rec['arch']} {rec['shape']} {rec['mesh']}: "
                  f"{rec['reason']}", flush=True)
        else:
            print(f"[ERR] {rec['arch']} {rec['shape']} {rec['mesh']}: "
                  f"{rec['error']}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--fsdp", default=None,
                    type=lambda s: s.lower() == "true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-seq-shard-cache", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    policy = dict(fsdp=args.fsdp, grad_compress=args.grad_compress,
                  microbatches=args.microbatches,
                  seq_shard_cache=not args.no_seq_shard_cache)
    kw = dict(save_hlo=args.save_hlo)
    policy.update(kw) if False else None
    if args.all:
        n_ok = n_err = 0
        for mesh_kind in ("single", "multi"):
            for arch in configs.ARCH_IDS:
                for shape in SHAPES:
                    rec = run_cell(arch, shape, mesh_kind, args.out,
                                   save_hlo=args.save_hlo, **policy)
                    n_ok += rec["status"] in ("ok", "skipped")
                    n_err += rec["status"] == "error"
        print(f"dry-run done: {n_ok} ok/skip, {n_err} errors")
        raise SystemExit(1 if n_err else 0)
    assert args.arch and args.shape
    rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                   save_hlo=args.save_hlo, **policy)
    raise SystemExit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
