"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — a
126-layer scanned transformer is undercounted ~126x (verified in
tests/test_roofline.py). This walker parses the post-optimization HLO text
and accounts compositionally:

  flops(while)  = trip_count x (flops(body) + flops(cond))
  flops(fusion) = flops(called computation);  bytes(fusion) = operand +
                  result bytes of the fusion op itself (post-fusion truth)
  flops(dot)    = 2 x prod(result dims) x prod(contracting dims)

Trip counts come from XLA's ``known_trip_count`` backend config when
present, else from the loop-condition constant (lax.scan shape).

Collectives are likewise multiplied by enclosing trip counts — essential:
FSDP all-gathers live INSIDE the layer scan.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
# "  %name = <shapes> opcode(operands), attrs"  /  "ROOT %name = ..."
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?([%\w.\-]+)\s*=\s*(\(?[a-z][^=]*?)\s+"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_ATTR = re.compile(r'"known_trip_count"\s*:\s*{\s*"n"\s*:\s*"(\d+)"')
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")
# on-wire multiplier (ring algorithms)
COLLECTIVE_WIRE = {"all-gather": 1.0, "all-reduce": 2.0,
                   "reduce-scatter": 1.0, "all-to-all": 1.0,
                   "collective-permute": 1.0}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    rest: str           # operand list + attributes (raw tail of the line)
    operands: list


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_wire: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})
    coll_raw: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVE_OPS})

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k in COLLECTIVE_OPS:
            self.coll_wire[k] += other.coll_wire[k] * mult
            self.coll_raw[k] += other.coll_raw[k] * mult
            self.coll_counts[k] += int(other.coll_counts[k] * mult)

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_wire.values())


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._cache: dict[str, Costs] = {}
        self._shape_of: dict[tuple, str] = {}
        self._slice_cache: dict[str, dict] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            st = line.strip()
            if (st.endswith("{") and "->" in st
                    and " = " not in st.split("->")[0]):
                is_entry = st.startswith("ENTRY")
                head = st[len("ENTRY"):].strip() if is_entry else st
                cur = head.split("(")[0].strip().lstrip("%").strip()
                self.comps[cur] = []
                if is_entry:
                    self.entry = cur
                continue
            if cur is None:
                continue
            if st == "}":
                cur = None
                continue
            if " = " not in st:
                continue
            lhs, rhs = st.split(" = ", 1)
            name = lhs.replace("ROOT", "").strip().lstrip("%")
            if not re.fullmatch(r"[\w.\-]+", name):
                continue
            # opcode = first bare `word(` token; everything before it is the
            # (possibly tuple, possibly /*index=N*/-commented) result shape
            mo = re.search(r"(?:^|\s)([a-z][\w\-]*)\(", rhs)
            if not mo:
                continue
            shape_str = rhs[:mo.start()]
            opcode = mo.group(1)
            rest = rhs[mo.end():]
            ops = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
            self.comps[cur].append(
                Instr(name, shape_str, opcode, rest, ops))

    # ---- helpers -----------------------------------------------------------
    def _operand_shape(self, comp: str, ref: str) -> str | None:
        key = (comp, ref)
        if key in self._shape_of:
            return self._shape_of[key]
        for ins in self.comps.get(comp, []):
            self._shape_of[(comp, ins.name)] = ins.shape_str
        # parameters: shapes appear inline in operand list — unavailable;
        # callers fall back to result-shape-based costs.
        return self._shape_of.get(key)

    def _trip_count(self, comp: str, instr: Instr) -> int:
        m = _TRIP_ATTR.search(instr.rest)
        if m:
            return int(m.group(1))
        mc = _COND_ATTR.search(instr.rest)
        if mc and mc.group(1) in self.comps:
            consts = []
            for ins in self.comps[mc.group(1)]:
                consts += [int(c) for c in _CONST_RE.findall(
                    ins.shape_str + " " + ins.rest)]
            pos = [c for c in consts if c > 0]
            if pos:
                return max(pos)
        return 1

    def _dot_flops(self, comp: str, instr: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(instr.shape_str)
        k = 1
        m = _CONTRACT_RE.search(instr.rest)
        if m and instr.operands:
            lhs_shape = self._operand_shape(comp, instr.operands[0])
            if lhs_shape:
                dims_m = _SHAPE_RE.search(lhs_shape)
                if dims_m and dims_m.group(2):
                    dims = [int(d) for d in dims_m.group(2).split(",")]
                    for ci in m.group(1).split(","):
                        if ci:
                            idx = int(ci)
                            if idx < len(dims):
                                k *= dims[idx]
        return 2.0 * out_elems * k

    def _operand_bytes(self, comp: str, ins: Instr) -> int:
        """Sum of resolvable operand sizes ("bytes accessed" semantics).

        For fusions, a parameter that is only dynamic-sliced/gathered inside
        the fused computation is counted at the slice size, not the full
        operand (a scanned-layer weight stack would otherwise be charged
        126x per step)."""
        self._operand_shape(comp, "")   # warm shape table
        slice_sized = {}
        if ins.opcode == "fusion":
            called = _CALL_ATTR.search(ins.rest)
            if called and called.group(1) in self.comps:
                slice_sized = self._fusion_param_read_bytes(called.group(1))
        total = 0
        for i, ref in enumerate(ins.operands):
            if i in slice_sized:
                total += slice_sized[i]
                continue
            sh = self._shape_of.get((comp, ref))
            if sh:
                total += _shape_elems_bytes(sh)[1]
        return total

    def _fusion_param_read_bytes(self, called: str) -> dict:
        """param index -> actually-read bytes, for params whose only
        consumers are (dynamic-)slice / gather ops."""
        if called in self._slice_cache:
            return self._slice_cache[called]
        out = {}
        instrs = self.comps.get(called, [])
        params = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                idx_m = re.match(r"\s*(\d+)", ins.rest)
                if idx_m:
                    params[ins.name] = int(idx_m.group(1))
        for pname, pidx in params.items():
            consumers = [i for i in instrs if pname in i.operands]
            if consumers and all(c.opcode in ("dynamic-slice", "slice",
                                              "gather")
                                 for c in consumers):
                out[pidx] = sum(_shape_elems_bytes(c.shape_str)[1]
                                for c in consumers)
        self._slice_cache[called] = out
        return out

    # ---- main recursion ------------------------------------------------------
    def comp_costs(self, comp: str) -> Costs:
        if comp in self._cache:
            return self._cache[comp]
        self._cache[comp] = Costs()   # cycle guard
        total = Costs()
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            elems, bts = _shape_elems_bytes(ins.shape_str)
            bts_rw = bts + self._operand_bytes(comp, ins)
            if op == "while":
                trip = self._trip_count(comp, ins)
                body = _CALL_ATTR.search(ins.rest)
                inner = Costs()
                if body and body.group(1) in self.comps:
                    inner.add(self.comp_costs(body.group(1)))
                cond = _COND_ATTR.search(ins.rest)
                if cond and cond.group(1) in self.comps:
                    inner.add(self.comp_costs(cond.group(1)))
                total.add(inner, trip)
            elif op in ("fusion", "call", "conditional", "map",
                        "reduce-window", "sort", "scatter", "reduce"):
                called = _CALL_ATTR.search(ins.rest)
                if called and called.group(1) in self.comps:
                    inner = self.comp_costs(called.group(1))
                    total.flops += inner.flops
                    total.transcendentals += inner.transcendentals
                    for k in COLLECTIVE_OPS:
                        total.coll_wire[k] += inner.coll_wire[k]
                        total.coll_raw[k] += inner.coll_raw[k]
                        total.coll_counts[k] += inner.coll_counts[k]
                # fusion bytes: the fusion's own operands + result are what
                # touch HBM; inner intermediate buffers stay in registers
                total.bytes += bts_rw
            elif op == "dot":
                total.flops += self._dot_flops(comp, ins)
                total.bytes += bts_rw
            elif op.startswith(COLLECTIVE_OPS) or op in COLLECTIVE_OPS \
                    or any(op == c + "-start" for c in COLLECTIVE_OPS):
                base = op.replace("-start", "")
                if base in COLLECTIVE_OPS:
                    total.coll_raw[base] += bts
                    total.coll_wire[base] += bts * COLLECTIVE_WIRE[base]
                    total.coll_counts[base] += 1
                    total.bytes += bts_rw
            elif op in ("exponential", "log", "tanh", "rsqrt", "sqrt",
                        "power", "logistic", "sine", "cosine"):
                total.transcendentals += elems
                total.flops += elems
                total.bytes += bts
            elif op in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "copy-start", "copy-done",
                        "after-all", "partition-id", "custom-call",
                        "opt-barrier"):
                pass
            elif op in ("iota", "broadcast", "pad"):
                pass                      # generative: fuse to no traffic
            elif op in ("copy", "transpose", "reshape", "slice",
                        "dynamic-slice", "concatenate",
                        "dynamic-update-slice", "gather", "reverse",
                        "convert", "select-and-scatter"):
                total.bytes += bts        # data movement, no flops
            else:
                # unfused elementwise: count result only — the TPU backend
                # would fuse these chains (CPU scheduling fuses less), so
                # operand re-reads would not hit HBM
                total.flops += elems
                total.bytes += bts
        self._cache[comp] = total
        return total

    def entry_costs(self) -> Costs:
        if self.entry is None:
            # fall back: largest computation
            best, best_n = None, -1
            for name, instrs in self.comps.items():
                if len(instrs) > best_n:
                    best, best_n = name, len(instrs)
            self.entry = best
        return self.comp_costs(self.entry)


def analyze(hlo_text: str) -> Costs:
    return HloCostModel(hlo_text).entry_costs()
