"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; real TPU launches get the same topology from the runtime.

  single pod : (data=16, model=16)        = 256 chips  (v5e pod)
  multi-pod  : (pod=2, data=16, model=16) = 512 chips  (DCN over 'pod')
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py (placeholder devices) or a real "
            "fleet")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for tests (requires xla_force_host_platform_device_count
    set in the TEST process, never globally)."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes)


def data_axis_names(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_axis_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axis_names(mesh)]))


def model_axis_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))
