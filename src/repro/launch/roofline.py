"""Roofline-term derivation from compiled dry-run artifacts.

   compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
   memory term     = HLO_bytes / (chips x HBM_bw)
   collective term = collective_bytes / (chips x link_bw)

`cost_analysis()` supplies FLOPs / bytes; collective bytes are parsed from
the post-SPMD-partitioning HLO text (per-device shapes), weighting each op
by its on-wire factor (ring all-reduce moves ~2x its operand bytes).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(constants from the assignment).
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link
HBM_PER_CHIP = 16 * 1024 ** 3          # v5e: 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,          # ring: reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device on-wire bytes by collective type (weighted) + raw sizes."""
    out = {k: 0 for k in _COLLECTIVES}
    raw = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        result_shapes, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        b = _shape_bytes(result_shapes)
        raw[op] += b
        out[op] += int(b * _COLLECTIVES[op])
        counts[op] += 1
    return {"weighted": out, "raw": raw, "counts": counts,
            "total_weighted": sum(out.values()),
            "total_raw": sum(raw.values())}


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO flops
    hbm_bytes: float           # per-device HLO bytes accessed
    coll_bytes: float          # per-device on-wire collective bytes
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""

    @classmethod
    def from_costs(cls, flops, hbm_bytes, coll_bytes,
                   links: int = 4) -> "Roofline":
        r = cls(flops=flops, hbm_bytes=hbm_bytes, coll_bytes=coll_bytes)
        r.compute_s = flops / PEAK_FLOPS
        r.memory_s = hbm_bytes / HBM_BW
        r.collective_s = coll_bytes / (ICI_BW * links)
        terms = {"compute": r.compute_s, "memory": r.memory_s,
                 "collective": r.collective_s}
        r.dominant = max(terms, key=terms.get)
        return r

    def to_dict(self):
        return dataclasses.asdict(self)


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for training;
    2 N D for a forward-only pass (prefill), 2 N per token for decode."""
    hd = cfg.hd
    n_mats = 3 if cfg.mlp_gated else 2
    if cfg.family == "moe":
        per_layer = (cfg.top_k * 3 * cfg.d_model * cfg.d_ff
                     + cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
                     + cfg.n_heads * hd * cfg.d_model)
    elif cfg.family in ("ssm", "hybrid"):
        per_layer = (cfg.d_model * (2 * cfg.d_inner + 2 * cfg.ssm_state
                                    + cfg.ssm_heads)
                     + cfg.d_inner * cfg.d_model)
        if cfg.family == "hybrid" and cfg.attn_every:
            attn = (2 * cfg.d_model * cfg.d_model
                    + cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
                    + cfg.n_heads * hd * cfg.d_model
                    + n_mats * cfg.d_model * cfg.d_ff)
            per_layer += attn / cfg.attn_every
    else:
        per_layer = (cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
                     + cfg.n_heads * hd * cfg.d_model
                     + n_mats * cfg.d_model * cfg.d_ff)
    n_layers = cfg.n_layers
    if cfg.family == "encdec":
        n_layers = (cfg.n_enc_layers or cfg.n_layers) + \
            (cfg.n_dec_layers or cfg.n_layers)
    n_active = per_layer * n_layers + 2 * cfg.vocab * cfg.d_model
    tokens = shape.global_batch * (1 if kind == "decode" else shape.seq_len)
    mult = 6 if kind == "train" else 2
    return mult * n_active * tokens
