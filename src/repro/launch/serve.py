"""Serving launcher: batched greedy decoding with the slot engine,
optionally with a CSR-dtANS-compressed (pruned + entropy-coded) LM head —
the paper's technique in the serving path.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --requests 8 --sparse-head
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import api
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--sparse-head", action="store_true",
                    help="prune + CSR-dtANS-encode the LM head and report "
                         "its compression (paper technique)")
    ap.add_argument("--sparsity", type=float, default=0.8)
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    sparse_head = None
    if args.sparse_head:
        sparse_head = Engine.compress_lm_head(cfg, params,
                                              sparsity=args.sparsity)
        print(f"LM head: {sparse_head.dense_bytes:,} B dense -> "
              f"{sparse_head.compressed_bytes:,} B CSR-dtANS "
              f"({sparse_head.compression_vs_dense:.2f}x vs dense, "
              f"{sparse_head.compression_vs_best_sparse:.2f}x vs best "
              f"sparse format)")

    eng = Engine(cfg, params, slots=args.slots, max_seq=args.max_seq,
                 sparse_head=sparse_head)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=args.prompt_len),
                       args.max_new_tokens) for _ in range(args.requests)]
    t0 = time.time()
    eng.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"served {sum(r.done for r in reqs)}/{len(reqs)} requests, "
          f"{toks} tokens in {dt:.1f}s ({toks/max(dt,1e-9):.1f} tok/s, "
          f"CPU interpret)")


if __name__ == "__main__":
    main()
