"""Parameter / optimizer / batch / cache PartitionSpecs (DESIGN.md §5).

Policy knobs:
  fsdp  — additionally shard each weight's non-TP dim over the data axis
          (needed when bf16 params alone exceed TP-sharded HBM: 405B, 34B,
          30B-MoE);
  zero1 — shard optimizer state dim-0 over the data axis when the param
          itself is not FSDP-sharded (ZeRO-1).

All rules are divisibility-guarded: a dim that doesn't divide the mesh axis
stays replicated rather than failing to lower.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import data_axis_names, data_axis_size, \
    model_axis_size
from repro.models.config import ArchConfig

FSDP_PARAM_THRESHOLD = 10e9


def should_fsdp(cfg: ArchConfig) -> bool:
    # cheap analytic estimate of param count
    hd = cfg.hd
    n_mats = 3 if cfg.mlp_gated else 2
    if cfg.family == "moe":
        per = cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
        per += 2 * cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    elif cfg.family in ("ssm", "hybrid"):
        per = cfg.d_model * (2 * cfg.d_inner + 2 * cfg.ssm_state
                             + cfg.ssm_heads) + cfg.d_inner * cfg.d_model
    else:
        per = (cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
               + cfg.n_heads * hd * cfg.d_model
               + n_mats * cfg.d_model * cfg.d_ff)
    total = per * cfg.n_layers + 2 * cfg.vocab * cfg.d_model
    return total > FSDP_PARAM_THRESHOLD


def _div(n: int, size: int) -> bool:
    return size > 1 and n % size == 0


class ShardingRules:
    def __init__(self, cfg: ArchConfig, mesh: Mesh, *, fsdp=None,
                 zero1=True, seq_shard_cache=True, dp_only=False):
        self.cfg = cfg
        self.mesh = mesh
        self.dp_only = dp_only
        self.fsdp = (should_fsdp(cfg) if fsdp is None else fsdp) \
            and not dp_only
        self.zero1 = zero1
        self.seq_shard_cache = seq_shard_cache
        self.dsize = data_axis_size(mesh)
        self.msize = 1 if dp_only else model_axis_size(mesh)
        self.dax = data_axis_names(mesh)
        self.data = (self.dax if len(self.dax) > 1
                     else (self.dax[0] if self.dax else None))

    # ----- parameters ------------------------------------------------------
    def _f(self, dim: int):
        """FSDP axis for a weight dim (or None)."""
        if self.fsdp and _div(dim, self.dsize):
            return "data"
        return None

    def _m(self, dim: int):
        return "model" if _div(dim, self.msize) else None

    def param_spec(self, path, leaf) -> P:
        names = [getattr(p, "key", None) for p in path]
        names = [n for n in names if n is not None]
        shape = leaf.shape
        stacked = any(n in ("layers", "enc_layers", "dec_layers")
                      for n in names)
        core = shape[1:] if stacked else shape
        name = names[-1] if names else ""
        in_ssm = "ssm" in names

        spec: tuple = tuple(None for _ in core)
        if name == "tok":
            spec = (self._m(core[0]), self._f(core[1]))
        elif name == "head":
            spec = (self._f(core[0]), self._m(core[1]))
        elif name in ("wq", "wk", "wv"):
            spec = (self._f(core[0]), self._m(core[1]))
        elif name in ("wi", "wg"):
            if len(core) == 3:   # moe (E, d, ff)
                if self._m(core[0]):
                    spec = ("model", self._f(core[1]), None)
                else:            # E % model axis != 0: FSDP over data,
                    spec = (None, self._f(core[1]), None)  # capacity-EP
            else:
                spec = (self._f(core[0]), self._m(core[1]))
        elif name == "wo":
            if len(core) == 3:   # moe (E, ff, d)
                if self._m(core[0]):
                    spec = ("model", self._f(core[1]), None)
                else:
                    spec = (None, self._f(core[1]), None)
            else:
                spec = (self._m(core[0]), self._f(core[1]))
        elif name == "router":
            spec = (None, None)
        elif name == "in_proj" and in_ssm:
            spec = (self._f(core[0]), self._m(core[1]))
        elif name == "in_proj":   # hybrid shared-attn input concat proj
            spec = (self._f(core[0]), None)
        elif name == "out_proj":
            spec = (self._m(core[0]), self._f(core[1]))
        elif name == "conv_w":
            spec = (None, self._m(core[1]))
        elif name in ("conv_b", "norm"):
            spec = (self._m(core[0]),)
        elif name in ("A_log", "D", "dt_bias", "scale"):
            spec = tuple(None for _ in core)
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    def params_pspecs(self, params_shape):
        return jax.tree_util.tree_map_with_path(self.param_spec,
                                                params_shape)

    # ----- optimizer state --------------------------------------------------
    def opt_spec(self, pspec: P, shape) -> P:
        """ZeRO-1: add data-axis sharding on dim 0 when free & divisible."""
        spec = list(pspec) + [None] * (len(shape) - len(pspec))
        if self.zero1 and not self.fsdp and spec and spec[0] is None \
                and _div(shape[0], self.dsize):
            spec[0] = "data"
        return P(*spec)

    # ----- batch / cache ----------------------------------------------------
    def batch_axis(self, global_batch: int):
        # dp_only: fold the model axis into data parallelism too
        candidates = []
        if self.dp_only:
            candidates.append(self.dax + ("model",))
        candidates.append(self.dax)
        if len(self.dax) > 1:
            candidates.append(self.dax[-1:])
        for axes in candidates:
            size = 1
            for a in axes:
                size *= int(self.mesh.shape[a])
            if _div(global_batch, size):
                return axes if len(axes) > 1 else axes[0]
        # batch too small for any DP split (e.g. long_500k batch=1)
        return None

    def batch_spec(self, batch_shape) -> dict:
        out = {}
        for k, v in batch_shape.items():
            b = self.batch_axis(v.shape[0])
            out[k] = P(*((b,) + (None,) * (len(v.shape) - 1)))
        return out

    def cache_spec(self, path, leaf) -> P:
        """Decode caches. KV: (L, B, S, Hk, hd) — prefer head sharding if
        Hk divides the model axis, else shard S (softmax collectives are
        cheaper than replicating a 32k cache)."""
        names = [getattr(p, "key", None) for p in path]
        names = [n for n in names if n is not None]
        shape = leaf.shape
        name = names[-1] if names else ""
        b = None
        if name in ("k", "v") and len(shape) == 5:
            L, B, S, Hk, hd = shape
            b = self.batch_axis(B)
            if _div(Hk, self.msize):
                return P(None, b, None, "model", None)
            if self.seq_shard_cache and _div(S, self.msize):
                return P(None, b, "model", None, None)
            return P(None, b, None, None, None)
        if name == "conv" and len(shape) == 4:    # (L, B, wc-1, ch)
            return P(None, self.batch_axis(shape[1]), None,
                     self._m(shape[3]))
        if name == "state" and len(shape) == 5:   # (L, B, H, P, N)
            return P(None, self.batch_axis(shape[1]),
                     self._m(shape[2]), None, None)
        if name == "memory" and len(shape) == 3:  # (B, ml, d)
            return P(self.batch_axis(shape[0]), None, None)
        if name == "x0":
            return P(self.batch_axis(shape[0]), None, None)
        b = self.batch_axis(shape[1]) if len(shape) > 1 else None
        return P(*((None, b) + (None,) * (len(shape) - 2)))

    def cache_pspecs(self, cache_shape):
        return jax.tree_util.tree_map_with_path(self.cache_spec,
                                                cache_shape)

    # ----- helpers ----------------------------------------------------------
    def named(self, pspec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            pspec_tree,
                            is_leaf=lambda x: isinstance(x, P))
