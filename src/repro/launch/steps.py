"""Build (step function, abstract sharded inputs) for every
(architecture x shape x mesh) cell — shared by dryrun.py and the drivers.

Everything here is allocation-free: parameters, optimizer state and caches
are `jax.eval_shape` ShapeDtypeStructs with NamedShardings attached.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import data_axis_size
from repro.launch.sharding import ShardingRules
from repro.models import api
from repro.models.config import SHAPES, ArchConfig, ShapeConfig
from repro.models.encdec import DEC_PREFILL_LEN
from repro.models.sharding import logical_rules, rules_for_mesh
from repro.optim import make_optimizer
from repro.train.trainer import TrainConfig, make_train_step

# Per-arch training knobs (optimizer, microbatch budget). Microbatch count
# is clamped so each microbatch still fills the data axis.
TRAIN_KNOBS = {
    "llama3-405b": dict(optimizer="adafactor", microbatches=16,
                        seq_parallel=True, acc_dtype="bfloat16",
                        opt_kwargs=dict(master=False)),
    "granite-34b": dict(optimizer="adafactor", microbatches=8,
                        seq_parallel=True),
    "qwen3-moe-30b-a3b": dict(optimizer="adafactor", microbatches=8,
                              seq_parallel=True),
    "yi-9b": dict(optimizer="adamw", microbatches=4, fsdp=True),
    "zamba2-7b": dict(optimizer="adamw", microbatches=4, fsdp=True),
    "granite-moe-3b-a800m": dict(optimizer="adamw", microbatches=4,
                                 fsdp=True),
    "seamless-m4t-large-v2": dict(optimizer="adamw", microbatches=4),
    "internvl2-1b": dict(optimizer="adamw", microbatches=2),
    "mamba2-130m": dict(optimizer="adamw", microbatches=1),
    "smollm-135m": dict(optimizer="adamw", microbatches=1),
}

# Tiny archs: pure DP — a 16-way TP axis would idle on 9-head / 1536-ff
# dims and replicate attention score memory (DESIGN.md §5).
DP_ONLY_ARCHS = {"smollm-135m", "mamba2-130m"}

# Cells skipped by assignment policy (DESIGN.md §6).
FULL_ATTENTION_ARCHS = {
    "smollm-135m", "yi-9b", "llama3-405b", "granite-34b", "internvl2-1b",
    "qwen3-moe-30b-a3b", "granite-moe-3b-a800m", "seamless-m4t-large-v2",
}


def cell_is_skipped(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return ("long_500k needs sub-quadratic attention; "
                f"{arch} is pure full-attention (skip per assignment)")
    return None


def _sds(tree, shardings):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        tree, shardings)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ArchConfig
    fn: object                 # callable to jit
    args: tuple                # abstract sharded args
    donate: tuple              # donated arg indices
    rules: ShardingRules
    kind: str

    logical: dict | None = None

    def lower(self, mesh):
        jitted = jax.jit(self.fn, donate_argnums=self.donate)
        rules = self.logical or rules_for_mesh(mesh.axis_names)
        with mesh, logical_rules(rules):
            return jitted.lower(*self.args)


def _microbatches(arch, global_batch, dsize):
    want = TRAIN_KNOBS[arch]["microbatches"]
    n = min(want, max(1, global_batch // dsize))
    while global_batch % n or (global_batch // n) % dsize:
        n -= 1
    return max(n, 1)


def abstract_params(cfg: ArchConfig, rules: ShardingRules):
    shapes = jax.eval_shape(
        functools.partial(api.init_params, cfg), jax.random.PRNGKey(0))
    pspecs = rules.params_pspecs(shapes)
    return _sds(shapes, rules.named(pspecs)), pspecs


def batch_struct(cfg: ArchConfig, shape: ShapeConfig, kind: str):
    """Abstract input batch per shape kind (the input_specs() contract)."""
    B, S = shape.global_batch, shape.seq_len
    if kind == "train":
        b = {"inputs": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        if cfg.family == "vlm":
            b["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        elif cfg.family == "encdec":
            b["frontend"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                 jnp.float32)
        return b
    if kind == "prefill":
        if cfg.family == "encdec":
            # long input is the AUDIO side; decoder prefills a short prefix
            return {"inputs": jax.ShapeDtypeStruct((B, DEC_PREFILL_LEN),
                                                   jnp.int32),
                    "frontend": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                     jnp.float32)}
        b = {"inputs": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == "vlm":
            b["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        return b
    raise ValueError(kind)


def build_cell(arch: str, shape_name: str, mesh, *, fsdp=None, zero1=True,
               grad_compress=False, seq_shard_cache=True,
               microbatches=None, dp_only=None, seq_axis=None) -> Cell:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if dp_only is None:
        # tiny archs: pure DP for train/prefill; decode keeps TP so the
        # 32k KV cache can be seq-sharded over the model axis
        dp_only = arch in DP_ONLY_ARCHS and shape.kind != "decode"
    if fsdp is None:
        fsdp = TRAIN_KNOBS[arch].get("fsdp")
    rules = ShardingRules(cfg, mesh, fsdp=fsdp, zero1=zero1,
                          seq_shard_cache=seq_shard_cache, dp_only=dp_only)
    if seq_axis is None and shape.kind != "decode" \
            and TRAIN_KNOBS[arch].get("seq_parallel"):
        seq_axis = "model"
    logical = rules_for_mesh(
        mesh.axis_names, dp_only=dp_only,
        batch_axes=rules.batch_axis(shape.global_batch),
        seq_axis=seq_axis)
    if cfg.family == "moe" and not dp_only:
        from repro.launch.mesh import model_axis_size
        if cfg.n_experts % model_axis_size(mesh) != 0:
            # E doesn't divide the model axis: shard dispatch capacity
            # instead of experts (granite-moe: E=40 on a 16-way axis)
            logical["experts"] = None
            logical["moe_capacity"] = "model"
    dsize = data_axis_size(mesh)
    params_sds, params_pspecs = abstract_params(cfg, rules)

    if shape.kind == "train":
        knobs = TRAIN_KNOBS[arch]
        n_mb = microbatches or _microbatches(arch, shape.global_batch,
                                             dsize)
        opt = make_optimizer(knobs["optimizer"], lr=1e-4,
                             **knobs.get("opt_kwargs", {}))
        tcfg = TrainConfig(optimizer=knobs["optimizer"],
                           microbatches=n_mb, grad_compress=grad_compress,
                           acc_dtype=knobs.get("acc_dtype", "float32"))
        opt_shapes = jax.eval_shape(opt.init, params_sds)
        opt_pspecs = jax.tree_util.tree_map_with_path(
            lambda path, leaf: rules.opt_spec(
                rules.param_spec(path[1:], leaf)
                if path and getattr(path[0], "key", "") in ("master", "m",
                                                            "v")
                else P(), leaf.shape),
            opt_shapes)
        opt_sds = _sds(opt_shapes, rules.named(opt_pspecs))
        batch = batch_struct(cfg, shape, "train")
        bspecs = rules.batch_spec(batch)
        batch_sds = {k: jax.ShapeDtypeStruct(
            v.shape, v.dtype,
            sharding=NamedSharding(mesh, bspecs[k]))
            for k, v in batch.items()}
        step = make_train_step(cfg, tcfg, opt)

        if grad_compress:
            fn = step
            err_shapes = jax.eval_shape(
                lambda p: jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p),
                params_sds)
            err_sds = _sds(err_shapes, rules.named(params_pspecs))
            args = (params_sds, opt_sds, err_sds, batch_sds)
            donate = (0, 1, 2)
        else:
            def fn(params, opt_state, batch):  # noqa
                return step(params, opt_state, {}, batch)
            args = (params_sds, opt_sds, batch_sds)
            donate = (0, 1)
        return Cell(arch, shape, cfg, fn, args, donate, rules, "train",
                    logical=logical)

    if shape.kind == "prefill":
        batch = batch_struct(cfg, shape, "prefill")
        bspecs = rules.batch_spec(batch)
        batch_sds = {k: jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
            for k, v in batch.items()}

        def fn(params, batch):  # noqa
            return api.prefill(params, cfg, batch)
        return Cell(arch, shape, cfg, fn, (params_sds, batch_sds), (),
                    rules, "prefill", logical=logical)

    # ---- decode ------------------------------------------------------------
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        functools.partial(api.make_decode_cache, cfg, B, S))
    cache_pspecs = rules.cache_pspecs(cache_shapes)
    cache_sds = _sds(cache_shapes, rules.named(cache_pspecs))
    tok_sds = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=NamedSharding(mesh, P(rules.batch_axis(B), None)))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))

    def fn(params, cache, token, pos):  # noqa
        return api.decode_step(params, cfg, cache, token, pos)

    return Cell(arch, shape, cfg, fn,
                (params_sds, cache_sds, tok_sds, pos_sds), (1,), rules,
                "decode", logical=logical)
