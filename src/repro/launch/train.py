"""Training launcher.

Two modes:
  * real execution on the available devices (reduced/smoke configs on CPU;
    the same code path drives TPU slices, where jax.distributed supplies
    the device set):
      PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
          --smoke --steps 20 --ckpt-dir /tmp/ckpt
  * production-mesh LOWERING of the exact assigned cell (no execution —
    this container has one CPU device); use launch/dryrun.py for the full
    analysis matrix.

Fault tolerance: --restore resumes from the newest valid checkpoint;
crashes mid-run are recoverable the same way (see examples/train_lm.py
for an injected-failure demo).
"""

from __future__ import annotations

import argparse

from repro import configs
from repro.data.pipeline import PipelineConfig, SyntheticTokens
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    pipe = SyntheticTokens(PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=0, frontend_tokens=(cfg.n_frontend_tokens
                                 if cfg.family in ("vlm", "encdec") else 0),
        d_model=cfg.d_model))
    tcfg = TrainConfig(optimizer=args.optimizer, lr=args.lr,
                       microbatches=args.microbatches,
                       grad_compress=args.grad_compress,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, tcfg, pipe)
    if args.restore and trainer.try_restore():
        print(f"restored from step {trainer.step}")
    hist = trainer.run(args.steps, log_every=max(1, args.steps // 5))
    print(f"done: {trainer.step} steps, final loss {hist[-1]:.4f}")
    if trainer.straggler_steps:
        print(f"straggler steps: {trainer.straggler_steps}")


if __name__ == "__main__":
    main()
