# Model zoo: the 10 assigned architectures as pure-functional JAX models.
