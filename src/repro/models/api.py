"""Family dispatcher: one uniform API over the 10-arch zoo.

  init_params(cfg, rng)                     -> params pytree
  forward(params, cfg, batch)               -> (logits, aux)
  loss_fn(params, cfg, batch)               -> (loss, metrics)
  prefill(params, cfg, batch, max_seq)      -> (logits, cache, pos)
  decode_step(params, cfg, cache, tok, pos) -> (logits, cache)
  decode_hidden(params, cfg, cache, tok, pos) -> (hidden, cache)
  make_decode_cache(cfg, batch_size, seq)   -> cache pytree
  cache_insert_slot(cfg, pool, req, slot)   -> pool cache pytree

``pos`` in the decode entry points is either a () scalar (every batch
row decodes at the same position — the classic lock-step call) or a
(B,) int32 vector of *per-slot* positions: row b writes its KV at
``pos[b]`` and attends keys ``<= pos[b]``; entry ``-1`` marks an
inactive slot whose cache lines (KV, SSM state, conv tail) pass
through unmodified.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer
from repro.models.config import ArchConfig

_MOE_AUX_WEIGHT = 0.01


def _mod(cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer
    if cfg.family in ("ssm", "hybrid"):
        return hybrid
    if cfg.family == "encdec":
        return encdec
    raise ValueError(f"unknown family {cfg.family}")


def init_params(cfg: ArchConfig, rng):
    return _mod(cfg).init_params(cfg, rng)


def forward(params, cfg: ArchConfig, batch):
    return _mod(cfg).forward(params, cfg, batch)


def forward_hidden(params, cfg: ArchConfig, batch):
    """(hidden, aux): the LM-head input over all token positions — for
    callers that supply their own unembed (e.g. a compressed
    `SparseLinear` head contracting all B*S rows through the blocked
    SpMM kernel). Transformer families only."""
    m = _mod(cfg)
    if not hasattr(m, "forward_hidden"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no forward_hidden")
    return m.forward_hidden(params, cfg, batch)


def loss_fn(params, cfg: ArchConfig, batch):
    """Masked next-token cross entropy (+ MoE load-balance aux)."""
    logits, aux = forward(params, cfg, batch)
    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(targets, dtype=jnp.float32)
    mask = mask.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction, NOT take_along_axis: gathering along the
    # model-sharded vocab axis would force an all-gather of full fp32
    # logits (observed +12 GiB/chip on smollm dry-run); the iota-compare
    # form fuses into a local reduction + tiny all-reduce.
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0),
                   axis=-1)
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    metrics = {"nll": loss, "aux": aux,
               "tokens": mask.sum().astype(jnp.float32)}
    if cfg.family == "moe":
        loss = loss + _MOE_AUX_WEIGHT * aux
    return loss, metrics


def prefill(params, cfg: ArchConfig, batch, max_seq=None):
    m = _mod(cfg)
    if not hasattr(m, "prefill"):
        raise NotImplementedError(f"{cfg.family} has no prefill")
    return m.prefill(params, cfg, batch, max_seq=max_seq)


def decode_step(params, cfg: ArchConfig, caches, token, pos):
    return _mod(cfg).decode_step(params, cfg, caches, token, pos)


def decode_hidden(params, cfg: ArchConfig, caches, token, pos):
    """One serving step stopping at the final norm: (hidden, cache)
    with hidden (B, 1, d_model) — what a compressed LM head
    (`repro.serving.sparse_linear.SparseLinear`) consumes in place of
    `decode_step`'s dense-logits path. ``decode_step(...) ==
    (lm_head(params["embed"], hidden), cache)`` for every family."""
    return _mod(cfg).decode_hidden(params, cfg, caches, token, pos)


def make_decode_cache(cfg: ArchConfig, batch_size: int, seq_len: int,
                      dtype=None):
    return _mod(cfg).make_decode_cache(cfg, batch_size, seq_len,
                                       dtype=dtype)


def cache_insert_slot(cfg: ArchConfig, pool, req, slot: int):
    """Insert a batch-size-1 decode cache ``req`` (e.g. returned by
    `prefill(..., max_seq=<pool seq len>)`) into batch slot ``slot`` of
    the pooled decode cache ``pool``. Every cache line of the slot is
    overwritten — the serving engine uses this to admit a freshly
    prefilled request into a slot whose previous occupant finished,
    without leaking the old request's KV/SSM state."""
    return _mod(cfg).cache_insert_slot(cfg, pool, req, slot)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
