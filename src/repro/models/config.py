"""Architecture configuration shared by the whole model zoo."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads

    # --- MoE ---------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) -------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4
    attn_every: int = 0       # hybrid: shared attn block after every N ssm

    # --- encoder-decoder ------------------------------------------------
    n_enc_layers: int = 0     # family == encdec: encoder depth
    n_dec_layers: int = 0     # family == encdec: decoder depth

    # --- modality frontend stubs ---------------------------------------
    frontend: str = ""        # "vision" | "speech" | "" (input_specs stub)
    n_frontend_tokens: int = 256  # patch / frame embeddings per sample

    # --- numerics / compilation ----------------------------------------
    mlp_gated: bool = True   # False: 2-matrix GELU MLP (GPT-BigCode style)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True        # checkpoint each layer in training
    # sub-quadratic attention available (SSM/hybrid) — gates long_500k
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (seq_len x global_batch + step kind)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
