"""Encoder-decoder backbone (SeamlessM4T-style): bidirectional encoder over
precomputed modality frame embeddings (the speech frontend is a stub per the
assignment) + causal decoder with cross-attention.

Shape conventions for the assigned LM shapes (DESIGN.md §6):
  train_4k    : encoder S frames + decoder S tokens (S = seq_len)
  prefill_32k : encoder seq_len frames + decoder prefill of 1024 tokens
  decode_32k  : decoder KV cache of seq_len, encoder memory of
                cfg.n_frontend_tokens frames
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (attention, attention_init, embed,
                                 embedding_init, lm_head, mlp, mlp_init,
                                 pos_vector, rmsnorm, rmsnorm_init)
from repro.models.sharding import shard

DEC_PREFILL_LEN = 1024


def _enc_layer_init(cfg, rng):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": rmsnorm_init(cfg),
        "attn": attention_init(cfg, k1),
        "ln2": rmsnorm_init(cfg),
        "mlp": mlp_init(cfg, k2),
    }


def _dec_layer_init(cfg, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": rmsnorm_init(cfg),
        "self_attn": attention_init(cfg, k1),
        "lnx": rmsnorm_init(cfg),
        "cross_attn": attention_init(cfg, k2),
        "ln2": rmsnorm_init(cfg),
        "mlp": mlp_init(cfg, k3),
    }


def init_params(cfg: ArchConfig, rng):
    ks = jax.random.split(rng, 3)
    ne = cfg.n_enc_layers or cfg.n_layers
    nd = cfg.n_dec_layers or cfg.n_layers
    enc = jax.vmap(lambda k: _enc_layer_init(cfg, k))(
        jax.random.split(ks[0], ne))
    dec = jax.vmap(lambda k: _dec_layer_init(cfg, k))(
        jax.random.split(ks[1], nd))
    return {
        "embed": embedding_init(cfg, ks[2]),
        "enc_layers": enc,
        "enc_norm": rmsnorm_init(cfg),
        "dec_layers": dec,
        "dec_norm": rmsnorm_init(cfg),
    }


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, Se, d) precomputed frontend embeddings."""
    x = frames.astype(cfg.param_dtype)
    x = shard(x, "batch", "seq", "d_model")
    B, Se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        a, _ = attention(lp["attn"], cfg, h, positions, causal=False)
        x = x + a
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(cfg, lp, x, positions, memory, kv_cache=None, cache_pos=None,
               return_cache=False):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    a, new_cache = attention(lp["self_attn"], cfg, h, positions,
                             causal=True, kv_cache=kv_cache,
                             cache_pos=cache_pos, return_cache=return_cache)
    x = x + a
    h = rmsnorm(lp["lnx"], x, cfg.norm_eps)
    a, _ = attention(lp["cross_attn"], cfg, h, positions, causal=False,
                     kv=memory)
    x = x + a
    h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    return x + mlp(lp["mlp"], h), new_cache


def forward(params, cfg: ArchConfig, batch):
    """Training: batch = {frontend: (B,Se,d), inputs: (B,S), targets}."""
    memory = encode(params, cfg, batch["frontend"])
    x = embed(params["embed"], batch["inputs"])
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        x, _ = _dec_layer(cfg, lp, x, positions, memory)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return lm_head(params["embed"], x), jnp.float32(0.0)


def prefill(params, cfg: ArchConfig, batch, max_seq=None):
    memory = encode(params, cfg, batch["frontend"])
    x = embed(params["embed"], batch["inputs"])
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        x, cache = _dec_layer(cfg, lp, x, positions, memory,
                              return_cache=True)
        return x, cache

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    if max_seq is not None and max_seq > S:
        caches = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, max_seq - S),
                                  (0, 0), (0, 0))), caches)
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return (lm_head(params["embed"], x[:, -1:, :]),
            {"kv": caches, "memory": memory}, jnp.int32(S))


def decode_hidden(params, cfg: ArchConfig, caches, token, pos):
    """One decoder step up to the final norm — the hidden states the
    LM head (dense or sparse) consumes; `decode_step` == lm_head of
    this (same contract as `transformer.decode_hidden`). ``pos`` may be
    () or (B,) per-slot positions (-1 = inactive slot, KV write
    masked)."""
    x = embed(params["embed"], token)
    B = token.shape[0]
    pos = pos_vector(pos, B)          # (B,); -1 marks an inactive slot
    positions = pos[:, None]
    memory = caches["memory"]

    def body(x, inp):
        lp, cache = inp
        x, new_cache = _dec_layer(cfg, lp, x, positions, memory,
                                  kv_cache=cache, cache_pos=pos)
        return x, new_cache

    x, new_kv = jax.lax.scan(body, x, (params["dec_layers"], caches["kv"]))
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return x, {"kv": new_kv, "memory": memory}


def decode_step(params, cfg: ArchConfig, caches, token, pos):
    x, new_caches = decode_hidden(params, cfg, caches, token, pos)
    return lm_head(params["embed"], x), new_caches


def cache_insert_slot(cfg: ArchConfig, pool, req, slot: int):
    """Insert a batch-size-1 decode cache (from `prefill`) into batch
    slot ``slot``: decoder self-attention KV carries the batch on axis 1
    (layer-stacked), the encoder memory on axis 0."""
    return {
        "kv": jax.tree.map(
            lambda p, r: jax.lax.dynamic_update_slice_in_dim(
                p, r.astype(p.dtype), slot, axis=1),
            pool["kv"], req["kv"]),
        "memory": jax.lax.dynamic_update_slice_in_dim(
            pool["memory"], req["memory"].astype(pool["memory"].dtype),
            slot, axis=0),
    }


def make_decode_cache(cfg: ArchConfig, batch, seq_len, memory_len=None,
                      dtype=None):
    dtype = dtype or cfg.param_dtype
    nd = cfg.n_dec_layers or cfg.n_layers
    ml = memory_len or cfg.n_frontend_tokens
    return {
        "kv": {
            "k": jnp.zeros((nd, batch, seq_len, cfg.n_kv_heads, cfg.hd),
                           dtype=dtype),
            "v": jnp.zeros((nd, batch, seq_len, cfg.n_kv_heads, cfg.hd),
                           dtype=dtype),
        },
        "memory": jnp.zeros((batch, ml, cfg.d_model),
                            dtype=cfg.param_dtype),
    }
