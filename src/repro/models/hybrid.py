"""Zamba2-style hybrid: a stack of Mamba2 blocks with ONE shared
attention+MLP block applied every ``attn_every`` SSM layers
[arXiv:2411.15242]. The shared block concatenates the current hidden state
with the original embedding (Zamba's residual trick) through an input
projection. Weights of the shared block are stored once; each of its
applications has its own KV cache slot at decode time.

Layout: the first ``n_groups * attn_every`` SSM layers run as a nested scan
(groups outer, layers inner, shared-attention applied between groups); the
remaining ``n_tail`` SSM layers run as one trailing scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (attention, attention_init, embed,
                                 embedding_init, lm_head, matmul, mlp,
                                 mlp_init, pos_vector, rmsnorm,
                                 rmsnorm_init, _dense_init)
from repro.models.sharding import shard
from repro.models.ssm import ssm_block, ssm_cache_init, ssm_init


def _plan(cfg: ArchConfig):
    every = cfg.attn_every or cfg.n_layers + 1
    n_groups = cfg.n_layers // every
    n_tail = cfg.n_layers - n_groups * every
    return every, n_groups, n_tail


def init_params(cfg: ArchConfig, rng):
    every, n_groups, n_tail = _plan(cfg)
    ks = jax.random.split(rng, 5)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {"ln": rmsnorm_init(cfg), "ssm": ssm_init(cfg, k1)}

    layers = jax.vmap(one)(layer_keys)
    out = {
        "embed": embedding_init(cfg, ks[4]),
        "layers": layers,
        "final_norm": rmsnorm_init(cfg),
    }
    if cfg.attn_every:
        out["shared_attn"] = {
            "in_proj": _dense_init(ks[1], (2 * cfg.d_model, cfg.d_model),
                                   cfg.param_dtype),
            "ln1": rmsnorm_init(cfg),
            "attn": attention_init(cfg, ks[2]),
            "ln2": rmsnorm_init(cfg),
            "mlp": mlp_init(cfg, ks[3]),
        }
    return out


def _ssm_layer(cfg, p, x, cache=None):
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    out, new_cache = ssm_block(p["ssm"], cfg, h, cache=cache)
    return x + out, new_cache


def _shared_block(cfg, p, x, x0, positions, kv_cache=None, cache_pos=None,
                  return_cache=False):
    h = jnp.concatenate([x, x0], axis=-1)
    h = matmul(h, p["in_proj"])
    h = rmsnorm(p["ln1"], h, cfg.norm_eps)
    attn_out, new_cache = attention(p["attn"], cfg, h, positions,
                                    causal=True, kv_cache=kv_cache,
                                    cache_pos=cache_pos,
                                    return_cache=return_cache)
    x = x + attn_out
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], h), new_cache


def _slice_layers(layers, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], layers)


def _group_layers(layers, n_groups, every):
    return jax.tree.map(
        lambda a: a[:n_groups * every].reshape((n_groups, every)
                                               + a.shape[1:]), layers)


def forward(params, cfg: ArchConfig, batch):
    every, n_groups, n_tail = _plan(cfg)
    x = embed(params["embed"], batch["inputs"])
    B, S, _ = x.shape
    x0 = x
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    shared = params.get("shared_attn")

    def inner(x, lp):
        x, _ = _ssm_layer(cfg, lp, x)
        return x, None

    inner_fn = jax.checkpoint(inner) if cfg.remat else inner

    def group(x, gp):
        x, _ = jax.lax.scan(inner_fn, x, gp)
        x, _ = _shared_block(cfg, shared, x, x0, positions)
        return x, None

    if n_groups:
        gstack = _group_layers(params["layers"], n_groups, every)
        x, _ = jax.lax.scan(group, x, gstack)
    if n_tail:
        tail = _slice_layers(params["layers"], n_groups * every,
                             cfg.n_layers)
        x, _ = jax.lax.scan(inner_fn, x, tail)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_head(params["embed"], x), jnp.float32(0.0)


def prefill(params, cfg: ArchConfig, batch, max_seq=None):
    """Prefill for SSM/hybrid: forward pass that also emits the decode
    cache (final SSD states + conv tails; per-application KV for the
    shared attention block)."""
    every, n_groups, n_tail = _plan(cfg)
    x = embed(params["embed"], batch["inputs"])
    B, S, _ = x.shape
    x0 = x
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    shared = params.get("shared_attn")

    def inner(x, lp):
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        out, cache = ssm_block(lp["ssm"], cfg, h, return_cache=True)
        return x + out, cache

    ssm_parts = []
    attn_kv = None
    if n_groups:
        gstack = _group_layers(params["layers"], n_groups, every)

        def group(x, gp):
            x, gcache = jax.lax.scan(inner, x, gp)
            x, kv = _shared_block(cfg, shared, x, x0, positions,
                                  return_cache=True)
            return x, (gcache, kv)

        x, (gc, kvs) = jax.lax.scan(group, x, gstack)
        ssm_parts.append(jax.tree.map(
            lambda a: a.reshape((n_groups * every,) + a.shape[2:]), gc))
        attn_kv = kvs                      # {k,v}: (n_groups, B, S, Hk, hd)
    if n_tail:
        tail = _slice_layers(params["layers"], n_groups * every,
                             cfg.n_layers)
        x, tc = jax.lax.scan(inner, x, tail)
        ssm_parts.append(tc)
    ssm_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                             *ssm_parts)
    caches = {"ssm": ssm_cache,
              "x0": jnp.zeros((B, 1, cfg.d_model), dtype=cfg.param_dtype)}
    if attn_kv is not None:
        if max_seq is not None and max_seq > S:
            attn_kv = jax.tree.map(
                lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, max_seq - S),
                                      (0, 0), (0, 0))), attn_kv)
        caches["attn"] = attn_kv
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params["embed"], x[:, -1:, :])
    return logits, caches, jnp.int32(S)


def make_decode_cache(cfg: ArchConfig, batch, seq_len, dtype=None):
    every, n_groups, n_tail = _plan(cfg)
    dtype = dtype or cfg.param_dtype
    ssm0 = ssm_cache_init(cfg, batch)
    out = {
        "ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), ssm0),
        "x0": jnp.zeros((batch, 1, cfg.d_model), dtype=cfg.param_dtype),
    }
    if n_groups:
        out["attn"] = {
            "k": jnp.zeros((n_groups, batch, seq_len, cfg.n_kv_heads,
                            cfg.hd), dtype=dtype),
            "v": jnp.zeros((n_groups, batch, seq_len, cfg.n_kv_heads,
                            cfg.hd), dtype=dtype),
        }
    return out


def decode_hidden(params, cfg: ArchConfig, caches, token, pos):
    """One serving step up to the final norm — the hidden states the
    LM head (dense or sparse) consumes; `decode_step` == lm_head of
    this (same contract as `transformer.decode_hidden`). ``pos`` may be
    a () scalar (all slots in lock step) or a (B,) vector of per-slot
    positions; entries of -1 mark inactive slots, whose SSM state, conv
    tail and attention KV lines all pass through unmodified."""
    every, n_groups, n_tail = _plan(cfg)
    x = embed(params["embed"], token)
    B = token.shape[0]
    pos = pos_vector(pos, B)          # (B,); -1 marks an inactive slot
    x0 = x
    positions = pos[:, None]
    shared = params.get("shared_attn")

    def inner(x, inp):
        lp, cache = inp
        x, new_cache = _ssm_layer(cfg, lp, x, cache=cache)
        return x, new_cache

    ssm_caches = caches["ssm"]

    def group(x, inp):
        gp, gcache, kv = inp
        x, new_gcache = jax.lax.scan(inner, x, (gp, gcache))
        x, new_kv = _shared_block(cfg, shared, x, x0, positions,
                                  kv_cache=kv, cache_pos=pos)
        return x, (new_gcache, new_kv)

    new_attn = caches.get("attn")
    if n_groups:
        gstack = _group_layers(params["layers"], n_groups, every)
        gcaches = jax.tree.map(
            lambda a: a[:n_groups * every].reshape(
                (n_groups, every) + a.shape[1:]), ssm_caches)
        x, (ng, nkv) = jax.lax.scan(group, x, (gstack, gcaches,
                                               caches["attn"]))
        new_head = jax.tree.map(
            lambda a: a.reshape((n_groups * every,) + a.shape[2:]), ng)
        new_attn = nkv
    if n_tail:
        tail_p = _slice_layers(params["layers"], n_groups * every,
                               cfg.n_layers)
        tail_c = jax.tree.map(lambda a: a[n_groups * every:], ssm_caches)
        x, new_tail = jax.lax.scan(inner, x, (tail_p, tail_c))
    parts = []
    if n_groups:
        parts.append(new_head)
    if n_tail:
        parts.append(new_tail)
    new_ssm = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
    # Inactive-slot write mask: the single-token SSM recurrence advances
    # state and conv tail for every batch row unconditionally — a pooled
    # step must not corrupt the state of slots that are not decoding
    # (attention KV already masks its own write inside `attention`).
    active = pos >= 0
    new_ssm = jax.tree.map(
        lambda new, old: jnp.where(
            active.reshape((1, -1) + (1,) * (new.ndim - 2)), new, old),
        new_ssm, ssm_caches)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_caches = {"ssm": new_ssm, "x0": caches["x0"]}
    if new_attn is not None:
        new_caches["attn"] = new_attn
    return x, new_caches


def decode_step(params, cfg: ArchConfig, caches, token, pos):
    x, new_caches = decode_hidden(params, cfg, caches, token, pos)
    return lm_head(params["embed"], x), new_caches


def cache_insert_slot(cfg: ArchConfig, pool, req, slot: int):
    """Insert a batch-size-1 decode cache (from `prefill`) into batch
    slot ``slot`` of a pooled cache. SSM states and attention KV carry
    the batch on axis 1 (layer/group-stacked); the pass-through ``x0``
    buffer on axis 0. Every cache line of the slot is overwritten —
    stale SSM state from the slot's previous occupant cannot leak."""
    def ins(axis):
        return lambda p, r: jax.lax.dynamic_update_slice_in_dim(
            p, r.astype(p.dtype), slot, axis=axis)

    out = {"ssm": jax.tree.map(ins(1), pool["ssm"], req["ssm"]),
           "x0": ins(0)(pool["x0"], req["x0"])}
    if "attn" in pool:
        out["attn"] = jax.tree.map(ins(1), pool["attn"], req["attn"])
    return out
