"""Shared functional layers: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Params are plain nested dicts of jnp arrays; every layer is a pair
(init_fn, apply_fn). Matmuls accumulate in fp32 (preferred_element_type)
and cast back to the activation dtype — standard large-model numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.sharding import shard


def _dense_init(rng, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else float(1.0 / np.sqrt(fan_in))
    return (jax.random.normal(rng, shape, dtype=jnp.float32)
            * scale).astype(dtype)


def matmul(x, w):
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


# --- RMSNorm ----------------------------------------------------------------

def rmsnorm_init(cfg: ArchConfig, d=None):
    return {"scale": jnp.ones((d or cfg.d_model,), dtype=cfg.param_dtype)}


def rmsnorm(p, x, eps):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --- RoPE -------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), \
        x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def pos_vector(pos, batch: int):
    """Normalize a decode position to a per-slot (B,) int32 vector.

    Scalar positions (the classic all-slots-in-lock-step call) broadcast
    to every batch row; a (B,) vector passes through. Convention shared
    by every family backend: entry ``-1`` marks an *inactive* slot —
    attention skips its cache write and masks out every key, and the
    SSM recurrence keeps its previous state.
    """
    p = jnp.asarray(pos, dtype=jnp.int32)
    return jnp.broadcast_to(p, (batch,)) if p.ndim == 0 else p


# --- GQA attention ------------------------------------------------------------

def attention_init(cfg: ArchConfig, rng, d=None, n_heads=None,
                   n_kv_heads=None):
    d = d or cfg.d_model
    H = n_heads or cfg.n_heads
    Hk = n_kv_heads or cfg.n_kv_heads
    hd = cfg.hd
    ks = jax.random.split(rng, 4)
    dt = cfg.param_dtype
    return {
        "wq": _dense_init(ks[0], (d, H * hd), dt),
        "wk": _dense_init(ks[1], (d, Hk * hd), dt),
        "wv": _dense_init(ks[2], (d, Hk * hd), dt),
        "wo": _dense_init(ks[3], (H * hd, d), dt),
    }


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, hk, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, hk, n_rep, hd)).reshape(b, s, hk * n_rep,
                                                           hd)


def attention(p, cfg: ArchConfig, x, positions, *, causal=True,
              kv_cache=None, cache_pos=None, kv=None,
              n_heads=None, n_kv_heads=None, return_cache=False):
    """GQA attention.

    x: (B, S, d). kv: optional cross-attention memory (B, Sk, d).
    kv_cache: optional dict {k, v: (B, Smax, Hk, hd)}; cache_pos: () int
    or (B,) int32 — write position for the current step; returns
    (out, new_cache). A (B,) cache_pos serves batch slots holding
    requests of unequal length: slot b writes its K/V row at
    ``cache_pos[b]`` and attends keys ``<= cache_pos[b]`` only, and a
    *negative* position marks an inactive slot — it matches no cache
    row, so the write is masked out entirely (the slot's live cache
    lines survive pooled steps it does not participate in).
    return_cache=True (prefill): return this call's {k, v} as the cache.
    """
    H = n_heads or cfg.n_heads
    Hk = n_kv_heads or cfg.n_kv_heads
    hd = cfg.hd
    B, S, _ = x.shape
    q = matmul(x, p["wq"]).reshape(B, S, H, hd)
    src = x if kv is None else kv
    k = matmul(src, p["wk"]).reshape(B, src.shape[1], Hk, hd)
    v = matmul(src, p["wv"]).reshape(B, src.shape[1], Hk, hd)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)

    cp = None if cache_pos is None \
        else jnp.asarray(cache_pos, dtype=jnp.int32)
    if kv is None:  # self-attention: rotary embedding
        if kv_cache is None:
            kpos = positions
        else:
            kpos = jnp.broadcast_to(cp[:, None] if cp.ndim else cp,
                                    (B, src.shape[1]))
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kpos, cfg.rope_theta)

    new_cache = {"k": k, "v": v} if return_cache else None
    if kv_cache is not None:
        if cp.ndim == 0:
            z = jnp.int32(0)
            idx = (z, cp, z, z)
            ck = jax.lax.dynamic_update_slice(kv_cache["k"], k.astype(
                kv_cache["k"].dtype), idx)
            cv = jax.lax.dynamic_update_slice(kv_cache["v"], v.astype(
                kv_cache["v"].dtype), idx)
        else:
            # Per-slot scatter (S == 1 decode): slot b writes at its own
            # position cp[b]; a negative cp[b] (inactive slot) matches no
            # cache row — the write is fully masked and the slot's cache
            # lines pass through untouched.
            Smax = kv_cache["k"].shape[1]
            hit = (jnp.arange(Smax, dtype=jnp.int32)[None, :]
                   == cp[:, None])[:, :, None, None]
            ck = jnp.where(hit, k.astype(kv_cache["k"].dtype),
                           kv_cache["k"])
            cv = jnp.where(hit, v.astype(kv_cache["v"].dtype),
                           kv_cache["v"])
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv

    n_rep = H // Hk
    Sk = k.shape[1]

    if kv_cache is not None:
        # decode: grouped-GQA attention straight against the bf16 cache —
        # no head-replicated K/V materialization (16x for 128q/8kv heads),
        # no fp32 cache copy (dots accumulate in fp32 via
        # preferred_element_type)
        scale = float(1.0 / np.sqrt(hd))
        qg = q.reshape(B, S, Hk, n_rep, hd)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
        kpos_ids = jnp.arange(Sk, dtype=jnp.int32)
        if cp.ndim == 0:
            mask = (kpos_ids <= cp)[None, None, None, None, :]
        else:
            # per-slot causal horizon: slot b attends keys <= cp[b] only
            mask = (kpos_ids[None, :]
                    <= cp[:, None])[:, None, None, None, :]
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(x.dtype), v,
                         preferred_element_type=jnp.float32)
        out = out.astype(x.dtype).reshape(B, S, H, hd)
    elif S > _FLASH_THRESHOLD:
        # long-sequence prefill/training: blocked online-softmax attention
        # (never materializes the S x Sk score matrix)
        kf = _repeat_kv(k, n_rep)
        vf = _repeat_kv(v, n_rep)
        out = _flash_attention(q, kf, vf,
                               causal=causal and kv is None)
    else:
        kf = _repeat_kv(k, n_rep)
        vf = _repeat_kv(v, n_rep)
        scale = float(1.0 / np.sqrt(hd))
        logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            kf.astype(jnp.float32)) * scale
        if causal and kv is None:
            qi = jnp.arange(S, dtype=jnp.int32)[:, None]
            ki = jnp.arange(Sk, dtype=jnp.int32)[None, :]
            logits = jnp.where((ki <= qi)[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs,
                         vf.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(B, S, H * hd)
    out = matmul(out, p["wo"])
    return shard(out, "batch", "seq", "d_model"), new_cache


_FLASH_THRESHOLD = 2048   # above this, use blocked attention
_FLASH_BLOCK_Q = 2048
_FLASH_BLOCK_K = 1024


def _flash_attention(q, k, v, *, causal, block_q=None, block_k=None):
    """Blocked attention with online softmax (Flash-style, pure JAX).

    q: (B, Sq, H, hd); k, v: (B, Sk, H, hd). Peak memory per step is
    O(block_q x block_k) instead of O(Sq x Sk).

    Numerics/memory (§Perf iterations 405B-2a/2b): q/k/v stay in their
    input dtype (bf16) — dots accumulate in fp32 via
    preferred_element_type; the probability block is cast back to the
    input dtype for the PV matmul (standard flash practice). Masks are
    iota-compares computed inline per step (fusible), never carried
    through the scan.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q or _FLASH_BLOCK_Q, Sq)
    bk = min(block_k or _FLASH_BLOCK_K, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    pad_q = nq * bq - Sq
    pad_k = nk * bk - Sk
    scale = float(1.0 / np.sqrt(hd))
    qpad = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kpad = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qb = qpad.reshape(B, nq, bq, H, hd).transpose(1, 0, 3, 2, 4)
    kb = kpad.reshape(B, nk, bk, H, hd).transpose(1, 0, 3, 2, 4)
    vb = vpad.reshape(B, nk, bk, H, hd).transpose(1, 0, 3, 2, 4)

    def q_block(args):
        qi, qblk = args                                   # (), (B,H,bq,hd)
        qpos = qi * bq + jnp.arange(bq, dtype=jnp.int32)  # (bq,)

        def kv_step(carry, inp):
            m, s, acc = carry
            ki, kblk, vblk = inp
            logits = jnp.einsum(
                "bhqd,bhkd->bhqk", qblk, kblk,
                preferred_element_type=jnp.float32) * scale
            kpos = ki * bk + jnp.arange(bk, dtype=jnp.int32)
            mask = kpos[None, :] < Sk
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            logits = jnp.where(mask[None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            s_new = s * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, s_new, acc_new), None

        m0 = jnp.full((B, H, bq), -jnp.inf, dtype=jnp.float32)
        s0 = jnp.zeros((B, H, bq), dtype=jnp.float32)
        a0 = jnp.zeros((B, H, bq, hd), dtype=jnp.float32)
        (m, s, acc), _ = jax.lax.scan(
            kv_step, (m0, s0, a0),
            (jnp.arange(nk, dtype=jnp.int32), kb, vb))
        return acc / jnp.maximum(s[..., None], 1e-30)

    out = jax.lax.map(q_block, (jnp.arange(nq, dtype=jnp.int32), qb))
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * bq, H, hd)
    return out[:, :Sq].astype(q.dtype)


# --- SwiGLU MLP ---------------------------------------------------------------

def mlp_init(cfg: ArchConfig, rng, d=None, d_ff=None):
    d = d or cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    dt = cfg.param_dtype
    p = {
        "wi": _dense_init(ks[0], (d, ff), dt),
        "wo": _dense_init(ks[2], (ff, d), dt),
    }
    if cfg.mlp_gated:
        p["wg"] = _dense_init(ks[1], (d, ff), dt)
    return p


def mlp(p, x):
    if "wg" in p:     # SwiGLU
        h = jax.nn.silu(matmul(x, p["wg"]).astype(jnp.float32)
                        ).astype(x.dtype)
        h = h * matmul(x, p["wi"])
    else:             # 2-matrix GELU (GPT-BigCode / granite-code style)
        h = jax.nn.gelu(matmul(x, p["wi"]).astype(jnp.float32)
                        ).astype(x.dtype)
    h = shard(h, "batch", "seq", "ff")
    return shard(matmul(h, p["wo"]), "batch", "seq", "d_model")


# --- Embedding / LM head --------------------------------------------------------

def embedding_init(cfg: ArchConfig, rng):
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 2)
    p = {"tok": _dense_init(ks[0], (cfg.vocab, cfg.d_model), dt, scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(ks[1], (cfg.d_model, cfg.vocab), dt)
    return p


def embed(p, tokens):
    out = jnp.take(p["tok"], tokens, axis=0)
    return shard(out, "batch", "seq", "d_model")


def lm_head(p, x):
    w = p["head"] if "head" in p else p["tok"].T
    logits = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return shard(logits, "batch", "seq", "vocab")
