"""Mixture-of-Experts block: top-k routing with per-expert capacity.

Dispatch is scatter/gather based (GShard-style but without materializing the
(tokens, E, C) one-hot): token ranks within their expert come from a cumsum
over the routing matrix, tokens beyond capacity are dropped (weights
renormalized), experts are sharded over the ``model`` mesh axis (EP).
An auxiliary load-balance loss (Switch Transformer eq. 4) is returned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import _dense_init, matmul
from repro.models.sharding import shard


def moe_init(cfg: ArchConfig, rng):
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 4)
    dt = cfg.param_dtype
    scale = float(1.0 / np.sqrt(d))
    return {
        "router": _dense_init(ks[0], (d, E), jnp.float32),  # fp32 router
        "wi": (jax.random.normal(ks[1], (E, d, ff), dtype=jnp.float32)
               * scale).astype(dt),
        "wg": (jax.random.normal(ks[2], (E, d, ff), dtype=jnp.float32)
               * scale).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, ff, d), dtype=jnp.float32)
               / float(np.sqrt(ff))).astype(dt),
    }


def moe(p, cfg: ArchConfig, x):
    """x: (B, S, d) -> (out (B, S, d), aux_loss ())."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.dot(xt.astype(jnp.float32), p["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)               # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- capacity + ranks ---------------------------------------------------
    # Rank of each assignment within its expert, computed CHUNKED over the
    # token axis (scan carries per-expert running counts): peak memory is
    # O(chunk x E) instead of O(T*K x E) — the unchunked one-hot cumsum was
    # 83 GB/chip on the 1M-token MoE prefill cells (§Perf iteration C4).
    capacity = int(np.ceil(T * K / E * cfg.capacity_factor))
    flat_e = gate_idx.reshape(-1)                               # (T*K,)
    CHUNK = 65536
    n_chunks = -(-(T * K) // CHUNK)
    pad = n_chunks * CHUNK - T * K
    fe_pad = jnp.pad(flat_e, (0, pad), constant_values=E)  # E -> no expert

    def _rank_chunk(counts, fe):
        oh = jax.nn.one_hot(fe, E, dtype=jnp.int32)             # (CHUNK, E)
        within = jnp.cumsum(oh, axis=0) - oh
        r = (within + counts[None, :])[jnp.arange(fe.shape[0]), fe
                                       % jnp.int32(E)]
        r = jnp.where(fe < E, r, capacity)                      # pad -> drop
        return counts + jnp.sum(oh, axis=0, dtype=jnp.int32), r

    _, ranks = jax.lax.scan(_rank_chunk,
                            jnp.zeros((E,), dtype=jnp.int32),
                            fe_pad.reshape(n_chunks, CHUNK))
    ranks = ranks.reshape(-1)[:T * K]
    keep = ranks < capacity

    # --- dispatch: gather tokens into (E, C, d) ---------------------------
    # Only an int32 slot->token map is scattered (E*C entries); the bf16
    # activations are then GATHERED — avoiding both the (T*K, d) repeat
    # and the (E*C, d) data scatter of the naive dispatch (~10 GB per
    # layer step on qwen3-30B; §Perf iteration moe-2).
    slot = jnp.where(keep, flat_e * capacity + ranks, E * capacity)
    tok_ids = jnp.arange(T * K, dtype=jnp.int32) // K           # (T*K,)
    tok_of_slot = jnp.zeros((E * capacity + 1,), dtype=jnp.int32)
    tok_of_slot = tok_of_slot.at[slot].set(tok_ids)
    xe = jnp.take(xt, tok_of_slot[:-1], axis=0).reshape(E, capacity, d)
    # EP when E divides the model axis; otherwise shard the capacity dim
    # (launcher maps exactly one of the two names to "model")
    xe = shard(xe, "experts", "moe_capacity", "d_model")

    # --- expert computation: params' dtype with fp32 accumulation --------
    pt = jnp.float32
    h = jnp.einsum("ecd,edf->ecf", xe, p["wg"],
                   preferred_element_type=pt)
    h = jax.nn.silu(h).astype(x.dtype)
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["wi"],
                       preferred_element_type=pt).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"],
                    preferred_element_type=pt).astype(x.dtype)
    ye = shard(ye, "experts", "moe_capacity", "d_model")

    # --- combine: gather back and weight ----------------------------------
    flat = ye.reshape(E * capacity, d)
    gathered = jnp.take(flat, jnp.clip(slot, 0, E * capacity - 1), axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0)
    # keep the combine result batch-sharded (T is B*S flattened, B-major)
    gathered = shard(gathered.reshape(T, K, d), "batch", None, None
                     ).reshape(T * K, d)
    w = (gate_vals.reshape(-1) * keep).astype(x.dtype)
    out = (gathered.reshape(T, K, d)
           * w.reshape(T, K, 1)).sum(axis=1).astype(x.dtype)

    # --- Switch load-balance aux loss -------------------------------------
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.bincount(flat_e, weights=keep.astype(jnp.float32),
                      length=E) / max(T * K, 1)
    aux = E * jnp.sum(me * ce)
    return shard(out.reshape(B, S, d), "batch", "seq", "d_model"), aux
