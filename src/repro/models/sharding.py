"""Logical-axis activation sharding (DIY flax-style logical rules).

Model code annotates activations with logical names via `shard(x, ...)`;
the launcher installs a mapping logical-name -> mesh axes before tracing.
Outside a mesh context the annotations are identity, so smoke tests on one
CPU device run the exact same model code.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_rules() -> dict | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_rules(rules: dict):
    """rules: logical axis name -> mesh axis (str, tuple of str, or None)."""
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shard(x, *names):
    """Annotate ``x`` with logical axis ``names`` (one per dim; None = any).

    No-op unless inside `logical_rules` (installed by the launcher) and an
    active mesh context.
    """
    rules = current_rules()
    if rules is None:
        return x
    axes = [rules.get(n) if n is not None else None for n in names]
    # de-duplicate mesh axes: a later dim wins (sequence-parallel runs map
    # both "seq" and "heads"/"ff"/"vocab" to the model axis; inside the
    # sharded-compute section the compute dim keeps it, Megatron-style)
    seen = set()
    for i in range(len(axes) - 1, -1, -1):
        flat = axes[i] if isinstance(axes[i], tuple) else (axes[i],)
        if any(a in seen for a in flat if a):
            axes[i] = None
        seen.update(a for a in flat if a)
    return jax.lax.with_sharding_constraint(x, P(*axes))


# Canonical rule sets -------------------------------------------------------

def rules_for_mesh(axis_names: tuple, *, dp_only: bool = False,
                   batch_axes=None, seq_axis=None) -> dict:
    """Standard DP/TP/SP/EP mapping for ('data','model') or
    ('pod','data','model') meshes (DESIGN.md §5).

    dp_only: pure data parallelism (tiny models — TP would idle on
    sub-16-way head/ff dims); batch_axes/seq_axis override the defaults
    (per-cell batch divisibility, sequence-parallel perf runs)."""
    data_axes = tuple(a for a in axis_names if a in ("pod", "data"))
    data = data_axes if len(data_axes) > 1 else (data_axes[0]
                                                 if data_axes else None)
    tp = None if dp_only else "model"
    return {
        "batch": data if batch_axes is None else batch_axes,
        "seq": seq_axis,      # "model" for sequence-parallel runs
        "d_model": None,
        "heads": tp,
        "kv_heads": tp,
        "ff": tp,
        "vocab": tp,
        "experts": tp,
        "moe_capacity": None,   # launcher flips to "model" when E doesn't
                                # divide the model axis (see launch/steps)
        "ssm_heads": tp,
        "capacity": None,
        "state": None,
    }
