"""Mamba2 block — SSD (state-space duality) chunked algorithm
[arXiv:2405.21060], plus the single-token recurrence for decoding.

Chunked scan: intra-chunk outputs use the quadratic "attention-like" dual
form; inter-chunk state is a (cheap) linear recurrence over chunk summaries
via `lax.scan`. State per head: (headdim x d_state); G=1 B/C groups.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import _dense_init, matmul, rmsnorm
from repro.models.sharding import shard


def ssm_init(cfg: ArchConfig, rng):
    d, din = cfg.d_model, cfg.d_inner
    N, H = cfg.ssm_state, cfg.ssm_heads
    wc = cfg.conv_width
    conv_ch = din + 2 * N   # x, B, C go through the depthwise conv
    ks = jax.random.split(rng, 5)
    dt = cfg.param_dtype
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * din + 2 * N + H), dt),
        "conv_w": (jax.random.normal(ks[1], (wc, conv_ch),
                                     dtype=jnp.float32) / wc).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dtype=dt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "norm": jnp.ones((din,), dtype=dt),
        "out_proj": _dense_init(ks[4], (din, d), dt),
    }


def _segsum(a):
    """a: (..., T). out[..., i, j] = sum_{k=j+1..i} a_k (i >= j), else -inf."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    return jnp.where(i >= j, seg, -jnp.inf)


def _ssd_chunked(x, a, Bm, Cm, chunk):
    """x: (b,s,h,p) f32; a: (b,s,h) f32 (negative decays);
    Bm, Cm: (b,s,n) f32 (G=1, broadcast over heads). Returns (b,s,h,p)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)   # (b,h,nc,T)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)                          # (b,h,nc,T)
    L = jnp.exp(_segsum(ac))                                 # (b,h,nc,T,T)

    # 1. intra-chunk (diagonal blocks)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)

    # 2. chunk summaries (state contribution of each chunk)
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)          # (b,h,nc,T)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", Bc, decay_states, xc)

    # 3. inter-chunk recurrence  S_{c} = S_{c-1} * exp(sum a_c) + states_c
    chunk_decay = jnp.exp(a_cum[..., -1])                    # (b,h,nc)

    def scan_fn(carry, inp):
        st, dec = inp                                        # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                    # emit PREVIOUS

    init = jnp.zeros((b, h, p, n), dtype=x.dtype)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4),                    # (nc,b,h,p,n)
         chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (b,nc,h,p,n)

    # 4. off-diagonal (previous chunks -> this chunk's outputs)
    state_decay = jnp.exp(a_cum)                             # (b,h,nc,T)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states,
                       state_decay)
    return (y_diag + y_off).reshape(b, s, h, p), final_state


def _split_proj(cfg: ArchConfig, zxbcdt):
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din:din + din + 2 * N]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def ssm_block(p, cfg: ArchConfig, u, *, cache=None, return_cache=False):
    """u: (B, S, d). cache (decode): dict(conv (B, wc-1, ch), state
    (B, H, P, N), none for training/prefill). Returns (out, new_cache).
    return_cache=True (prefill): emit the end-of-sequence (conv, state)
    cache for subsequent decoding."""
    B, S, d = u.shape
    din, N, H, P = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                    cfg.ssm_headdim)
    wc = cfg.conv_width
    zxbcdt = matmul(u, p["in_proj"])
    z, xBC, dtr = _split_proj(cfg, zxbcdt)
    z = shard(z, "batch", "seq", "ff")
    xBC = shard(xBC, "batch", "seq", None)

    A = -jnp.exp(p["A_log"])                                 # (H,)
    dt_f = jax.nn.softplus(dtr.astype(jnp.float32)
                           + p["dt_bias"])                   # (B,S,H)

    if cache is None:
        # causal depthwise conv over (x,B,C) channels
        pad = jnp.zeros((B, wc - 1, xBC.shape[-1]), dtype=xBC.dtype)
        xp = jnp.concatenate([pad, xBC], axis=1)
        conv = sum(xp[:, k:k + S, :].astype(jnp.float32)
                   * p["conv_w"][k].astype(jnp.float32)
                   for k in range(wc)) + p["conv_b"].astype(jnp.float32)
        xBC_c = jax.nn.silu(conv)
        xs = shard(xBC_c[..., :din].reshape(B, S, H, P),
                   "batch", "seq", "ssm_heads", None)
        Bm = xBC_c[..., din:din + N]
        Cm = xBC_c[..., din + N:]
        a = shard(dt_f * A, "batch", "seq", "ssm_heads")     # (B,S,H)
        xdt = xs * dt_f[..., None]
        chunk = min(cfg.ssm_chunk, S)
        pad_s = (-S) % chunk
        if pad_s:
            # pad with x=0 (no contribution) and a=0 (decay 1, state kept)
            xdt = jnp.pad(xdt, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
            a = jnp.pad(a, ((0, 0), (0, pad_s), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad_s), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad_s), (0, 0)))
        else:
            Bm_p, Cm_p = Bm, Cm
        y, final_state = _ssd_chunked(xdt, a, Bm_p, Cm_p, chunk)
        y = y[:, :S]
        y = y + p["D"][None, None, :, None] * xs
        new_cache = None
        if return_cache:
            tail = xp[:, S:S + wc - 1, :]     # last wc-1 raw conv inputs
            new_cache = {"conv": tail.astype(u.dtype),
                         "state": final_state.astype(jnp.float32)}
    else:
        # single-token recurrence (S == 1)
        conv_st = cache["conv"]                              # (B, wc-1, ch)
        window = jnp.concatenate([conv_st, xBC], axis=1)     # (B, wc, ch)
        conv = (window.astype(jnp.float32)
                * p["conv_w"].astype(jnp.float32)[None]).sum(axis=1) \
            + p["conv_b"].astype(jnp.float32)
        xBC_c = jax.nn.silu(conv)[:, None, :]                # (B,1,ch)
        xs = xBC_c[..., :din].reshape(B, 1, H, P)
        Bm = xBC_c[..., din:din + N]                         # (B,1,N)
        Cm = xBC_c[..., din + N:]
        a = jnp.exp(dt_f * A)                                # (B,1,H)
        st = cache["state"]                                  # (B,H,P,N) f32
        upd = jnp.einsum("bhp,bn->bhpn", (xs * dt_f[..., None])[:, 0],
                         Bm[:, 0])
        st = st * a[:, 0, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", st, Cm[:, 0])[:, None]
        y = y + p["D"][None, None, :, None] * xs
        new_cache = {"conv": window[:, 1:, :], "state": st}

    y = y.reshape(B, S, din).astype(u.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    y = rmsnorm({"scale": p["norm"]}, y, cfg.norm_eps)
    out = matmul(y, p["out_proj"])
    return shard(out, "batch", "seq", "d_model"), new_cache


def ssm_cache_init(cfg: ArchConfig, batch, dtype=jnp.float32):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch),
                          dtype=cfg.param_dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                            cfg.ssm_state), dtype=dtype),
    }
