"""Decoder-only LM backbone (llama-style), covering the dense, MoE and
VLM/frontend-stub families. Layers are stacked and driven by `lax.scan`
(compile-time O(1) in depth — required for the 126-layer 405B config);
each layer is rematerialized in training when cfg.remat.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (attention, attention_init, embed,
                                 embedding_init, lm_head, mlp, mlp_init,
                                 pos_vector, rmsnorm, rmsnorm_init)
from repro.models.moe import moe, moe_init
from repro.models.sharding import shard


def _layer_init(cfg: ArchConfig, rng):
    ks = jax.random.split(rng, 4)
    p = {
        "ln1": rmsnorm_init(cfg),
        "attn": attention_init(cfg, ks[0]),
        "ln2": rmsnorm_init(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_init(cfg, ks[1])
    else:
        p["mlp"] = mlp_init(cfg, ks[1])
    return p


def init_params(cfg: ArchConfig, rng):
    k_emb, k_layers = jax.random.split(rng)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    return {
        "embed": embedding_init(cfg, k_emb),
        "layers": layers,
        "final_norm": rmsnorm_init(cfg),
    }


def _layer_apply(cfg: ArchConfig, p, x, positions, kv_cache=None,
                 cache_pos=None, return_cache=False):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, new_cache = attention(
        p["attn"], cfg, h, positions, causal=True, kv_cache=kv_cache,
        cache_pos=cache_pos, return_cache=return_cache)
    x = x + attn_out
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        ff, aux = moe(p["moe"], cfg, h)
    else:
        ff, aux = mlp(p["mlp"], h), jnp.float32(0.0)
    return x + ff, aux, new_cache


def _embed_inputs(cfg: ArchConfig, params, batch):
    """Token embedding, with frontend-stub embeddings prepended for the
    vlm family (precomputed patch/frame embeddings, DESIGN.md §4)."""
    x = embed(params["embed"], batch["inputs"])
    if cfg.family == "vlm" and "frontend" in batch:
        fe = batch["frontend"].astype(x.dtype)       # (B, P, d)
        fe = shard(fe, "batch", "seq", "d_model")
        x = jnp.concatenate([fe, x], axis=1)
    return x


def forward_hidden(params, cfg: ArchConfig, batch):
    """Training/eval hidden states: the (B, S, d_model) LM-head input
    (post final norm). Returns (hidden, aux) — `forward` is
    ``lm_head(params["embed"], hidden)``; callers that swap the unembed
    for a compressed head (`repro.serving.SparseLinear`) start here."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, layer_p):
        x, aux = carry
        x, a, _ = _layer_apply(cfg, layer_p, x, positions)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.family == "vlm" and "frontend" in batch:
        x = x[:, batch["frontend"].shape[1]:, :]      # text positions only
    return x, aux


def forward(params, cfg: ArchConfig, batch):
    """Training/eval forward. Returns (logits over token positions, aux)."""
    x, aux = forward_hidden(params, cfg, batch)
    return lm_head(params["embed"], x), aux


def prefill(params, cfg: ArchConfig, batch, max_seq: int | None = None):
    """Prefill pass: returns (last-position logits, kv cache, next pos)."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, layer_p):
        x, _, cache = _layer_apply(cfg, layer_p, x, positions,
                                   return_cache=True)
        return x, cache

    x, caches = jax.lax.scan(body, x, params["layers"])
    if max_seq is not None and max_seq > S:
        pad = max_seq - S
        caches = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0),
                                  (0, 0))), caches)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params["embed"], x[:, -1:, :])
    return logits, caches, jnp.int32(S)


def decode_hidden(params, cfg: ArchConfig, caches, token, pos):
    """One serving step up to (and including) the final norm: the
    (B, 1, d) hidden states the LM head — dense `lm_head` or a
    compressed `SparseLinear` — consumes. `decode_step` is exactly
    ``lm_head(decode_hidden(...))``; the serving engine calls this
    directly when the output projection is sparse."""
    x = embed(params["embed"], token)
    B = token.shape[0]
    pos = pos_vector(pos, B)          # (B,); -1 marks an inactive slot
    positions = pos[:, None]

    def body(x, inp):
        layer_p, cache = inp
        x, _, new_cache = _layer_apply(cfg, layer_p, x, positions,
                                       kv_cache=cache, cache_pos=pos)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_caches


def decode_step(params, cfg: ArchConfig, caches, token, pos):
    """One serving step: token (B, 1) int32, pos () int32 — the write
    position (number of tokens already in the cache) — or (B,) int32
    per-slot write positions (entry -1 = inactive slot, no cache
    write)."""
    x, new_caches = decode_hidden(params, cfg, caches, token, pos)
    return lm_head(params["embed"], x), new_caches


def cache_insert_slot(cfg: ArchConfig, pool, req, slot: int):
    """Insert a single-request decode cache (batch size 1 — e.g. the
    cache `prefill(..., max_seq=pool length)` returns) into batch slot
    ``slot`` of a pooled decode cache. The slot's whole cache line is
    overwritten, so stale K/V from the slot's previous occupant cannot
    leak into the new request."""
    return jax.tree.map(
        lambda p, r: jax.lax.dynamic_update_slice_in_dim(
            p, r.astype(p.dtype), slot, axis=1), pool, req)


def make_decode_cache(cfg: ArchConfig, batch, seq_len, dtype=None):
    """Allocate (or spec) the stacked KV cache for decode shapes."""
    dtype = dtype or cfg.param_dtype
    return {
        "k": jnp.zeros((cfg.n_layers, batch, seq_len, cfg.n_kv_heads,
                        cfg.hd), dtype=dtype),
        "v": jnp.zeros((cfg.n_layers, batch, seq_len, cfg.n_kv_heads,
                        cfg.hd), dtype=dtype),
    }
