"""repro.obs: dependency-free metrics + tracing for the serving stack.

The paper's core claim is a *measured* speedup; SMASH makes the same
point structurally — compression only pays when decode time hides behind
the consumer, which nothing can know without instrumentation on the real
execution path. This package is that instrumentation layer:

* **Metrics** (`repro.obs.metrics`): a `MetricsRegistry` of counters,
  gauges and histograms. Histograms keep a bounded reservoir and report
  exact p50/p95/p99 (numpy-compatible linear interpolation) while the
  sample count fits the reservoir; beyond it, seeded reservoir sampling
  keeps the quantiles representative at fixed memory. `snapshot()` is
  lock-free — it copies instrument state without stopping writers.
* **Tracing** (`repro.obs.trace`): a `span()` context manager and
  `event()` emitter writing JSONL to the path in ``$REPRO_TRACE`` (or
  `configure_trace(path)`). With no sink configured both are near-free
  no-ops — the serving engine stays instrumented in production with
  sub-2% overhead (measured by ``benchmarks.run --only load``).

Instrumented layers: `serving.Engine` (step/prefill/decode/refill wall
time, tokens/sec, occupancy, queue depth, TTFT, end-to-end latency),
`serving.SparseLinear` + `kernels.ops` (decode invocations, bytes moved
per SpMM, batch-size histogram), and `repro.autotune` (decision-cache
hits/misses, timing dispersion, selection events). `docs/observability.md`
lists every metric name and the trace schema.
"""

from repro.obs.metrics import (NULL, Counter, Gauge, Histogram,
                               MetricsRegistry, default_registry)
from repro.obs.trace import (configure_trace, event, span, trace_active,
                             trace_path)

__all__ = [
    "NULL", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "configure_trace", "default_registry", "event", "span",
    "trace_active", "trace_path",
]
