"""Counters, gauges and reservoir histograms behind a `MetricsRegistry`.

Design constraints, in order:

1. **Zero dependencies on the hot path.** Instruments are plain Python
   objects; one `Histogram.observe` is an attribute bump plus a list
   append (or an O(1) reservoir replacement). No numpy import is needed
   until someone asks for a quantile.
2. **Lock-free snapshots.** `snapshot()` copies instrument state without
   taking locks — under the GIL every read it performs is of a
   consistent single value, and the reservoir copy is a single
   ``list(...)``. Writers are never blocked by a reader; a snapshot
   racing a write may miss the very last observation, which is the
   correct trade for telemetry.
3. **Exact quantiles while bounded.** A histogram keeps every sample up
   to ``capacity`` (default 4096) and computes p50/p95/p99 by sorting
   the reservoir with numpy's ``linear`` interpolation — bit-identical
   to ``np.percentile`` until the reservoir overflows, then a seeded
   Algorithm-R reservoir keeps a uniform sample at fixed memory.

A registry constructed with ``enabled=False`` hands out shared no-op
instruments — `repro.serving.Engine(metrics=obs.NULL)` is the
instrumentation-off baseline the load benchmark's overhead measurement
compares against.
"""

from __future__ import annotations

import math
import random

#: Default histogram reservoir size: exact quantiles for every workload
#: this repo benches (thousands of steps), bounded memory for servers.
DEFAULT_RESERVOIR = 4096

#: rel-IQR above which a timing histogram's sample is counted as noisy
#: (shared with `autotune.measure.TimingSample.noisy`).
NOISY_REL_IQR = 0.5


class Counter:
    """Monotonic counter. ``add`` accepts any non-negative increment so
    byte counters and call counters share one type."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def add(self, n: int | float = 1) -> None:
        self._value += n

    @property
    def value(self):
        return self._value

    def snapshot(self):
        return self._value


class Gauge:
    """Last-write-wins scalar (queue depth, tokens/sec of the last step)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> float:
        return self._value


class Histogram:
    """Bounded-reservoir distribution with exact small-sample quantiles.

    ``observe`` is O(1); ``quantile(q)`` sorts a *copy* of the reservoir
    (telemetry reads are rare and must not perturb writers). While
    ``count <= capacity`` quantiles are exact and match
    ``np.percentile(samples, 100 q)``; beyond that the seeded reservoir
    (Algorithm R) keeps a uniform subsample, so quantiles stay unbiased
    at fixed memory. min/max/total/count are always exact.
    """

    __slots__ = ("name", "capacity", "_samples", "_count", "_total",
                 "_min", "_max", "_rng")

    def __init__(self, name: str, capacity: int = DEFAULT_RESERVOIR):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.name = name
        self.capacity = capacity
        self._samples: list[float] = []
        self._count = 0
        self._total = 0.0
        self._min = math.inf
        self._max = -math.inf
        # Deterministic per-instrument seed: two runs of the same
        # workload keep the same reservoir (reproducible BENCH deltas).
        self._rng = random.Random(0xC0FFEE ^ hash(name))

    def observe(self, v: float) -> None:
        v = float(v)
        self._count += 1
        self._total += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        if len(self._samples) < self.capacity:
            self._samples.append(v)
        else:
            j = self._rng.randrange(self._count)
            if j < self.capacity:
                self._samples[j] = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def total(self) -> float:
        return self._total

    @property
    def mean(self) -> float:
        return self._total / self._count if self._count else math.nan

    @property
    def min(self) -> float:
        return self._min if self._count else math.nan

    @property
    def max(self) -> float:
        return self._max if self._count else math.nan

    def quantile(self, q: float) -> float:
        """Exact-over-reservoir quantile, numpy ``linear`` method (so
        tests can pin equality against ``np.percentile``)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1]; got {q}")
        xs = sorted(self._samples)
        if not xs:
            return math.nan
        pos = q * (len(xs) - 1)
        lo = math.floor(pos)
        hi = math.ceil(pos)
        return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)

    def snapshot(self) -> dict:
        return {
            "count": self._count,
            "total": self._total,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class _NullCounter(Counter):
    def add(self, n=1):
        pass


class _NullGauge(Gauge):
    def set(self, v):
        pass


class _NullHistogram(Histogram):
    def observe(self, v):
        pass


class MetricsRegistry:
    """name -> instrument, get-or-create. One registry per concern: the
    process default (`default_registry()`) backs the always-on
    instrumentation; benchmarks construct isolated registries so dense
    and compressed serving runs don't mix samples; ``enabled=False``
    (the shared `NULL` instance) turns every instrument into a no-op."""

    def __init__(self, *, enabled: bool = True,
                 reservoir: int = DEFAULT_RESERVOIR):
        self.enabled = enabled
        self.reservoir = reservoir
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        if not enabled:
            self._null_c = _NullCounter("null")
            self._null_g = _NullGauge("null")
            self._null_h = _NullHistogram("null")

    # -- get-or-create ---------------------------------------------------
    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return self._null_c
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return self._null_g
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, capacity: int | None = None) -> Histogram:
        if not self.enabled:
            return self._null_h
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                name, capacity if capacity is not None else self.reservoir)
        return h

    # -- reads -----------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-dict copy of every instrument — safe to mutate, safe to
        ``json.dump``, detached from subsequent writes."""
        return {
            "counters": {k: c.snapshot()
                         for k, c in self._counters.items()},
            "gauges": {k: g.snapshot() for k, g in self._gauges.items()},
            "histograms": {k: h.snapshot()
                           for k, h in self._histograms.items()},
        }

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()


#: Shared no-op registry: `Engine(metrics=obs.NULL)` serves uninstrumented.
NULL = MetricsRegistry(enabled=False)

_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry behind the always-on instrumentation."""
    return _default
