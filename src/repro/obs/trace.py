"""JSONL span tracing with a near-free disabled path.

A trace is a flat JSONL file, one object per line, written as spans
*close* (children therefore appear before their parents, like Chrome's
trace events). Schema:

    {"type": "span",  "name": ..., "id": N, "parent": N | null,
     "ts": unix_start_seconds, "dur_s": wall_seconds, ...attrs}
    {"type": "event", "name": ..., "id": N, "parent": N | null,
     "ts": unix_seconds, ...attrs}

Nesting is tracked per-thread/task with a `contextvars.ContextVar`
stack, so spans nest correctly across threads and asyncio tasks alike.

The sink is the path in ``$REPRO_TRACE`` (read once, lazily) or whatever
`configure_trace(path)` set last; `configure_trace(None)` turns tracing
off. With no sink, `span()` yields immediately and `event()` returns —
one predicate check per call — which is what keeps the serving engine's
instrumentation overhead under 2% with tracing off (the load benchmark
measures it; see docs/observability.md).
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import json
import os
import threading
import time

_ENV_VAR = "REPRO_TRACE"

_sink = None                  # open file object, or None
_sink_path: str | None = None
_env_checked = False
_write_lock = threading.Lock()
_ids = itertools.count(1)
_stack: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_obs_span_stack", default=())


def _ensure_env() -> None:
    """Adopt ``$REPRO_TRACE`` on first use (not at import: the env var
    may be set by the harness after the module loads but before the
    first span)."""
    global _env_checked
    if _env_checked:
        return
    _env_checked = True
    path = os.environ.get(_ENV_VAR)
    if path and _sink is None:
        configure_trace(path)


def configure_trace(path: str | os.PathLike | None) -> None:
    """Point the trace sink at ``path`` (append mode; parent dirs are
    created), or disable tracing with ``None``. Replaces any previous
    sink. Takes precedence over ``$REPRO_TRACE``."""
    global _sink, _sink_path, _env_checked
    _env_checked = True          # explicit config wins over the env var
    if _sink is not None:
        try:
            _sink.close()
        except OSError:
            pass
        _sink = None
        _sink_path = None
    if path is None:
        return
    p = os.fspath(path)
    d = os.path.dirname(p)
    if d:
        os.makedirs(d, exist_ok=True)
    _sink = open(p, "a")
    _sink_path = p


def trace_active() -> bool:
    """True when a sink is configured — the single check every span and
    event makes before doing any work."""
    _ensure_env()
    return _sink is not None


def trace_path() -> str | None:
    """Path of the active sink (None when tracing is off)."""
    _ensure_env()
    return _sink_path


def _write(obj: dict) -> None:
    line = json.dumps(obj, default=str)
    with _write_lock:
        sink = _sink
        if sink is None:          # configure_trace(None) raced us
            return
        sink.write(line + "\n")
        sink.flush()              # crash-visible; tracing is opt-in


@contextlib.contextmanager
def span(name: str, **attrs):
    """Time a block and emit one JSONL span on exit.

    Yields the span id (None when tracing is off — callers never
    branch on it). Attributes must be JSON-serializable; anything else
    is stringified. Exceptions propagate; the span records
    ``error=<type>`` and still closes, so a trace of a crashed run ends
    with the failing span."""
    if not trace_active():
        yield None
        return
    sid = next(_ids)
    stack = _stack.get()
    parent = stack[-1] if stack else None
    token = _stack.set(stack + (sid,))
    ts = time.time()
    t0 = time.perf_counter()
    try:
        yield sid
    except BaseException as e:
        attrs = {**attrs, "error": type(e).__name__}
        raise
    finally:
        _stack.reset(token)
        _write({"type": "span", "name": name, "id": sid,
                "parent": parent, "ts": ts,
                "dur_s": time.perf_counter() - t0, **attrs})


def event(name: str, **attrs) -> None:
    """Emit one instantaneous JSONL event (parented to the enclosing
    span, when inside one). No-op with tracing off."""
    if not trace_active():
        return
    stack = _stack.get()
    _write({"type": "event", "name": name, "id": next(_ids),
            "parent": stack[-1] if stack else None,
            "ts": time.time(), **attrs})
