from repro.optim.adafactor import adafactor
from repro.optim.adamw import adamw
from repro.optim.grad_compress import with_error_feedback


def make_optimizer(name: str, lr: float = 3e-4, **kw):
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
