"""Adafactor (factored second moment) — the memory-frugal optimizer used
for the 405B config: O(n+m) state for an (n, m) matrix instead of O(nm),
plus fp32 master weights (still the dominant term, FSDP-sharded).

The second-moment state is kept as a flat list aligned with
jax.tree.leaves(params) (unambiguous regardless of param dict key names).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def adafactor(lr: float, decay: float = 0.8, eps: float = 1e-30,
              clip_rms: float = 1.0, weight_decay: float = 0.0,
              master: bool = True) -> Optimizer:
    """master=False drops the fp32 master copy (param updates applied in
    the params' own dtype). Saves 4 bytes/param — the difference between
    fitting and not fitting 405B training on a 16 GiB/chip v5e pod; the
    small-update truncation cost is documented in EXPERIMENTS.md §Dry-run.
    """
    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def state_for(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], dtype=jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    dtype=jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, dtype=jnp.float32)}

        state = {
            "step": jnp.zeros((), dtype=jnp.int32),
            "v": [state_for(p) for p in jax.tree.leaves(params)],
        }
        if master:
            state["master"] = jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True),
                params)
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, v, master):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(g.shape):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                r = vr / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True), eps)
                u = g / jnp.sqrt(
                    jnp.maximum(r[..., None] * vc[..., None, :], eps))
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = g / jnp.sqrt(jnp.maximum(nv["v"], eps))
            # RMS update clipping
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            master = master - lr * (u + weight_decay * master)
            return nv, master

        treedef = jax.tree.structure(grads)
        masters = (jax.tree.leaves(state["master"]) if master else
                   [p.astype(jnp.float32) for p in jax.tree.leaves(params)])
        out = [upd(g, v, w) for g, v, w in zip(
            jax.tree.leaves(grads), state["v"], masters)]
        new_w = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_params = jax.tree.map(lambda w, p: w.astype(p.dtype),
                                  new_w, params)
        new_state = {"step": step, "v": [o[0] for o in out]}
        if master:
            new_state["master"] = new_w
        return new_params, new_state

    return Optimizer(init=init, update=update)
