"""AdamW with fp32 master weights over (possibly bf16) params.

Interface (shared by all optimizers here):
  init(params)                     -> state
  update(grads, state, params)     -> (new_params, new_state)
State and master weights are plain pytrees so the launcher can shard them
(ZeRO-1: dim-0 sharding over the data axis, launch/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), dtype=jnp.int32),
            # copy=True: astype(f32) on f32 params is a no-op alias,
            # which breaks buffer donation (donate-twice)
            "master": jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True),
                params),
            "m": jax.tree.map(
                lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params),
            "v": jax.tree.map(
                lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            master = master - lr * (u + weight_decay * master)
            return m, v, master

        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        flat_w = jax.tree.leaves(state["master"])
        treedef = jax.tree.structure(grads)
        out = [upd(g, m, v, w) for g, m, v, w
               in zip(flat_g, flat_m, flat_v, flat_w)]
        new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_w = jax.tree.unflatten(treedef, [o[2] for o in out])
        new_params = jax.tree.map(
            lambda w, p: w.astype(p.dtype), new_w, params)
        return new_params, {"step": step, "master": new_w, "m": new_m,
                            "v": new_v}

    return Optimizer(init=init, update=update)
