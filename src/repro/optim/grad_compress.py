"""Gradient compression with error feedback.

Casting gradients to bf16 *before* the data-parallel reduction halves the
all-reduce bytes (the HLO all-reduce dtype follows its operand); the
quantization error is carried in an fp32 residual and re-injected next step
(error feedback), which keeps convergence intact in practice.

Used as a wrapper around microbatch gradient accumulation in the trainer;
the roofline collective term reflects the byte reduction (EXPERIMENTS.md
§Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype=jnp.float32),
                        params)


def compress(grads, err):
    """Returns (bf16 grads to reduce, new fp32 residual)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        gc = g32.astype(jnp.bfloat16)
        return gc, g32 - gc.astype(jnp.float32)

    flat = jax.tree.map(one, grads, err)
    comp = jax.tree.map(lambda t: t[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_err


def with_error_feedback(grads, err):
    comp, new_err = compress(grads, err)
    return jax.tree.map(lambda g: g.astype(jnp.float32), comp), new_err
