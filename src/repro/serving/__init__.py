# Serving: batched engine + dtANS-compressed sparse weights.
