"""Batched serving engine with continuous batching-lite and optional
dtANS-sparse projection weights.

A fixed pool of batch slots is filled from a request queue; prefill runs
per-request (padded to the slot length), decode steps run for the whole
pool in lock step. Slots whose request finishes are refilled immediately —
the decode batch never drains (the paper's memory-bound SpMVM regime is
per-token decode, where weight bytes dominate).

Sparse mode: `compress_lm_head` swaps the output projection for a
SparseLinear (pruned + entropy-coded). The LM head is the single largest
matrix of small LMs (vocab x d) and is matvec-bound at decode — exactly
the paper's target workload. Each pooled decode step stops the jit'd
model at the final norm (`api.decode_hidden`) and contracts the
(slots, 1, d) hidden states against the compressed head in ONE fused
multi-RHS SpMM (`SparseLinear.apply` -> `ops.spmm`): one entropy decode
per step, amortized over every active slot.
"""

from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import api
from repro.models.config import ArchConfig
from repro.serving.sparse_linear import SparseLinear


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # Observability timestamps (time.perf_counter seconds): submission,
    # first generated token (TTFT = t_first - t_submit), completion
    # (end-to-end latency = t_done - t_submit).
    t_submit: float | None = None
    t_first: float | None = None
    t_done: float | None = None


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_seq: int = 256, sparse_head: SparseLinear | None = None,
                 greedy: bool = True,
                 metrics: obs.MetricsRegistry | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.sparse_head = sparse_head
        self.greedy = greedy
        # Metrics land in the process default registry unless the caller
        # isolates them (benchmarks pass a fresh registry per run;
        # `obs.NULL` serves uninstrumented — the overhead baseline).
        self.metrics = metrics if metrics is not None \
            else obs.default_registry()
        m = self.metrics
        self._m_step = m.histogram("engine.step_s")
        self._m_prefill = m.histogram("engine.prefill_s")
        self._m_decode = m.histogram("engine.decode_s")
        self._m_refill = m.histogram("engine.refill_s")
        self._m_occupancy = m.histogram("engine.occupancy")
        self._m_ttft = m.histogram("engine.ttft_s")
        self._m_e2e = m.histogram("engine.e2e_s")
        self._m_tokens = m.counter("engine.tokens_total")
        self._m_steps = m.counter("engine.steps_total")
        self._m_submitted = m.counter("engine.requests_submitted")
        self._m_completed = m.counter("engine.requests_completed")
        self._m_tps = m.gauge("engine.tokens_per_sec")
        self._m_queue = m.gauge("engine.queue_depth")
        #: True when the last `run_until_drained` hit ``max_steps`` with
        #: requests still active (only reachable with on_truncate="warn").
        self.truncated = False
        self.queue: list[Request] = []
        self.active: list[Request | None] = [None] * slots
        #: Completed requests in completion order, appended by `step`
        #: and drained by `run_until_drained`.
        self.finished: list[Request] = []
        # Monotonic default rid: the old len(queue) default collided as
        # soon as submits interleaved with steps (queue drains), making
        # drained results ambiguous to correlate.
        self._next_rid = 0
        self.pos = np.zeros(slots, dtype=np.int32)
        self.cache = api.make_decode_cache(cfg, slots, max_seq,
                                           dtype=jnp.float32)
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(p, cfg, c, t, pos))
        # Sparse mode stops the jit'd step at the hidden states; the
        # pooled (slots, 1, d) batch then feeds the compressed head's
        # fused SpMM kernel (one entropy decode per step, amortized
        # over every active slot).
        self._decode_hidden = jax.jit(
            lambda p, c, t, pos: api.decode_hidden(p, cfg, c, t, pos))

    # --- sparse head ---------------------------------------------------------
    @classmethod
    def compress_lm_head(cls, cfg, params, sparsity=0.8,
                         **kw) -> SparseLinear:
        """Compress the LM head of ``params`` into a `SparseLinear`.

        Resolves the head weight the same way `models.layers.lm_head`
        does (untied ``head`` or tied ``tok.T``), validates its shape
        against ``cfg`` (a mismatched config would silently compress the
        wrong projection), and hands the weight over in its *source*
        dtype — `SparseLinear.from_dense` preserves float32/float64 end
        to end, so a float64 head serves float64 logits.
        """
        emb = params["embed"]
        w = np.asarray(emb["head"]) if "head" in emb \
            else np.asarray(emb["tok"]).T                # (d, vocab)
        if cfg is not None and w.shape != (cfg.d_model, cfg.vocab):
            raise ValueError(
                f"LM head shape {w.shape} does not match config "
                f"(d_model={cfg.d_model}, vocab={cfg.vocab})")
        return SparseLinear.from_dense(w, sparsity=sparsity, **kw)

    def _head(self, hidden):
        """hidden: (B, 1, d) -> logits (B, 1, vocab) through the
        compressed head's fused SpMM path (`SparseLinear.apply` ->
        `ops.spmm`: decode once, contract all B pooled hidden states)."""
        if self.sparse_head is None:
            raise RuntimeError("dense path returns logits directly")
        return self.sparse_head.apply(hidden)

    # --- request lifecycle ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, rid=None) -> Request:
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        r = Request(rid=rid,
                    prompt=np.asarray(prompt, dtype=np.int32),
                    max_new_tokens=max_new_tokens,
                    t_submit=time.perf_counter())
        self.queue.append(r)
        self._m_submitted.add(1)
        self._m_queue.set(len(self.queue))
        return r

    def _fill_slots(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                r = self.queue.pop(0)
                self.active[s] = r
                # per-slot "prefill": feed prompt tokens through decode
                # steps (slot-local; simple and exact for slot counts ~4-8)
                t0 = time.perf_counter()
                with obs.span("engine.prefill", rid=r.rid,
                              prompt_len=int(len(r.prompt))):
                    for i, tok in enumerate(r.prompt[:-1]):
                        self._step_slot(s, int(tok), i)
                self._m_prefill.observe(time.perf_counter() - t0)
                self.pos[s] = len(r.prompt) - 1
        self._m_queue.set(len(self.queue))

    def _step_slot(self, s: int, tok: int, pos: int):
        toks = np.zeros((self.slots, 1), dtype=np.int32)
        toks[s, 0] = tok
        _, self.cache = self._decode(self.params, self.cache,
                                     jnp.asarray(toks), jnp.int32(pos))

    def step(self) -> int:
        """One lock-step decode for all active slots; returns #tokens.

        Instrumented: step wall time splits into refill (slot
        assignment + per-request prefill) and pooled decode spans;
        tokens/sec, slot occupancy, TTFT and end-to-end latency land in
        `self.metrics` (see docs/observability.md for the names).
        """
        t_step0 = time.perf_counter()
        with obs.span("engine.step"):
            with obs.span("engine.refill"):
                self._fill_slots()
            t_refill = time.perf_counter() - t_step0
            n_active = sum(r is not None for r in self.active)
            if n_active == 0:
                return 0
            toks = np.zeros((self.slots, 1), dtype=np.int32)
            for s, r in enumerate(self.active):
                if r is not None:
                    toks[s, 0] = (r.out[-1] if r.out else r.prompt[-1])
            # NOTE: slots share one cache_pos per step; engine keeps them
            # in sync by construction (prefill aligns pos to the max +
            # padding).
            pos = int(self.pos.max())
            t_dec0 = time.perf_counter()
            with obs.span("engine.decode", batch=n_active,
                          sparse=self.sparse_head is not None):
                if self.sparse_head is not None:
                    # hidden-state decode, then the compressed LM head:
                    # the pooled (slots, 1, d) hidden states contract
                    # against the entropy-coded head in ONE fused SpMM
                    # (decode amortized over the whole batch) — the
                    # dense in-model head is never consulted in sparse
                    # mode.
                    hidden, self.cache = self._decode_hidden(
                        self.params, self.cache, jnp.asarray(toks),
                        jnp.int32(pos))
                    logits = np.asarray(self._head(hidden),
                                        dtype=np.float32)
                else:
                    logits, self.cache = self._decode(self.params,
                                                      self.cache,
                                                      jnp.asarray(toks),
                                                      jnp.int32(pos))
                    logits = np.asarray(logits, dtype=np.float32)
            t_decode = time.perf_counter() - t_dec0
            now = time.perf_counter()
            produced = 0
            for s, r in enumerate(self.active):
                if r is None:
                    continue
                nxt = int(logits[s, 0].argmax())
                r.out.append(nxt)
                produced += 1
                self.pos[s] += 1
                if len(r.out) == 1:
                    r.t_first = now
                    if r.t_submit is not None:
                        self._m_ttft.observe(now - r.t_submit)
                if len(r.out) >= r.max_new_tokens:
                    r.done = True
                    r.t_done = now
                    self.active[s] = None
                    self.finished.append(r)
                    self._m_completed.add(1)
                    if r.t_submit is not None:
                        self._m_e2e.observe(now - r.t_submit)
        dt = time.perf_counter() - t_step0
        self._m_step.observe(dt)
        self._m_refill.observe(t_refill)
        self._m_decode.observe(t_decode)
        self._m_occupancy.observe(n_active / self.slots)
        self._m_tokens.add(produced)
        self._m_steps.add(1)
        self._m_tps.set(produced / dt if dt > 0 else 0.0)
        return produced

    def run_until_drained(self, max_steps: int = 10000, *,
                          on_truncate: str = "raise") -> list[Request]:
        """Step until queue and slots are empty; returns the completed
        requests in completion order (including any that finished in
        manual `step` calls before this drain and were not yet
        reported).

        Hitting ``max_steps`` with requests still queued or active used
        to return partial results silently — a load test could report a
        truncated run as complete. Now ``on_truncate="raise"`` (default)
        raises RuntimeError; ``"warn"`` emits a UserWarning, sets
        ``self.truncated`` and returns what finished.
        """
        if on_truncate not in ("raise", "warn"):
            raise ValueError(f"on_truncate must be 'raise' or 'warn'; "
                             f"got {on_truncate!r}")
        self.truncated = False
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
        if self.queue or any(r is not None for r in self.active):
            pending = len(self.queue) + sum(r is not None
                                            for r in self.active)
            msg = (f"run_until_drained hit max_steps={max_steps} with "
                   f"{pending} request(s) still pending — results are "
                   f"truncated")
            self.metrics.counter("engine.drain_truncations").add(1)
            if on_truncate == "raise":
                raise RuntimeError(msg)
            warnings.warn(msg, stacklevel=2)
            self.truncated = True
        finished, self.finished = self.finished, []
        return finished
