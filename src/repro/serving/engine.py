"""Batched serving engine: per-slot continuous batching with batched
prefill and optional dtANS-sparse projection weights.

A fixed pool of batch slots is filled FIFO from a bounded request
queue. Each slot tracks its own cache position (`Engine.pos[s]`;
``-1`` = empty slot), so requests with unequal prompt lengths decode
together — slot s reads and writes KV at exactly ``pos[s]``, never at
another slot's position. Admitting a request runs its whole prompt
through ONE batched forward (`api.prefill`) and scatters the resulting
batch-size-1 cache into the slot (`api.cache_insert_slot`); the other
slots' live cache lines are untouched (the old token-by-token replay
fed zero tokens through every slot and corrupted their KV on each
mid-flight refill). Admission control rejects requests the pool could
never serve correctly — empty prompts and
``prompt_len + max_new_tokens > max_seq`` — at `submit` time, which
makes a slot position walking past ``max_seq`` unreachable.

Sampling: ``greedy=True`` (default) takes the argmax;
``greedy=False`` samples from the temperature-scaled softmax,
optionally truncated to the ``top_k`` most likely tokens, with a
seeded per-engine generator (two engines with the same ``sample_seed``
reproduce the same stream).

Sparse mode: `compress_lm_head` swaps the output projection for a
SparseLinear (pruned + entropy-coded). The LM head is the single largest
matrix of small LMs (vocab x d) and is matvec-bound at decode — exactly
the paper's target workload. Each pooled decode step stops the jit'd
model at the final norm (`api.decode_hidden`) and contracts the
(slots, 1, d) hidden states against the compressed head in ONE fused
multi-RHS SpMM (`SparseLinear.apply` -> `ops.spmm`): one entropy decode
per step, amortized over every active slot.
"""

from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import api
from repro.models.config import ArchConfig
from repro.serving.sparse_linear import SparseLinear


class AdmissionError(ValueError):
    """Request rejected by admission control at `Engine.submit`."""


class QueueFullError(AdmissionError):
    """Request rejected because the FIFO queue is at ``max_queue``."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # Observability timestamps (time.perf_counter seconds): submission,
    # first generated token (TTFT = t_first - t_submit), completion
    # (end-to-end latency = t_done - t_submit).
    t_submit: float | None = None
    t_first: float | None = None
    t_done: float | None = None


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_seq: int = 256, sparse_head: SparseLinear | None = None,
                 greedy: bool = True, temperature: float = 1.0,
                 top_k: int = 0, sample_seed: int = 0,
                 max_queue: int | None = None,
                 metrics: obs.MetricsRegistry | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.sparse_head = sparse_head
        self.greedy = greedy
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._sampler = np.random.default_rng(sample_seed)
        self.max_queue = max_queue
        # Metrics land in the process default registry unless the caller
        # isolates them (benchmarks pass a fresh registry per run;
        # `obs.NULL` serves uninstrumented — the overhead baseline).
        self.metrics = metrics if metrics is not None \
            else obs.default_registry()
        m = self.metrics
        self._m_step = m.histogram("engine.step_s")
        self._m_prefill = m.histogram("engine.prefill_s")
        self._m_decode = m.histogram("engine.decode_s")
        self._m_refill = m.histogram("engine.refill_s")
        self._m_occupancy = m.histogram("engine.occupancy")
        self._m_ttft = m.histogram("engine.ttft_s")
        self._m_e2e = m.histogram("engine.e2e_s")
        self._m_tokens = m.counter("engine.tokens_total")
        self._m_steps = m.counter("engine.steps_total")
        self._m_submitted = m.counter("engine.requests_submitted")
        self._m_completed = m.counter("engine.requests_completed")
        self._m_rejected = m.counter("engine.rejections")
        self._m_refills = m.counter("engine.refills_total")
        self._m_tps = m.gauge("engine.tokens_per_sec")
        self._m_queue = m.gauge("engine.queue_depth")
        self._m_slot_pos = [m.gauge(f"engine.slot_pos.{s}")
                            for s in range(slots)]
        #: True when the last `run_until_drained` hit ``max_steps`` with
        #: requests still active (only reachable with on_truncate="warn").
        self.truncated = False
        self.queue: list[Request] = []
        self.active: list[Request | None] = [None] * slots
        #: Completed requests in completion order, appended by `step`
        #: and drained by `run_until_drained`.
        self.finished: list[Request] = []
        # Monotonic default rid: the old len(queue) default collided as
        # soon as submits interleaved with steps (queue drains), making
        # drained results ambiguous to correlate.
        self._next_rid = 0
        #: Per-slot cache position: the index the slot's NEXT decode
        #: step writes KV at. -1 = empty slot (backends mask its cache
        #: writes and attention entirely).
        self.pos = np.full(slots, -1, dtype=np.int32)
        self.cache = api.make_decode_cache(cfg, slots, max_seq,
                                           dtype=jnp.float32)
        # A zeroed batch-size-1 cache, scattered into a slot on admission
        # of a 1-token prompt (no prefill runs, but the slot's stale
        # state from its previous occupant must still be cleared).
        self._blank_slot = api.make_decode_cache(cfg, 1, max_seq,
                                                 dtype=jnp.float32)
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(p, cfg, c, t, pos))
        # Sparse mode stops the jit'd step at the hidden states; the
        # pooled (slots, 1, d) batch then feeds the compressed head's
        # fused SpMM kernel (one entropy decode per step, amortized
        # over every active slot).
        self._decode_hidden = jax.jit(
            lambda p, c, t, pos: api.decode_hidden(p, cfg, c, t, pos))
        # Batched prefill: the whole prompt in one forward pass. jit
        # retraces once per distinct prompt length (real engines bucket
        # lengths; the pools this repo serves see a handful).
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, cfg, b, max_seq=max_seq))

    # --- sparse head ---------------------------------------------------------
    @classmethod
    def compress_lm_head(cls, cfg, params, sparsity=0.8,
                         **kw) -> SparseLinear:
        """Compress the LM head of ``params`` into a `SparseLinear`.

        Resolves the head weight the same way `models.layers.lm_head`
        does (untied ``head`` or tied ``tok.T``), validates its shape
        against ``cfg`` (a mismatched config would silently compress the
        wrong projection), and hands the weight over in its *source*
        dtype — `SparseLinear.from_dense` preserves float32/float64 end
        to end, so a float64 head serves float64 logits.
        """
        emb = params["embed"]
        w = np.asarray(emb["head"]) if "head" in emb \
            else np.asarray(emb["tok"]).T                # (d, vocab)
        if cfg is not None and w.shape != (cfg.d_model, cfg.vocab):
            raise ValueError(
                f"LM head shape {w.shape} does not match config "
                f"(d_model={cfg.d_model}, vocab={cfg.vocab})")
        return SparseLinear.from_dense(w, sparsity=sparsity, **kw)

    def _head(self, hidden):
        """hidden: (B, 1, d) -> logits (B, 1, vocab) through the
        compressed head's fused SpMM path (`SparseLinear.apply` ->
        `ops.spmm`: decode once, contract all B pooled hidden states).
        The engine's own registry is threaded through so head metrics
        stay isolated with the engine's (`metrics=` contract)."""
        if self.sparse_head is None:
            raise RuntimeError("dense path returns logits directly")
        return self.sparse_head.apply(hidden, metrics=self.metrics)

    # --- scheduler: admission control ----------------------------------------
    def _reject(self, reason: str, msg: str):
        self._m_rejected.add(1)
        self.metrics.counter(f"engine.rejections.{reason}").add(1)
        if reason == "queue_full":
            raise QueueFullError(msg)
        raise AdmissionError(msg)

    def submit(self, prompt, max_new_tokens: int, rid=None) -> Request:
        """Admit a request into the FIFO queue, or raise
        `AdmissionError` / `QueueFullError`.

        Admission rules (each rejection bumps ``engine.rejections`` and
        ``engine.rejections.<reason>``):

        * non-empty prompt — an empty prompt has no last token to feed
          the first decode step (used to crash deep inside `step`);
        * ``max_new_tokens >= 1``;
        * ``prompt_len + max_new_tokens <= max_seq`` — the request's
          final decode position is then ``prompt_len + max_new - 2 <=
          max_seq - 2``, so a slot position can never walk past the
          cache (used to scatter KV out of range);
        * queue depth below ``max_queue`` (when set).
        """
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        if prompt.size == 0:
            self._reject("empty_prompt", "empty prompt rejected: the "
                         "first decode step feeds prompt[-1]")
        if max_new_tokens < 1:
            self._reject("bad_max_new",
                         f"max_new_tokens must be >= 1; "
                         f"got {max_new_tokens}")
        if len(prompt) + max_new_tokens > self.max_seq:
            self._reject(
                "exceeds_max_seq",
                f"prompt_len + max_new_tokens = "
                f"{len(prompt)} + {max_new_tokens} > max_seq="
                f"{self.max_seq}: request would overrun the KV cache")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self._reject("queue_full",
                         f"queue at max_queue={self.max_queue}")
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        r = Request(rid=rid,
                    prompt=prompt,
                    max_new_tokens=max_new_tokens,
                    t_submit=time.perf_counter())
        self.queue.append(r)
        self._m_submitted.add(1)
        self._m_queue.set(len(self.queue))
        return r

    # --- scheduler: refill + batched prefill ----------------------------------
    def _fill_slots(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                r = self.queue.pop(0)
                self.active[s] = r
                t0 = time.perf_counter()
                with obs.span("engine.prefill", rid=r.rid, slot=s,
                              prompt_len=int(len(r.prompt))):
                    self._prefill_slot(s, r)
                self._m_prefill.observe(time.perf_counter() - t0)
                self._m_refills.add(1)
        self._m_queue.set(len(self.queue))
        for s, g in enumerate(self._m_slot_pos):
            g.set(int(self.pos[s]))

    def _prefill_slot(self, s: int, r: Request):
        """Admit request ``r`` into slot ``s``: run ``prompt[:-1]``
        through ONE batched `api.prefill` forward and scatter the
        resulting cache into the slot (the last prompt token is fed by
        the first pooled decode step, which produces the first output
        token). Slots other than ``s`` are untouched — no cross-slot
        KV writes, unlike the old per-token replay that fed zero
        tokens through every other slot."""
        L = len(r.prompt)
        if L > 1:
            batch = {"inputs": jnp.asarray(r.prompt[None, :-1])}
            if self.cfg.family == "encdec":
                # No frame frontend flows through `submit`; a zero
                # frame block matches the zero `memory` the pooled
                # decode cache initializes (encode(0) == 0 end to end).
                batch["frontend"] = jnp.zeros(
                    (1, self.cfg.n_frontend_tokens, self.cfg.d_model),
                    dtype=jnp.float32)
            _, req_cache, _ = self._prefill(self.params, batch)
        else:
            # 1-token prompt: nothing to prefill, but the slot's cache
            # lines still hold its previous occupant's state.
            req_cache = self._blank_slot
        self.cache = api.cache_insert_slot(self.cfg, self.cache,
                                           req_cache, s)
        self.pos[s] = L - 1

    # --- sampling --------------------------------------------------------------
    def _select_token(self, logits_row: np.ndarray) -> int:
        """Next token from one slot's (vocab,) logits: argmax when
        ``greedy``, else seeded temperature/top-k sampling."""
        if self.greedy:
            return int(logits_row.argmax())
        z = logits_row.astype(np.float64) / max(self.temperature, 1e-6)
        if self.top_k and self.top_k < z.size:
            kth = np.partition(z, -self.top_k)[-self.top_k]
            z = np.where(z >= kth, z, -np.inf)
        z = z - z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._sampler.choice(z.size, p=p))

    # --- decode ----------------------------------------------------------------
    def step(self) -> int:
        """One pooled decode for all active slots; returns #tokens.

        Each slot decodes at ITS OWN position (`self.pos`, a (slots,)
        vector threaded through `api.decode_step` / `decode_hidden`):
        mixed-length prompts and mid-flight refills stay token-identical
        to running each request alone. Instrumented: step wall time
        splits into refill (admission + batched prefill) and pooled
        decode spans; tokens/sec, slot occupancy, per-slot position
        gauges, TTFT and end-to-end latency land in `self.metrics`
        (see docs/observability.md for the names).
        """
        t_step0 = time.perf_counter()
        with obs.span("engine.step"):
            with obs.span("engine.refill"):
                self._fill_slots()
            t_refill = time.perf_counter() - t_step0
            n_active = sum(r is not None for r in self.active)
            if n_active == 0:
                return 0
            toks = np.zeros((self.slots, 1), dtype=np.int32)
            for s, r in enumerate(self.active):
                if r is not None:
                    toks[s, 0] = (r.out[-1] if r.out else r.prompt[-1])
            # Per-slot positions: empty slots carry -1 and are fully
            # masked inside the model (no KV/SSM writes, no attention).
            pos = jnp.asarray(self.pos)
            t_dec0 = time.perf_counter()
            with obs.span("engine.decode", batch=n_active,
                          sparse=self.sparse_head is not None):
                if self.sparse_head is not None:
                    # hidden-state decode, then the compressed LM head:
                    # the pooled (slots, 1, d) hidden states contract
                    # against the entropy-coded head in ONE fused SpMM
                    # (decode amortized over the whole batch) — the
                    # dense in-model head is never consulted in sparse
                    # mode.
                    hidden, self.cache = self._decode_hidden(
                        self.params, self.cache, jnp.asarray(toks), pos)
                    logits = np.asarray(self._head(hidden),
                                        dtype=np.float32)
                else:
                    logits, self.cache = self._decode(self.params,
                                                      self.cache,
                                                      jnp.asarray(toks),
                                                      pos)
                    logits = np.asarray(logits, dtype=np.float32)
            t_decode = time.perf_counter() - t_dec0
            now = time.perf_counter()
            produced = 0
            for s, r in enumerate(self.active):
                if r is None:
                    continue
                nxt = self._select_token(logits[s, 0])
                r.out.append(nxt)
                produced += 1
                self.pos[s] += 1
                if self.pos[s] >= self.max_seq:
                    # Unreachable by construction: admission control
                    # bounds prompt_len + max_new_tokens <= max_seq.
                    raise RuntimeError(
                        f"slot {s} position {int(self.pos[s])} overran "
                        f"max_seq={self.max_seq} — admission control "
                        f"failed")
                if len(r.out) == 1:
                    r.t_first = now
                    if r.t_submit is not None:
                        self._m_ttft.observe(now - r.t_submit)
                if len(r.out) >= r.max_new_tokens:
                    r.done = True
                    r.t_done = now
                    self.active[s] = None
                    self.pos[s] = -1
                    self.finished.append(r)
                    self._m_completed.add(1)
                    if r.t_submit is not None:
                        self._m_e2e.observe(now - r.t_submit)
            for s, g in enumerate(self._m_slot_pos):
                g.set(int(self.pos[s]))
        dt = time.perf_counter() - t_step0
        self._m_step.observe(dt)
        self._m_refill.observe(t_refill)
        self._m_decode.observe(t_decode)
        self._m_occupancy.observe(n_active / self.slots)
        self._m_tokens.add(produced)
        self._m_steps.add(1)
        self._m_tps.set(produced / dt if dt > 0 else 0.0)
        return produced

    def run_until_drained(self, max_steps: int = 10000, *,
                          on_truncate: str = "raise") -> list[Request]:
        """Step until queue and slots are empty; returns the completed
        requests in completion order (including any that finished in
        manual `step` calls before this drain and were not yet
        reported).

        Hitting ``max_steps`` with requests still queued or active used
        to return partial results silently — a load test could report a
        truncated run as complete. Now ``on_truncate="raise"`` (default)
        raises RuntimeError; ``"warn"`` emits a UserWarning, sets
        ``self.truncated`` and returns what finished.
        """
        if on_truncate not in ("raise", "warn"):
            raise ValueError(f"on_truncate must be 'raise' or 'warn'; "
                             f"got {on_truncate!r}")
        self.truncated = False
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
        if self.queue or any(r is not None for r in self.active):
            pending = len(self.queue) + sum(r is not None
                                            for r in self.active)
            msg = (f"run_until_drained hit max_steps={max_steps} with "
                   f"{pending} request(s) still pending — results are "
                   f"truncated")
            self.metrics.counter("engine.drain_truncations").add(1)
            if on_truncate == "raise":
                raise RuntimeError(msg)
            warnings.warn(msg, stacklevel=2)
            self.truncated = True
        finished, self.finished = self.finished, []
        return finished
