"""SparseLinear: a dense projection replaced by a pruned, entropy-coded
weight matrix decoded on the fly (the paper's LLM-inference motivation,
Section I, made concrete).

Pipeline: dense W (d_in, d_out) -> magnitude prune -> codebook-quantize
surviving values (8-bit centroids make the value distribution low-entropy,
which is what dtANS compresses; raw float32 mantissas would all escape) ->
CSR-dtANS encode of W^T (so y = W^T-rows . x = SpMVM per output neuron).

`apply` contracts a batch of activations against the decoded matrix
through the fused multi-RHS Pallas kernel (`ops.spmm`): one entropy
decode per call, amortized over every request in the batch — the same
kernel machinery as `kernels/dtans_spmv`, batched.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.csr_dtans import CSRdtANS, encode_matrix
from repro.kernels import ops
from repro.kernels.pack import PackedMatrix, pack_matrix
from repro.sparse.formats import CSR, best_baseline_nbytes
from repro.sparse.prune import codebook_quantize, magnitude_prune


@dataclasses.dataclass
class SparseLinear:
    mat: CSRdtANS            # encodes W^T: (d_out rows, d_in cols)
    packed: PackedMatrix
    d_in: int
    d_out: int
    dense_bytes: int
    baseline_bytes: int      # best of CSR/COO/SELL on the pruned matrix
    decision: object = None  # autotune Decision when built with auto=True
    mesh: object = None      # jax mesh the layer serves from (or None)
    n_shards: int = 1        # row shards of the weight (1 = one chip)
    plan: object = None      # sparse.shard.ShardPlan when n_shards > 1

    @classmethod
    def from_dense(cls, w: np.ndarray, sparsity: float = 0.8,
                   value_bits: int = 8, lane_width: int = 128,
                   shared_table: bool = True, auto: bool = False,
                   autotune_budget: int = 0,
                   autotune_batch: int = 1,
                   autotune_cache=None,
                   autotune_measure: bool = False,
                   autotune_machine=None,
                   mesh=None, n_shards: int | None = None
                   ) -> "SparseLinear":
        """Compress a dense projection for decode-on-the-fly serving.

        The source dtype is preserved end-to-end: a float64 projection
        prunes, quantizes, encodes and decodes in float64 (non-float
        inputs fall back to float32 — the format codes float bit
        patterns).

        With ``auto=True`` the ``lane_width`` / ``shared_table`` knobs are
        ignored and chosen per matrix by `repro.autotune` (fingerprint the
        pruned weight, pick the modeled-fastest entropy-coded
        configuration among every ``decodes=True`` family in
        `repro.sparse.registry` — plain CSR-dtANS, group-aligned
        RGCSR-dtANS, block-aligned BCSR-dtANS, ...; every such family
        runs the same decode kernels, so serving is indifferent, and the
        winning spec's `FormatSpec.encode` builds the artifact — no
        per-format branch here; decisions persist in the autotune cache,
        so repeated serving runs skip the search). ``autotune_budget`` >
        0 additionally encodes the
        top candidates to refine estimated sizes into exact ones;
        ``autotune_measure=True`` further wall-clock times those
        candidates' decode kernels and picks the measured-fastest
        (`repro.autotune.measure`); ``autotune_batch`` prices the
        selection for a ``B``-RHS serving batch (decode amortizes over
        the batch — the knob to set to the expected pool size);
        ``autotune_machine`` substitutes a
        calibrated `MachineModel` (e.g. ``load_profile(...)``) for the
        default v5e constants; ``autotune_cache`` overrides the default
        persistent cache (pass ``repro.autotune.DecisionCache(path=None)``
        for memory-only).

        ``mesh`` builds the layer for multi-chip serving: the pruned
        weight is row-partitioned into ``model_axis_size(mesh)`` shards
        along the winning format's decode-slice boundaries
        (`FormatSpec.shard`) and `apply` routes through the shard_map +
        psum path (`repro.kernels.shard_ops`) — every device decodes
        only its shard's bitstream. ``n_shards`` pins the shard count
        explicitly (usable without a mesh: the sequential sharded path,
        mostly for tests). The selection, when ``auto=True``, is priced
        at the same shard count it will serve on.
        """
        from repro.sparse.registry import get_format
        d_in, d_out = w.shape
        w_arr = np.asarray(w)
        if w_arr.dtype not in (np.float32, np.float64):
            w_arr = w_arr.astype(np.float32)
        pruned = magnitude_prune(w_arr.T, sparsity)
        pruned = codebook_quantize(pruned, bits=value_bits)
        if n_shards is not None:
            k = int(n_shards)
        elif mesh is not None:
            from repro.launch.mesh import model_axis_size
            k = model_axis_size(mesh)
        else:
            k = 1
        decision = None
        if auto:
            from repro.autotune import V5E, choose_dtans_config
            decision = choose_dtans_config(
                pruned, warm=True, budget=autotune_budget,
                batch=autotune_batch, n_shards=k,
                # The timing harness is single-device; sharded builds
                # select on the modeled sharded cost instead.
                measure=autotune_measure if k == 1 else False,
                machine=autotune_machine
                if autotune_machine is not None else V5E,
                cache=autotune_cache)
            spec = get_format(decision.fmt)
            knobs = decision.knobs_dict()
            mat = spec.encode(pruned, **knobs)
        else:
            spec = get_format("dtans")
            knobs = {"lane_width": lane_width,
                     "shared_table": shared_table}
            mat = encode_matrix(pruned, lane_width=lane_width,
                                shared_table=shared_table)
        plan = spec.shard(pruned, k, **knobs) if k > 1 else None
        _, bb = best_baseline_nbytes(pruned)
        return cls(mat=mat, packed=pack_matrix(mat), d_in=d_in,
                   d_out=d_out, dense_bytes=w.size * w.dtype.itemsize,
                   baseline_bytes=bb, decision=decision, mesh=mesh,
                   n_shards=k, plan=plan)

    @property
    def compressed_bytes(self) -> int:
        return self.mat.nbytes

    @property
    def compression_vs_dense(self) -> float:
        return self.dense_bytes / self.mat.nbytes

    @property
    def compression_vs_best_sparse(self) -> float:
        return self.baseline_bytes / self.mat.nbytes

    def apply(self, x, *, interpret: bool = True, bn=None,
              pipeline: bool = False,
              metrics: obs.MetricsRegistry | None = None):
        """x: (..., d_in) -> (..., d_out).

        Every batch size routes through the fused Pallas SpMM kernel
        (`ops.spmm`): the matrix decodes ONCE per call and contracts
        against all B flattened rows of ``x`` in-kernel — the multi-RHS
        generalization of the paper's SpMVM (B == 1 runs the
        single-vector kernel and is bit-identical to `ops.spmv`).
        Accumulation happens in the packed matrix's dtype
        (`ops.out_dtype`) — a float64 weight contracts in float64.

        Large batches route through the grid-blocked path
        automatically: `ops.spmm` column-tiles the RHS when the
        flattened batch's x/y working set overflows the kernel VMEM
        budget (`repro.kernels.tiling.choose_bn`), so a training-shaped
        ``B = batch * seq`` pool never needs x/y resident whole — and
        the blocked result is bit-identical to the unblocked kernel.
        ``bn`` pins the column-tile width explicitly; ``pipeline``
        double-buffers the entropy decode behind the contraction.

        ``metrics``: registry the ``serving.*`` instruments land in
        (the process default when omitted). Callers that isolate their
        instrumentation — `Engine(metrics=...)` threads its own
        registry through — keep dense-vs-compressed benchmark runs from
        cross-contaminating each other's ``serving.*`` numbers.
        """
        dt = ops.out_dtype(self.packed)
        lead = x.shape[:-1]
        xb = jnp.asarray(x, dtype=dt).reshape(-1, self.d_in)
        reg = metrics if metrics is not None else obs.default_registry()
        reg.counter("serving.sparse_apply_calls").add(1)
        reg.histogram("serving.apply_batch").observe(xb.shape[0])
        with obs.span("serving.sparse_apply", batch=int(xb.shape[0]),
                      d_in=self.d_in, d_out=self.d_out,
                      n_shards=int(self.n_shards)):
            if self.plan is not None:
                from repro.kernels import shard_ops
                y = shard_ops.shard_spmm(self.plan, xb.T,
                                         mesh=self.mesh,
                                         interpret=interpret,
                                         bn=bn, pipeline=pipeline)
            else:
                y = ops.spmm(self.packed, xb.T, interpret=interpret,
                             bn=bn, pipeline=pipeline)  # (d_out, B)
        return y.T.reshape(*lead, self.d_out).astype(x.dtype)

    def apply_dense_reference(self, x):
        """Oracle: decode to dense and matmul (tests). Contracts in the
        matrix dtype, like `apply`."""
        from repro.core.csr_dtans import decode_matrix
        w = decode_matrix(self.mat).to_dense()   # (d_out, d_in)
        return (jnp.asarray(x, dtype=w.dtype) @ jnp.asarray(w).T
                ).astype(x.dtype)
