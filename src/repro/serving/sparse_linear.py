"""SparseLinear: a dense projection replaced by a pruned, entropy-coded
weight matrix decoded on the fly (the paper's LLM-inference motivation,
Section I, made concrete).

Pipeline: dense W (d_in, d_out) -> magnitude prune -> codebook-quantize
surviving values (8-bit centroids make the value distribution low-entropy,
which is what dtANS compresses; raw float32 mantissas would all escape) ->
CSR-dtANS encode of W^T (so y = W^T-rows . x = SpMVM per output neuron).

`apply` contracts a batch of activations against the decoded matrix; the
decode runs through the same kernel machinery as `kernels/dtans_spmv`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr_dtans import CSRdtANS, encode_matrix
from repro.kernels import ops
from repro.kernels.pack import PackedMatrix, pack_matrix
from repro.sparse.formats import CSR, best_baseline_nbytes
from repro.sparse.prune import codebook_quantize, magnitude_prune


@dataclasses.dataclass
class SparseLinear:
    mat: CSRdtANS            # encodes W^T: (d_out rows, d_in cols)
    packed: PackedMatrix
    d_in: int
    d_out: int
    dense_bytes: int
    baseline_bytes: int      # best of CSR/COO/SELL on the pruned matrix
    decision: object = None  # autotune Decision when built with auto=True

    @classmethod
    def from_dense(cls, w: np.ndarray, sparsity: float = 0.8,
                   value_bits: int = 8, lane_width: int = 128,
                   shared_table: bool = True, auto: bool = False,
                   autotune_budget: int = 0,
                   autotune_cache=None,
                   autotune_measure: bool = False,
                   autotune_machine=None) -> "SparseLinear":
        """Compress a dense projection for decode-on-the-fly serving.

        The source dtype is preserved end-to-end: a float64 projection
        prunes, quantizes, encodes and decodes in float64 (non-float
        inputs fall back to float32 — the format codes float bit
        patterns).

        With ``auto=True`` the ``lane_width`` / ``shared_table`` knobs are
        ignored and chosen per matrix by `repro.autotune` (fingerprint the
        pruned weight, pick the modeled-fastest entropy-coded
        configuration among every ``decodes=True`` family in
        `repro.sparse.registry` — plain CSR-dtANS, group-aligned
        RGCSR-dtANS, block-aligned BCSR-dtANS, ...; every such family
        runs the same decode kernels, so serving is indifferent, and the
        winning spec's `FormatSpec.encode` builds the artifact — no
        per-format branch here; decisions persist in the autotune cache,
        so repeated serving runs skip the search). ``autotune_budget`` >
        0 additionally encodes the
        top candidates to refine estimated sizes into exact ones;
        ``autotune_measure=True`` further wall-clock times those
        candidates' decode kernels and picks the measured-fastest
        (`repro.autotune.measure`); ``autotune_machine`` substitutes a
        calibrated `MachineModel` (e.g. ``load_profile(...)``) for the
        default v5e constants; ``autotune_cache`` overrides the default
        persistent cache (pass ``repro.autotune.DecisionCache(path=None)``
        for memory-only).
        """
        d_in, d_out = w.shape
        w_arr = np.asarray(w)
        if w_arr.dtype not in (np.float32, np.float64):
            w_arr = w_arr.astype(np.float32)
        pruned = magnitude_prune(w_arr.T, sparsity)
        pruned = codebook_quantize(pruned, bits=value_bits)
        decision = None
        if auto:
            from repro.autotune import V5E, choose_dtans_config
            from repro.sparse.registry import get_format
            decision = choose_dtans_config(
                pruned, warm=True, budget=autotune_budget,
                measure=autotune_measure,
                machine=autotune_machine
                if autotune_machine is not None else V5E,
                cache=autotune_cache)
            mat = get_format(decision.fmt).encode(
                pruned, **decision.knobs_dict())
        else:
            mat = encode_matrix(pruned, lane_width=lane_width,
                                shared_table=shared_table)
        _, bb = best_baseline_nbytes(pruned)
        return cls(mat=mat, packed=pack_matrix(mat), d_in=d_in,
                   d_out=d_out, dense_bytes=w.size * w.dtype.itemsize,
                   baseline_bytes=bb, decision=decision)

    @property
    def compressed_bytes(self) -> int:
        return self.mat.nbytes

    @property
    def compression_vs_dense(self) -> float:
        return self.dense_bytes / self.mat.nbytes

    @property
    def compression_vs_best_sparse(self) -> float:
        return self.baseline_bytes / self.mat.nbytes

    def apply(self, x, *, interpret: bool = True):
        """x: (..., d_in) -> (..., d_out).

        Batched contraction against the decoded sparse matrix: decode once
        (cols, vals), gather x at cols, reduce — the SpMM generalization of
        the paper's SpMVM kernel (one x per request in the batch). Both
        paths accumulate in the packed matrix's dtype (`ops.out_dtype`) —
        a float64 weight is contracted in float64, matching the
        single-vector SpMV path.
        """
        dt = ops.out_dtype(self.packed)
        lead = x.shape[:-1]
        xb = jnp.asarray(x, dtype=dt).reshape(-1, self.d_in)
        if xb.shape[0] == 1:
            y = ops.spmv(self.packed, xb[0], interpret=interpret)[None]
        else:
            cols, vals = ops.decode(self.packed, interpret=interpret)
            S, L, W = cols.shape
            mask = cols >= 0
            xg = jnp.take(xb, jnp.clip(cols, 0, self.d_in - 1),
                          axis=1)                      # (B, S, L, W)
            contrib = jnp.where(mask[None], xg * vals[None], 0.0)
            y = contrib.sum(-1).reshape(xb.shape[0], S * L)[:, :self.d_out]
        return y.reshape(*lead, self.d_out).astype(x.dtype)

    def apply_dense_reference(self, x):
        """Oracle: decode to dense and matmul (tests). Contracts in the
        matrix dtype, like `apply`."""
        from repro.core.csr_dtans import decode_matrix
        w = decode_matrix(self.mat).to_dense()   # (d_out, d_in)
        return (jnp.asarray(x, dtype=w.dtype) @ jnp.asarray(w).T
                ).astype(x.dtype)
