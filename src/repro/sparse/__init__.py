# Sparse-matrix substrate: formats (COO/CSR/SELL), reference SpMVM,
# random-graph generators, and magnitude pruning for NN weights.
