# Sparse-matrix substrate: formats (COO/CSR/SELL + row-grouped CSR in
# rgcsr.py), reference SpMVM, random-graph generators, MatrixMarket IO,
# and magnitude pruning for NN weights.
