"""Blocked CSR (BCSR): CSR over dense r x c blocks.

The matrix is tiled into ``r x c`` blocks; every block containing at
least one nonzero is stored *densely* (all ``r * c`` cells, explicit
zeros included), addressed by one 32-bit block-column index per block
and a CSR-style pointer per block row. Per-element column indices
disappear entirely — the whole point of the format: on matrices whose
nonzeros cluster into tiles (FEM stencils, multi-DOF meshes, pruned NN
weights with structured masks) the index overhead drops from 4 bytes
per nonzero to ``4 / (r * c * fill)`` bytes, and the kernel processes
fully dense tiles in lock-step with zero per-element control flow.

The layout follows the blocked formats the SMASH line (Kanellopoulos et
al.) and AlphaSparse's operator zoo both draw on; the trade it makes is
*fill-in*: a block with one nonzero still stores (and processes) all
``r * c`` cells, so the format only wins when the block-fill histogram
says the matrix is block-structured — exactly the per-matrix question
`repro.autotune` answers from `Fingerprint.block_nonempty`.

Byte-exact accounting (`nbytes`, mirrored fingerprint-side by
`bcsr_nbytes_exact`): 32-bit block-column indices, 32-bit block-row
pointers, ``r * c`` values per stored block.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.formats import CSR

#: Block shapes swept by the autotuner (`repro.autotune`); the
#: fingerprint carries an exact nonempty-block count for each.  The
#: rectangular entries cover banded/row-run structure (wide blocks pay
#: less row metadata per stored cell; tall blocks align more rows per
#: block row) — the format and fingerprint support any r x c, this
#: tuple is only the default sweep.
BCSR_BLOCK_SHAPES = ((2, 2), (4, 4), (8, 8), (2, 4), (4, 2))


def count_nonempty_blocks(indptr: np.ndarray, indices: np.ndarray,
                          shape: tuple, block_shape: tuple,
                          row_of: np.ndarray | None = None) -> int:
    """Number of nonempty ``r x c`` blocks of a CSR pattern (O(nnz)).

    Shared by `BCSR.from_csr`, the format accounting below and
    `repro.autotune.fingerprint`, so the selector's 'exact' sizes can
    never drift from the format's own. ``row_of`` optionally passes a
    precomputed per-nonzero row-id expansion (callers evaluating
    several block shapes avoid re-deriving it per shape).
    """
    r, c = block_shape
    m, n = shape
    indptr = np.asarray(indptr, dtype=np.int64)
    nnz = int(indptr[-1])
    if nnz == 0:
        return 0
    if row_of is None:
        row_of = np.repeat(np.arange(m, dtype=np.int64), np.diff(indptr))
    nbc = (n + c - 1) // c
    block_id = (row_of // r) * nbc + np.asarray(indices, np.int64) // c
    return int(np.unique(block_id).size)


def bcsr_nbytes_exact(n_blocks: int, rows: int, block_shape: tuple,
                      value_bytes: int) -> int:
    """`BCSR.nbytes` from the nonempty-block count alone."""
    r, c = block_shape
    nbr = (rows + r - 1) // r
    return n_blocks * (4 + r * c * value_bytes) + (nbr + 1) * 4


@dataclasses.dataclass
class BCSR:
    """Blocked CSR with dense ``r x c`` blocks."""

    block_shape: tuple         # (r, c)
    block_ptr: np.ndarray      # (n_block_rows + 1,) absolute block offsets
    block_cols: np.ndarray     # (n_blocks,) block-column indices
    values: np.ndarray         # (n_blocks, r, c), explicit zeros included
    shape: tuple[int, int]

    @property
    def n_blocks(self) -> int:
        return int(self.block_cols.size)

    @property
    def n_block_rows(self) -> int:
        return int(self.block_ptr.size - 1)

    @property
    def nnz_stored(self) -> int:
        """Stored cells, fill-in included (the kernel's work count)."""
        r, c = self.block_shape
        return self.n_blocks * r * c

    @property
    def nbytes(self) -> int:
        return bcsr_nbytes_exact(self.n_blocks, self.shape[0],
                                 self.block_shape,
                                 self.values.dtype.itemsize)

    @classmethod
    def from_csr(cls, a: CSR, block_shape: tuple = (4, 4)) -> "BCSR":
        r, c = block_shape
        if r < 1 or c < 1:
            raise ValueError(f"block dims must be >= 1, got {block_shape}")
        m, n = a.shape
        nbr = (m + r - 1) // r
        nbc = (n + c - 1) // c
        row_of = np.repeat(np.arange(m, dtype=np.int64), np.diff(a.indptr))
        cols = np.asarray(a.indices, dtype=np.int64)
        bid = (row_of // r) * nbc + cols // c
        blocks, inv = np.unique(bid, return_inverse=True)
        values = np.zeros((blocks.size, r, c), dtype=a.values.dtype)
        # scatter each nonzero into its block cell
        values[inv, row_of % r, cols % c] = a.values
        block_rows = blocks // nbc
        block_cols = blocks % nbc
        block_ptr = np.zeros(nbr + 1, dtype=np.int64)
        np.add.at(block_ptr, block_rows + 1, 1)
        block_ptr = np.cumsum(block_ptr)
        return cls(block_shape=(r, c), block_ptr=block_ptr,
                   block_cols=block_cols, values=values, shape=a.shape)

    def to_dense(self) -> np.ndarray:
        r, c = self.block_shape
        m, n = self.shape
        out = np.zeros((m, n), dtype=self.values.dtype)
        for br in range(self.n_block_rows):
            for k in range(int(self.block_ptr[br]),
                           int(self.block_ptr[br + 1])):
                bc = int(self.block_cols[k])
                r0, c0 = br * r, bc * c
                rr = min(r, m - r0)
                cc = min(c, n - c0)
                out[r0:r0 + rr, c0:c0 + cc] = self.values[k, :rr, :cc]
        return out

    def to_csr(self) -> CSR:
        """Back to CSR, dropping the fill-in zeros (lossless for
        matrices built by `from_csr`, which never stores an explicit
        zero value)."""
        return CSR.from_dense(self.to_dense())

    def spmv(self, x: np.ndarray, y: np.ndarray | None = None) -> np.ndarray:
        """Reference y = A x + y running the block layout directly."""
        r, c = self.block_shape
        m, n = self.shape
        out = (np.zeros(m, dtype=self.values.dtype) if y is None
               else y.astype(self.values.dtype).copy())
        for br in range(self.n_block_rows):
            acc = np.zeros(r, dtype=self.values.dtype)
            for k in range(int(self.block_ptr[br]),
                           int(self.block_ptr[br + 1])):
                c0 = int(self.block_cols[k]) * c
                cc = min(c, n - c0)
                acc += self.values[k, :, :cc] @ x[c0:c0 + cc]
            rr = min(r, m - br * r)
            out[br * r:br * r + rr] += acc[:rr]
        return out


def block_fill_csr(a: CSR, block_shape: tuple = (4, 4)) -> CSR:
    """CSR of ``a`` with every nonempty block's in-bounds cells made
    explicit (zeros stored). This is the index layout `BCSRdtANS`
    entropy-codes: within a block the column deltas degenerate to runs
    of 1 — near-zero entropy — which is how the blocked layout composes
    with the dtANS layer without any new kernel machinery.

    Vectorized (no per-block-row Python loop): this runs once per
    admitted block shape of every matrix the exhaustive oracle encodes,
    including real ``--mtx-dir`` inputs.
    """
    r, c = block_shape
    m, n = a.shape
    b = BCSR.from_csr(a, block_shape)
    if b.n_blocks == 0:
        return CSR(indptr=np.zeros(m + 1, dtype=np.int64),
                   indices=np.zeros(0, dtype=np.int64),
                   values=np.zeros(0, dtype=a.values.dtype),
                   shape=a.shape)
    # Per stored cell (block-major, row-in-block, col-in-block order):
    # its absolute column and row; drop out-of-bounds edge cells.
    brow_of = np.repeat(np.arange(b.n_block_rows, dtype=np.int64),
                        np.diff(b.block_ptr))          # (nblocks,)
    cell_cols = (b.block_cols[:, None] * c
                 + np.arange(c, dtype=np.int64)[None, :])  # (nblocks, c)
    rows_parts, cols_parts, vals_parts = [], [], []
    for i in range(r):          # <= 8 iterations, all-array bodies
        cell_rows = np.repeat(brow_of * r + i, c)
        ok = (cell_cols.reshape(-1) < n) & (cell_rows < m)
        rows_parts.append(cell_rows[ok])
        cols_parts.append(cell_cols.reshape(-1)[ok])
        vals_parts.append(b.values[:, i, :].reshape(-1)[ok])
    rows = np.concatenate(rows_parts)
    cols = np.concatenate(cols_parts)
    vals = np.concatenate(vals_parts)
    # Stable sort by row: within a row all cells come from one i-slice,
    # already in ascending block/column order.
    order = np.argsort(rows, kind="stable")
    indptr = np.zeros(m + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(np.bincount(rows, minlength=m))
    return CSR(indptr=indptr, indices=cols[order], values=vals[order],
               shape=a.shape)
