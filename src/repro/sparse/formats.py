"""Sparse matrix formats: COO, CSR, SELL (paper Section III-A).

These are the cuSPARSE-equivalent baselines the paper compares against, with
byte-exact size accounting (32-bit indices, 32/64-bit values) used in
benchmarks/bench_compression.py (paper Fig. 6 / Table I).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSR:
    """Compressed sparse row (Fig. 2 of the paper)."""
    indptr: np.ndarray    # (m+1,) int64 (stored as 32-bit for sizing)
    indices: np.ndarray   # (nnz,) int64 (stored as 32-bit for sizing)
    values: np.ndarray    # (nnz,) float32/float64
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.values.size)

    @property
    def nbytes(self) -> int:
        vb = self.values.dtype.itemsize
        return self.nnz * (4 + vb) + (self.shape[0] + 1) * 4

    def to_dense(self) -> np.ndarray:
        m, n = self.shape
        out = np.zeros((m, n), dtype=self.values.dtype)
        for i in range(m):
            s, e = self.indptr[i], self.indptr[i + 1]
            out[i, self.indices[s:e]] += self.values[s:e]
        return out

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    @classmethod
    def from_dense(cls, a: np.ndarray) -> "CSR":
        m, n = a.shape
        mask = a != 0
        indptr = np.zeros(m + 1, dtype=np.int64)
        indptr[1:] = np.cumsum(mask.sum(axis=1))
        cols = np.nonzero(mask)[1]
        vals = a[mask]
        return cls(indptr=indptr, indices=cols.astype(np.int64),
                   values=vals, shape=(m, n))

    @classmethod
    def from_coo(cls, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 shape: tuple[int, int], sum_duplicates: bool = True) -> "CSR":
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        if sum_duplicates and rows.size:
            key_same = np.zeros(rows.size, dtype=bool)
            key_same[1:] = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
            if key_same.any():
                group = np.cumsum(~key_same) - 1
                nv = np.zeros(group[-1] + 1, dtype=vals.dtype)
                np.add.at(nv, group, vals)
                keep = ~key_same
                rows, cols, vals = rows[keep], cols[keep], nv
        m = shape[0]
        indptr = np.zeros(m + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(indptr=indptr, indices=cols.astype(np.int64),
                   values=vals, shape=shape)


@dataclasses.dataclass
class COO:
    rows: np.ndarray
    cols: np.ndarray
    values: np.ndarray
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.values.size)

    @property
    def nbytes(self) -> int:
        return self.nnz * (8 + self.values.dtype.itemsize)

    @classmethod
    def from_csr(cls, a: CSR) -> "COO":
        rows = np.repeat(np.arange(a.shape[0], dtype=np.int64),
                         np.diff(a.indptr))
        return cls(rows=rows, cols=a.indices.copy(), values=a.values.copy(),
                   shape=a.shape)


@dataclasses.dataclass
class SELL:
    """Sliced ELLPACK, slice height C (paper: GPU-friendly SIMD format).

    Rows in a slice are padded to the slice's max nnz; values/indices stored
    column-major per slice. Size: one offset per slice + one index per
    stored (incl. padded) entry.
    """
    slice_height: int
    slice_offsets: np.ndarray   # (nslices+1,) into packed arrays
    indices: np.ndarray         # packed, padded, column-major per slice
    values: np.ndarray
    shape: tuple[int, int]

    @property
    def nbytes(self) -> int:
        vb = self.values.dtype.itemsize
        return (self.indices.size * (4 + vb)
                + (self.slice_offsets.size) * 4)

    @classmethod
    def from_csr(cls, a: CSR, slice_height: int = 32) -> "SELL":
        m, _ = a.shape
        C = slice_height
        nsl = (m + C - 1) // C
        rnnz = np.diff(a.indptr)
        idx_chunks, val_chunks = [], []
        offsets = np.zeros(nsl + 1, dtype=np.int64)
        for s in range(nsl):
            r0, r1 = s * C, min((s + 1) * C, m)
            w = int(rnnz[r0:r1].max()) if r1 > r0 else 0
            rows = r1 - r0
            ind = np.zeros((C, w), dtype=np.int64)
            val = np.zeros((C, w), dtype=a.values.dtype)
            for i in range(rows):
                lo, hi = a.indptr[r0 + i], a.indptr[r0 + i + 1]
                ind[i, :hi - lo] = a.indices[lo:hi]
                val[i, :hi - lo] = a.values[lo:hi]
            # column-major within the slice
            idx_chunks.append(ind.T.ravel())
            val_chunks.append(val.T.ravel())
            offsets[s + 1] = offsets[s] + C * w
        return cls(
            slice_height=C,
            slice_offsets=offsets,
            indices=(np.concatenate(idx_chunks) if idx_chunks
                     else np.zeros(0, dtype=np.int64)),
            values=(np.concatenate(val_chunks) if val_chunks
                    else np.zeros(0, dtype=a.values.dtype)),
            shape=a.shape,
        )


def best_baseline_nbytes(a: CSR) -> tuple[str, int]:
    """Smallest of CSR/COO/SELL — the paper's compression baseline.

    RGCSR (`repro.sparse.rgcsr`) is deliberately NOT part of this
    baseline: the paper compares against the cuSPARSE formats, and the
    Fig. 6 / Table I reproductions must keep that denominator. Use
    `all_format_nbytes` for the full byte-exact table.
    """
    sizes = {
        "csr": a.nbytes,
        "coo": COO.from_csr(a).nbytes,
        "sell": SELL.from_csr(a).nbytes,
    }
    name = min(sizes, key=sizes.get)
    return name, sizes[name]


def all_format_nbytes(a: CSR, group_sizes: tuple = None) -> dict[str, int]:
    """Byte-exact size of every uncompressed format, RGCSR included.

    Returns ``{"csr": ..., "coo": ..., "sell": ..., "rgcsr[G=4]": ...}``.
    RGCSR sizes come from the row-nnz histogram (no construction), which
    tests assert equals `RGCSR.from_csr(a, G).nbytes`.
    """
    from repro.sparse.rgcsr import (RGCSR_GROUP_SIZES, rgcsr_nbytes_exact)
    if group_sizes is None:
        group_sizes = RGCSR_GROUP_SIZES
    sizes = {
        "csr": a.nbytes,
        "coo": COO.from_csr(a).nbytes,
        "sell": SELL.from_csr(a).nbytes,
    }
    rnnz = a.row_nnz()
    vb = a.values.dtype.itemsize
    for g in group_sizes:
        sizes[f"rgcsr[G={g}]"] = rgcsr_nbytes_exact(rnnz, g, vb)
    return sizes
