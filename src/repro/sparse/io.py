"""MatrixMarket (.mtx) I/O — feed SuiteSparse-style matrices to the
tuner and benchmarks without a scipy dependency.

Supports the ``coordinate`` format with ``real`` / ``integer`` /
``pattern`` fields and ``general`` / ``symmetric`` / ``skew-symmetric``
symmetries, plus dense ``array real general`` files. ``.gz`` paths are
transparently decompressed. Writing always produces
``coordinate real general`` (the lossless lowest common denominator).

    from repro.sparse.io import load_mtx, save_mtx
    a = load_mtx("suitesparse/bcsstk17.mtx.gz")   # -> formats.CSR
    decision = repro.autotune.select(a)
"""

from __future__ import annotations

import gzip
import io as _io
import os

import numpy as np

from repro.sparse.formats import CSR

_BANNER = "%%MatrixMarket"


def _open(path_or_file, mode: str):
    if hasattr(path_or_file, "read") or hasattr(path_or_file, "write"):
        return path_or_file, False
    path = os.fspath(path_or_file)
    if path.endswith(".gz"):
        return gzip.open(path, mode + "t"), True
    return open(path, mode), True


def load_mtx(path_or_file) -> CSR:
    """Read a MatrixMarket file into a `repro.sparse.formats.CSR`."""
    f, owned = _open(path_or_file, "r")
    try:
        header = f.readline()
        if isinstance(header, bytes):
            raise ValueError("open MatrixMarket files in text mode")
        parts = header.strip().split()
        if len(parts) != 5 or parts[0] != _BANNER:
            raise ValueError(f"not a MatrixMarket file: {header!r}")
        _, obj, fmt, field, symmetry = (p.lower() for p in parts)
        if obj != "matrix":
            raise ValueError(f"unsupported object {obj!r}")
        if field == "complex":
            raise ValueError("complex matrices are not supported")
        if symmetry == "hermitian":
            raise ValueError("hermitian matrices are not supported")
        if fmt not in ("coordinate", "array"):
            raise ValueError(f"unsupported format {fmt!r}")
        if symmetry not in ("general", "symmetric", "skew-symmetric"):
            raise ValueError(f"unsupported symmetry {symmetry!r}")

        line = f.readline()
        while line and line.lstrip().startswith("%"):
            line = f.readline()
        dims = line.split()

        if fmt == "array":
            if symmetry != "general":
                raise ValueError("array format only supported as general")
            m, n = int(dims[0]), int(dims[1])
            data = np.loadtxt(f, dtype=np.float64, ndmin=1)
            if data.size != m * n:
                raise ValueError(
                    f"array body has {data.size} entries, expected {m * n}")
            return CSR.from_dense(data.reshape((n, m)).T)  # column-major

        m, n, nnz = int(dims[0]), int(dims[1]), int(dims[2])
        if nnz == 0:
            return CSR(indptr=np.zeros(m + 1, dtype=np.int64),
                       indices=np.zeros(0, dtype=np.int64),
                       values=np.zeros(0, dtype=np.float64), shape=(m, n))
        body = np.loadtxt(f, dtype=np.float64, ndmin=2)
        if body.shape[0] != nnz:
            raise ValueError(
                f"body has {body.shape[0]} entries, header says {nnz}")
        rows = body[:, 0].astype(np.int64) - 1
        cols = body[:, 1].astype(np.int64) - 1
        if field == "pattern":
            vals = np.ones(rows.size, dtype=np.float64)
        else:
            if body.shape[1] < 3:
                raise ValueError(f"{field!r} entries need a value column")
            vals = body[:, 2]
        if rows.size and ((rows < 0).any() or (rows >= m).any()
                          or (cols < 0).any() or (cols >= n).any()):
            raise ValueError("index out of range (file is 1-based)")

        if symmetry in ("symmetric", "skew-symmetric"):
            off = rows != cols          # mirror strictly-lower entries
            sign = -1.0 if symmetry == "skew-symmetric" else 1.0
            rows, cols, vals = (np.concatenate([rows, cols[off]]),
                                np.concatenate([cols, rows[off]]),
                                np.concatenate([vals, sign * vals[off]]))
        return CSR.from_coo(rows, cols, vals, (m, n),
                            sum_duplicates=False)
    finally:
        if owned:
            f.close()


def save_mtx(path_or_file, a: CSR, comment: str | None = None) -> None:
    """Write ``a`` as ``coordinate real general`` MatrixMarket."""
    f, owned = _open(path_or_file, "w")
    try:
        m, n = a.shape
        f.write(f"{_BANNER} matrix coordinate real general\n")
        if comment:
            for line in comment.splitlines():
                f.write(f"% {line}\n")
        f.write(f"{m} {n} {a.nnz}\n")
        rows = np.repeat(np.arange(m, dtype=np.int64), a.row_nnz())
        buf = _io.StringIO()
        for r, c, v in zip(rows, a.indices, a.values):
            buf.write(f"{r + 1} {c + 1} {v:.17g}\n")
        f.write(buf.getvalue())
    finally:
        if owned:
            f.close()
