"""Magnitude pruning of dense NN weight matrices -> CSR.

The paper motivates entropy-coded SpMVM with pruned-LLM inference
(SparseGPT / SpQR citations). This is the bridge: prune a dense weight,
optionally quantize the surviving values to a small codebook (which is what
makes entropy coding effective on NN weights), and hand the result to
CSR-dtANS via `repro.core.csr_dtans.encode_matrix`.
"""

from __future__ import annotations

import numpy as np

from repro.sparse.formats import CSR


def magnitude_prune(w: np.ndarray, sparsity: float) -> CSR:
    """Zero out the smallest-|w| fraction ``sparsity`` of entries."""
    if not 0.0 <= sparsity < 1.0:
        raise ValueError("sparsity in [0, 1)")
    flat = np.abs(w).ravel()
    k = int(round(sparsity * flat.size))
    if k > 0:
        thresh = np.partition(flat, k - 1)[k - 1]
        w = np.where(np.abs(w) <= thresh, 0.0, w).astype(w.dtype)
    return CSR.from_dense(np.asarray(w))


def codebook_quantize(a: CSR, bits: int = 8) -> CSR:
    """Cluster surviving values to 2^bits centroids (uniform quantiles).

    Entropy coding of raw float weights barely compresses (all mantissas
    distinct); a codebook makes the value distribution low-entropy while
    keeping accuracy loss tiny — the standard lossy/lossless split. The
    *format* stays lossless w.r.t. its input, matching the paper's scope.
    """
    vals = a.values
    n_centroids = 1 << bits
    qs = np.linspace(0.0, 1.0, n_centroids)
    centroids = np.unique(np.quantile(vals, qs))
    idx = np.searchsorted(centroids, vals)
    idx = np.clip(idx, 1, centroids.size - 1)
    left = centroids[idx - 1]
    right = centroids[idx]
    snapped = np.where(np.abs(vals - left) <= np.abs(right - vals),
                       left, right).astype(vals.dtype)
    return CSR(indptr=a.indptr.copy(), indices=a.indices.copy(),
               values=snapped, shape=a.shape)
