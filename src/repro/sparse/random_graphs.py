"""Random graph adjacency generators (paper Fig. 4): Erdős–Rényi,
Watts–Strogatz, Barabási–Albert. Used to reproduce the delta-encoding
entropy-reduction experiment and to generate benchmark matrices."""

from __future__ import annotations

import numpy as np

from repro.sparse.formats import CSR


def erdos_renyi(n: int, avg_degree: float, rng: np.random.Generator) -> CSR:
    """G(n, p) with p = avg_degree / n; directed adjacency, unit values."""
    p = min(1.0, avg_degree / n)
    # sample via geometric gaps over the flattened index space (memory-safe)
    total = n * n
    est = int(total * p * 1.2 + 100)
    gaps = rng.geometric(p, size=est)
    pos = np.cumsum(gaps) - 1
    pos = pos[pos < total]
    while pos.size and (pos[-1] < total - 1):
        extra = rng.geometric(p, size=est // 4 + 16)
        more = pos[-1] + np.cumsum(extra)
        pos = np.concatenate([pos, more[more < total]])
        if more.size and more[-1] >= total:
            break
    rows, cols = pos // n, pos % n
    vals = np.ones(rows.size, dtype=np.float64)
    return CSR.from_coo(rows, cols, vals, (n, n))


def watts_strogatz(n: int, k: int, beta: float,
                   rng: np.random.Generator) -> CSR:
    """Ring lattice with k neighbors per side, rewired with prob beta."""
    rows = np.repeat(np.arange(n, dtype=np.int64), 2 * k)
    offs = np.concatenate([np.arange(1, k + 1), -np.arange(1, k + 1)])
    cols = (rows.reshape(n, 2 * k) + offs[None, :]).ravel() % n
    rewire = rng.random(rows.size) < beta
    cols[rewire] = rng.integers(0, n, size=int(rewire.sum()))
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    vals = np.ones(rows.size, dtype=np.float64)
    return CSR.from_coo(rows, cols, vals, (n, n))


def barabasi_albert(n: int, m: int, rng: np.random.Generator) -> CSR:
    """Preferential attachment with m edges per new node (small-world)."""
    targets = list(range(m))
    repeated: list[int] = []
    rows, cols = [], []
    for v in range(m, n):
        for t in targets:
            rows.append(v)
            cols.append(t)
        repeated.extend(targets)
        repeated.extend([v] * m)
        # next targets: preferential sample from the degree-weighted list
        targets = [repeated[i] for i in
                   rng.integers(0, len(repeated), size=m)]
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.ones(rows.size, dtype=np.float64)
    return CSR.from_coo(rows, cols, vals, (n, n))


def stencil_2d(side: int, dtype=np.float64) -> CSR:
    """5-point 2-D Laplacian stencil — the classic scientific-computing
    matrix family where delta-encoding shines (paper Section IV-A)."""
    n = side * side
    idx = np.arange(n, dtype=np.int64)
    r, c = idx // side, idx % side
    rows = [idx]
    cols = [idx]
    vals = [np.full(n, 4.0, dtype=dtype)]
    for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
        ok = (0 <= r + dr) & (r + dr < side) & (0 <= c + dc) & (c + dc < side)
        rows.append(idx[ok])
        cols.append(((r + dr) * side + (c + dc))[ok])
        vals.append(np.full(int(ok.sum()), -1.0, dtype=dtype))
    return CSR.from_coo(np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals), (n, n))


def banded(n: int, bands: int, dtype=np.float64,
           rng: np.random.Generator | None = None) -> CSR:
    """Banded matrix with ``bands`` diagonals and few distinct values."""
    rng = rng or np.random.default_rng(0)
    offs = np.unique(np.concatenate([[0], rng.integers(-8, 9, size=bands)]))
    rows, cols, vals = [], [], []
    palette = rng.standard_normal(4).astype(dtype)
    for j, off in enumerate(offs):
        idx = np.arange(max(0, -off), min(n, n - off), dtype=np.int64)
        rows.append(idx)
        cols.append(idx + off)
        vals.append(np.full(idx.size, palette[j % palette.size], dtype=dtype))
    return CSR.from_coo(np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals), (n, n))


def block_sparse(n_block_rows: int, n_block_cols: int,
                 block: tuple = (4, 4), density: float = 0.05,
                 rng: np.random.Generator | None = None,
                 dtype=np.float64) -> CSR:
    """Block-structured sparsity: a uniform random ``density`` fraction
    of ``r x c`` tiles is fully dense (random values), the rest empty —
    the FEM / multi-DOF-mesh / structured-pruning pattern blocked
    formats exist for (every stored tile is 100% filled, so BCSR pays
    zero fill-in)."""
    r, c = block
    rng = rng or np.random.default_rng(0)
    mask = rng.random((n_block_rows, n_block_cols)) < density
    bi, bj = np.nonzero(mask)
    nb = bi.size
    dr = np.arange(r, dtype=np.int64)
    dc = np.arange(c, dtype=np.int64)
    rows = (bi[:, None] * r + dr[None, :]).repeat(c, axis=1).reshape(-1)
    cols = np.tile((bj[:, None] * c + dc[None, :]), (1, r)).reshape(-1)
    vals = rng.standard_normal(nb * r * c).astype(dtype)
    return CSR.from_coo(rows, cols, vals,
                        (n_block_rows * r, n_block_cols * c))
