"""FormatSpec registry: one object per sparse-format family, one seam
for every layer that dispatches on a format.

Before this module existed, candidate generation (`autotune/search`),
the exact-size oracle (`autotune/oracle`), kernel timing
(`autotune/measure`), cost modeling (`autotune/cost_model`), serving
(`serving/sparse_linear`) and the kernel entry points each carried
their own ``if fmt == ...`` chain over the same format names — six
coordinated edits per new format. Now a format is ONE `FormatSpec`
subclass registered here; every consumer iterates the registry:

* ``knob_grid`` / ``candidates`` — the configuration sweep the
  autotuner and the exhaustive oracle both enumerate (a single source,
  so selector and oracle can never disagree about the candidate set);
* ``nbytes_exact`` / ``nbytes_estimate`` / ``nbytes_constructed`` —
  fingerprint-exact, fingerprint-estimated and constructed-truth byte
  counts (`select(budget=k)` refinement and the oracle use the last);
* ``cost_terms`` — the lock-step / row-sequential / decode work split
  the roofline model and `measure.calibrate`'s design matrix charge;
* ``pack`` / ``runner`` / ``spmv_fn`` — the registered kernel path the
  timing harness and the conformance suite drive;
* ``spmm_fn`` / ``spmm_runner`` / ``spmm`` — the multi-RHS path
  (``X: (n, B)`` -> ``Y: (m, B)``): fused SpMM kernels where the
  format has one, a generic per-column fallback otherwise, so every
  registered format serves batches;
* ``encode_knobs`` / ``decode_knobs`` — the canonical config-string
  round-trip (``"rgcsr_dtans[G=8,shared]"``), replacing ad-hoc
  ``p.startswith("G=")`` parsing;
* ``encode`` — the storable entropy-coded artifact serving builds
  (``decodes=True`` formats only).

``fp`` arguments are duck-typed `repro.autotune.fingerprint.Fingerprint`
objects; this module deliberately imports nothing from ``repro.autotune``
at load time so the dependency points one way (autotune -> registry).

Adding a format touches exactly one file (see ``docs/formats.md`` for
the worked bcsr walkthrough): subclass `FormatSpec`, call `register`.
The autotune sweep, the fig9 selector-vs-oracle benchmark, serving's
``auto=True`` path and the conformance suite pick it up by iteration.
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.params import PAPER, DtansParams

#: dtANS interleave widths swept by the tuner: GPU-warp and TPU-lane.
DTANS_LANE_WIDTHS = (32, 128)
DTANS_SHARED_TABLE = (True, False)

#: Fill-in guard for the blocked entropy format: a block layout whose
#: stored-cell count exceeds this multiple of nnz is pointless to
#: encode (and expensive for the oracle), so the knob grid skips it.
BCSR_DTANS_MAX_FILL = 3.0


@dataclasses.dataclass(frozen=True)
class CostTerms:
    """Per-kernel work split of one (format, config) on one matrix.

    The roofline model charges ``lockstep`` element slots at
    ``spmv_ops_per_elem``, ``rowseq`` elements additionally at
    ``row_seq_penalty``, and ``decode`` elements at
    ``decode_ops_per_nnz`` — and `measure.calibrate` fits exactly those
    three coefficients, so a format's cost terms define both its
    modeled time and its calibration design-matrix row.
    """

    lockstep: float = 0.0
    rowseq: float = 0.0
    decode: float = 0.0

    @property
    def work_elems(self) -> float:
        """Total processed element slots (reporting)."""
        return self.lockstep + self.rowseq


#: Config-string component spellings: knob name -> (prefix, parse).
_KNOB_PREFIX = {
    "group_size": "G=",
    "lane_width": "w=",
    "slice_height": "C=",
    "block_shape": "B=",
}


def _render_knob(name: str, value) -> str:
    if name == "shared_table":
        return "shared" if value else "split"
    if name == "block_shape":
        r, c = value
        return f"B={r}x{c}"
    # Unlisted knobs (third-party FormatSpecs) spell out their name.
    return f"{_KNOB_PREFIX.get(name, name + '=')}{value}"


def _parse_component(p: str, knob_names=()) -> tuple[str, object]:
    if p == "shared":
        return "shared_table", True
    if p == "split":
        return "shared_table", False
    head, eq, body = p.partition("=")
    if eq and head in knob_names:
        # A knob the spec literally declares wins over the reserved
        # short prefixes (a third-party spec may name a knob "G" or
        # "B"; the reserved meanings cannot apply to a spec that does
        # not declare group_size/block_shape anyway). Values round-trip
        # through their repr: int, then bool, then float, else the
        # string itself (mode=("fast", "safe")).
        if body in ("True", "False"):
            return head, body == "True"
        for conv in (int, float):
            try:
                return head, conv(body)
            except ValueError:
                pass
        return head, body
    for name, prefix in _KNOB_PREFIX.items():
        if p.startswith(prefix):
            body = p[len(prefix):]
            if name == "block_shape":
                r, _, c = body.partition("x")
                return name, (int(r), int(c))
            return name, int(body)
    raise ValueError(f"unknown config component {p!r}")


class FormatSpec:
    """One sparse-format family: knobs, sizes, cost terms, kernels.

    Subclasses override the class attributes and the methods their
    family supports; `register` makes the format visible to every
    registry consumer. See the module docstring for the contract and
    ``docs/formats.md`` for a worked example.
    """

    #: Family name — the ``fmt`` string everywhere.
    name: str = ""
    #: Enumerated by the autotuner's candidate search and the oracle.
    #: ``dense`` is registered but not selectable (it is the timing
    #: harness's bandwidth anchor, not a sparse candidate).
    selectable: bool = True
    #: Entropy-coded: owns an `encode` producing a decode-on-the-fly
    #: artifact (what serving's ``auto=True`` chooses among).
    decodes: bool = False
    #: Ordered knob domains: name -> default sweep tuple. The first
    #: entry of each domain is the knob's default.
    knob_domains: dict = {}
    #: Knobs always spelled in the config name (others appear only when
    #: they differ from the default — ``"sell"`` vs ``"sell[C=16]"``).
    named_knobs: tuple = ()
    #: Small-width knobs for the conformance corpus's tiny matrices.
    conformance_knobs: dict = {}

    # -- knobs -------------------------------------------------------

    def default_knobs(self) -> dict:
        return {k: v[0] for k, v in self.knob_domains.items()}

    def _knobs(self, knobs: dict) -> dict:
        """Defaults overlaid with ``knobs``; rejects unknown names."""
        unknown = set(knobs) - set(self.knob_domains)
        if unknown:
            raise ValueError(f"{self.name}: unknown knobs "
                             f"{sorted(unknown)}")
        out = self.default_knobs()
        out.update({k: v for k, v in knobs.items() if v is not None})
        if "block_shape" in out:
            out["block_shape"] = tuple(out["block_shape"])
        return out

    def normalize_knobs(self, knobs: dict | None = None) -> dict:
        """Public form of `_knobs`: defaults applied, names validated."""
        return self._knobs(knobs or {})

    def filter_knobs(self, knobs: dict) -> dict:
        """Drop None values and knobs this format does not declare —
        the one sanitization policy for caller-supplied knob sets (the
        cost model and the timing harness both accept a candidate's
        full knob surface and keep only what the format understands)."""
        return {k: v for k, v in knobs.items()
                if v is not None and k in self.knob_domains}

    def knob_grid(self, fp=None, overrides: dict | None = None
                  ) -> list[dict]:
        """Every knob combination the sweep enumerates for this format
        (``overrides`` narrows/extends individual knob domains; entries
        for knobs this format does not have are ignored). ``fp`` lets
        `admit` prune matrix-adaptive nonsense configurations."""
        axes = []
        for k, dom in self.knob_domains.items():
            if overrides and overrides.get(k) is not None:
                dom = tuple(overrides[k])
            axes.append([(k, v) for v in dom])
        grid = [self._knobs(dict(combo))
                for combo in itertools.product(*axes)]
        return [g for g in grid if fp is None or self.admit(fp, g)]

    def admit(self, fp, knobs: dict) -> bool:
        """Matrix-adaptive configuration filter (default: admit all)."""
        return True

    def encode_knobs(self, knobs: dict | None = None) -> str:
        """Canonical config name, e.g. ``"dtans[w=32,shared]"``."""
        kn = self._knobs(knobs or {})
        defaults = self.default_knobs()
        parts = [_render_knob(k, kn[k]) for k in self.knob_domains
                 if k in self.named_knobs or kn[k] != defaults[k]]
        return f"{self.name}[{','.join(parts)}]" if parts else self.name

    def decode_knobs(self, config_name: str) -> dict:
        """Inverse of `encode_knobs`; returns only the spelled knobs
        (defaults are applied by the consuming methods)."""
        fmt, _, rest = config_name.partition("[")
        if fmt != self.name:
            raise ValueError(f"config {config_name!r} is not a "
                             f"{self.name!r} config")
        out: dict = {}
        if rest:
            for p in rest.rstrip("]").split(","):
                k, v = _parse_component(p, tuple(self.knob_domains))
                if k not in self.knob_domains:
                    raise ValueError(
                        f"{self.name}: component {p!r} in "
                        f"{config_name!r} names no knob of this format")
                out[k] = v
        return out

    def interleave_width(self, knobs: dict | None = None) -> int | None:
        """Decode-slice interleave width of an encoded artifact
        (``decodes=True`` formats); None for plain formats."""
        return None

    def artifact_key(self, knobs: dict | None = None) -> tuple:
        """Key under which expensive constructed artifacts memoize in a
        shared ``artifacts`` mapping (oracle / measure / refinement)."""
        kn = self._knobs(knobs or {})
        return (self.name,) + tuple(kn[k] for k in self.knob_domains)

    # -- sizing ------------------------------------------------------

    def nbytes_exact(self, fp, **knobs) -> int | None:
        """Byte-exact size from the fingerprint alone, or None when the
        fingerprint cannot carry it (estimate + refinement instead)."""
        return None

    def nbytes_estimate(self, fp, *, params: DtansParams = PAPER,
                        **knobs) -> int:
        """Estimated size from fingerprint features (entropy formats)."""
        b = self.nbytes_exact(fp, **knobs)
        if b is None:
            raise NotImplementedError(
                f"{self.name}: no size estimate")
        return b

    def nbytes_constructed(self, a, *, params: DtansParams = PAPER,
                           artifacts: dict | None = None,
                           **knobs) -> int:
        """Constructed-truth size (builds/encodes; memoized under
        `artifact_key` when ``artifacts`` is given)."""
        raise NotImplementedError(f"{self.name}: nbytes_constructed")

    # -- cost model --------------------------------------------------

    def cost_terms(self, fp, **knobs) -> CostTerms:
        raise NotImplementedError(f"{self.name}: cost_terms")

    # -- kernels -----------------------------------------------------

    @property
    def spmv_fn(self):
        """The public ``repro.kernels.ops`` entry point this format's
        runner drives, or None for XLA-lowered stand-ins (csr / coo /
        dense have no Pallas kernel by design)."""
        return None

    def pack(self, a, *, params: DtansParams = PAPER,
             artifacts: dict | None = None, **knobs):
        """Packed, runnable artifact for matrix ``a``."""
        raise NotImplementedError(f"{self.name}: pack")

    def runner(self, packed, x, *, interpret: bool = True):
        """Zero-arg callable computing ``y = A x`` from `pack`'s
        artifact (feed it to `repro.autotune.measure.time_kernel`)."""
        fn = self.spmv_fn
        if fn is None:
            raise NotImplementedError(f"{self.name}: runner")
        return lambda: fn(packed, x, interpret=interpret)

    def spmv(self, a, x, *, params: DtansParams = PAPER,
             interpret: bool = True, **knobs):
        """One-shot ``y = A x`` through the registered kernel path —
        how the conformance suite drives every format."""
        packed = self.pack(a, params=params, **knobs)
        return self.runner(packed, x, interpret=interpret)()

    # -- multi-RHS (SpMM) --------------------------------------------

    @property
    def spmm_fn(self):
        """The public multi-RHS ``repro.kernels.ops`` entry point
        (``X: (n, B)`` -> ``Y: (m, B)``), or None when the format has
        no fused SpMM kernel — `spmm_runner` then falls back to one
        `runner` call per column, so EVERY registered format exposes a
        batched path (third-party specs included) and gains the fused
        kernel by overriding only this property."""
        return None

    def spmm_runner(self, packed, x, *, interpret: bool = True,
                    bn=None, tile_mode: str = "auto",
                    pipeline: bool = False):
        """Zero-arg callable computing ``Y = A X`` (``X: (n, B)``) from
        `pack`'s artifact — the batched analogue of `runner`, driven by
        the timing harness (``measure.spmv_runner(batch=B)``), the
        conformance suite and serving.

        ``bn`` / ``tile_mode`` column-tile the RHS through the kernel
        entry point (`repro.kernels.tiling`) and ``pipeline``
        double-buffers the entropy decode — kernel-backed families
        only.  The per-column fallback ignores ``bn`` (a column loop is
        already maximally tiled) and rejects ``pipeline`` for formats
        with nothing to decode, so third-party specs join unchanged."""
        fn = self.spmm_fn
        if pipeline and not self.decodes:
            raise ValueError(f"{self.name}: pipeline= only applies to "
                             "entropy-decoding formats")
        if fn is not None:
            kw = {}
            if bn is not None:
                kw["bn"] = bn
            if tile_mode != "auto":
                kw["tile_mode"] = tile_mode
            if pipeline:
                kw["pipeline"] = True
            return lambda: fn(packed, x, interpret=interpret, **kw)
        x2 = np.asarray(x)
        if x2.ndim != 2:
            raise ValueError(f"{self.name}: spmm_runner expects x of "
                             f"shape (n, B); got {x2.shape}")
        runners = [self.runner(packed, x2[:, b], interpret=interpret)
                   for b in range(x2.shape[1])]
        import jax.numpy as jnp
        return lambda: jnp.stack([jnp.asarray(r()) for r in runners],
                                 axis=-1)

    def spmm(self, a, x, *, params: DtansParams = PAPER,
             interpret: bool = True, bn=None, tile_mode: str = "auto",
             pipeline: bool = False, **knobs):
        """One-shot ``Y = A X`` through the registered batched kernel
        path — how the conformance suite sweeps every format over B
        (and, with ``bn`` / ``pipeline``, over the tiled and pipelined
        schedules, pinned bit-identical to the plain kernel)."""
        packed = self.pack(a, params=params, **knobs)
        return self.spmm_runner(packed, x, interpret=interpret, bn=bn,
                                tile_mode=tile_mode,
                                pipeline=pipeline)()

    # -- sharding (multi-device row partition) -----------------------

    def shard_unit(self, knobs: dict | None = None) -> int:
        """Row alignment of a shard boundary: the height of the
        format's independent row unit (decode slice / group / block
        row).  Slices never straddle shards, so `shard` cuts only at
        multiples of this.  Default: the encoded interleave width for
        the ``decodes=True`` families, 1 (any row) otherwise."""
        return int(self.interleave_width(knobs) or 1)

    def shard(self, a, n_shards: int, *, params: DtansParams = PAPER,
              artifacts: dict | None = None, **knobs):
        """Row-partition matrix ``a`` into an ``n_shards``-way
        `repro.sparse.shard.ShardPlan` — the registry-generic seam
        (same pattern as `spmm_runner`): boundaries at `shard_unit`
        multiples, each row block packed through this family's own
        `pack`, per-shard sizes exact via `nbytes_constructed`.  A
        third-party spec that implements the single-device contract
        shards for free.

        ``artifacts`` memoizes each shard's expensive constructed
        artifact under ``artifact_key + (n_shards, k)`` — one mapping
        shared with the oracle / refinement convention."""
        from repro.sparse.shard import ShardPlan, csr_row_block, \
            shard_boundaries
        kn = self._knobs(knobs)
        unit = self.shard_unit(kn)
        bounds = shard_boundaries(a.shape[0], n_shards, unit)
        arts = artifacts if artifacts is not None else {}
        shards = []
        sizes = []
        for k in range(n_shards):
            sub = csr_row_block(a, bounds[k], bounds[k + 1])
            key = self.artifact_key(kn) + ("shard", n_shards, k)
            sub_arts = arts.setdefault(key, {})
            shards.append(self.pack(sub, params=params,
                                    artifacts=sub_arts, **kn))
            sizes.append(int(self.nbytes_constructed(
                sub, params=params, artifacts=sub_arts, **kn)))
        return ShardPlan(fmt=self.name,
                         knobs=tuple((k, kn[k]) for k in
                                     self.knob_domains),
                         n_shards=int(n_shards), unit=unit,
                         boundaries=bounds, shards=tuple(shards),
                         shard_nbytes=tuple(sizes), shape=a.shape,
                         dtype=np.dtype(a.values.dtype))

    def shard_runner(self, plan, x, *, mesh=None,
                     interpret: bool = True, bn=None,
                     tile_mode: str = "auto", pipeline: bool = False):
        """Zero-arg callable computing ``y = A x`` (1-D ``x``) or
        ``Y = A X`` (2-D ``x``) from a `shard` plan — the sharded
        analogue of `runner` / `spmm_runner`.  With a ``mesh`` whose
        ``model`` axis matches ``plan.n_shards``, kernel-backed
        families run under `jax.shard_map` (each device decodes only
        its shard, partial y's reduce via psum); otherwise — and for
        packed artifacts without a registered shard_map adapter — a
        sequential per-shard loop through this family's single-device
        runners, so EVERY registered format (third-party specs
        included) has a sharded path."""
        from repro.kernels import shard_ops
        x2 = np.asarray(x)
        if x2.ndim == 1:
            return lambda: shard_ops.shard_spmv(plan, x, mesh=mesh,
                                                interpret=interpret,
                                                pipeline=pipeline)
        return lambda: shard_ops.shard_spmm(plan, x, mesh=mesh,
                                            interpret=interpret,
                                            bn=bn, tile_mode=tile_mode,
                                            pipeline=pipeline)

    # -- encoded artifact (decodes=True formats) ---------------------

    def encode(self, a, *, params: DtansParams = PAPER, **knobs):
        """Storable entropy-coded artifact (serving's build path)."""
        raise TypeError(f"format {self.name!r} is not entropy-coded")

    # -- candidates --------------------------------------------------

    def candidates(self, fp, overrides: dict | None = None, *,
                   params: DtansParams = PAPER
                   ) -> list[tuple[dict, int, bool]]:
        """``(knobs, nbytes, exact_size)`` per sweep point — what the
        cost model prices and the oracle refines."""
        out = []
        for knobs in self.knob_grid(fp, overrides):
            b = self.nbytes_exact(fp, **knobs)
            if b is None:
                out.append((knobs,
                            int(self.nbytes_estimate(fp, params=params,
                                                     **knobs)), False))
            else:
                out.append((knobs, int(b), True))
        return out


class KnobbedConfigMixin:
    """Accessors shared by the dataclasses that carry a ``(fmt,
    knobs)`` configuration (`repro.autotune.cost_model.Candidate`,
    `repro.autotune.search.Decision`): one implementation of the
    config-name rendering and the per-knob convenience properties, so
    the two can never drift apart. Expects ``self.fmt: str`` and
    ``self.knobs: tuple[(name, value), ...]``."""

    def knobs_dict(self) -> dict:
        return dict(self.knobs)

    @property
    def config_name(self) -> str:
        return get_format(self.fmt).encode_knobs(self.knobs_dict())

    @property
    def lane_width(self) -> int | None:
        """Interleave width of the encoded artifact for the dtANS
        family (== group size / block height for the aligned variants);
        None for plain formats."""
        kn = self.knobs_dict()
        if "lane_width" in kn:
            return kn["lane_width"]
        return get_format(self.fmt).interleave_width(kn)

    @property
    def shared_table(self) -> bool | None:
        return self.knobs_dict().get("shared_table")

    @property
    def group_size(self) -> int | None:
        return self.knobs_dict().get("group_size")

    @property
    def block_shape(self) -> tuple | None:
        return self.knobs_dict().get("block_shape")


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, FormatSpec] = {}


def register(spec: FormatSpec, *, replace: bool = False) -> FormatSpec:
    """Make ``spec`` visible to every registry consumer."""
    if not spec.name:
        raise ValueError("FormatSpec.name must be set")
    if spec.name in _REGISTRY and not replace:
        raise ValueError(f"format {spec.name!r} already registered "
                         f"(pass replace=True to override)")
    _REGISTRY[spec.name] = spec
    return spec


def unregister(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_format(name: str) -> FormatSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown format {name!r} "
                         f"(registered: {sorted(_REGISTRY)})") from None


def format_names(*, selectable: bool | None = None,
                 decodes: bool | None = None) -> tuple[str, ...]:
    """Registered family names, registration order, optionally filtered."""
    return tuple(s.name for s in iter_formats(selectable=selectable,
                                              decodes=decodes))


def iter_formats(*, selectable: bool | None = None,
                 decodes: bool | None = None) -> tuple[FormatSpec, ...]:
    return tuple(s for s in _REGISTRY.values()
                 if (selectable is None or s.selectable == selectable)
                 and (decodes is None or s.decodes == decodes))


def parse_config(config_name: str) -> tuple[FormatSpec, dict]:
    """Canonical config string -> (spec, spelled knobs)."""
    fmt = config_name.partition("[")[0]
    spec = get_format(fmt)
    return spec, spec.decode_knobs(config_name)


# --------------------------------------------------------------------------
# Built-in formats
# --------------------------------------------------------------------------


class DenseSpec(FormatSpec):
    """Dense ``A @ x`` — calibration's bandwidth anchor, never a sparse
    candidate."""

    name = "dense"
    selectable = False

    def nbytes_exact(self, fp, **knobs) -> int:
        return int(fp.rows) * int(fp.cols) * int(fp.value_bytes)

    def nbytes_constructed(self, a, *, params=PAPER, artifacts=None,
                           **knobs) -> int:
        m, n = a.shape
        return m * n * a.values.dtype.itemsize

    def cost_terms(self, fp, **knobs) -> CostTerms:
        return CostTerms(lockstep=float(fp.rows) * float(fp.cols))

    def pack(self, a, *, params=PAPER, artifacts=None, **knobs):
        return a.to_dense()

    def runner(self, packed, x, *, interpret: bool = True):
        import jax
        import jax.numpy as jnp
        d = jnp.asarray(packed)
        xj = jnp.asarray(x, dtype=d.dtype)
        return jax.jit(lambda: d @ xj)

    def spmm_runner(self, packed, x, *, interpret: bool = True,
                    bn=None, tile_mode: str = "auto",
                    pipeline: bool = False):
        # Dense ``A @ X`` is the same contraction for any number of
        # right-hand sides — the single-vector runner already is the
        # batched bandwidth anchor.  XLA tiles the contraction itself,
        # so the tile knobs are accepted and ignored.
        return self.runner(packed, x, interpret=interpret)


class _RowSeqSpec(FormatSpec):
    """Shared machinery of the row-sequential baselines (csr / coo).

    There is no Pallas kernel for them (the paper abandons row-
    sequential SpMV on GPUs for the reason the cost model charges
    ``row_seq_penalty``); the measurable stand-in is the XLA
    scatter-add SpMV both formats lower to.
    """

    def cost_terms(self, fp, **knobs) -> CostTerms:
        return CostTerms(rowseq=float(fp.nnz))

    def pack(self, a, *, params=PAPER, artifacts=None, **knobs):
        return a

    def runner(self, packed, x, *, interpret: bool = True):
        import jax
        import jax.numpy as jnp
        a = packed
        m = a.shape[0]
        rows = jnp.asarray(np.repeat(np.arange(m, dtype=np.int64),
                                     np.diff(a.indptr)))
        idx = jnp.asarray(a.indices)
        vals = jnp.asarray(a.values)
        xj = jnp.asarray(x, dtype=a.values.dtype)

        @jax.jit
        def run():
            return jnp.zeros(m, vals.dtype).at[rows].add(vals * xj[idx])

        return run

    def spmm_runner(self, packed, x, *, interpret: bool = True,
                    bn=None, tile_mode: str = "auto",
                    pipeline: bool = False):
        # Batched scatter-add stand-in: one (m, B) accumulator, the
        # same row scatter, every RHS column updated per nonzero.
        # Tile knobs accepted and ignored (XLA-lowered, no VMEM grid).
        import jax
        import jax.numpy as jnp
        a = packed
        m = a.shape[0]
        rows = jnp.asarray(np.repeat(np.arange(m, dtype=np.int64),
                                     np.diff(a.indptr)))
        idx = jnp.asarray(a.indices)
        vals = jnp.asarray(a.values)
        xj = jnp.asarray(x, dtype=a.values.dtype)

        @jax.jit
        def run():
            return jnp.zeros((m, xj.shape[1]), vals.dtype
                             ).at[rows].add(vals[:, None] * xj[idx, :])

        return run


class CsrSpec(_RowSeqSpec):
    name = "csr"

    def nbytes_exact(self, fp, **knobs) -> int:
        return fp.nnz * (4 + fp.value_bytes) + (fp.rows + 1) * 4

    def nbytes_constructed(self, a, *, params=PAPER, artifacts=None,
                           **knobs) -> int:
        return a.nbytes


class CooSpec(_RowSeqSpec):
    name = "coo"

    def nbytes_exact(self, fp, **knobs) -> int:
        return fp.nnz * (8 + fp.value_bytes)

    def nbytes_constructed(self, a, *, params=PAPER, artifacts=None,
                           **knobs) -> int:
        from repro.sparse.formats import COO
        return COO.from_csr(a).nbytes


class SellSpec(FormatSpec):
    name = "sell"
    knob_domains = {"slice_height": (32,)}
    conformance_knobs = {"slice_height": 16}

    def nbytes_exact(self, fp, *, slice_height=32) -> int:
        nslices = -(-fp.rows // slice_height) if fp.rows else 0
        return (fp.lockstep(slice_height) * (4 + fp.value_bytes)
                + (nslices + 1) * 4)

    def nbytes_constructed(self, a, *, params=PAPER, artifacts=None,
                           slice_height=32) -> int:
        from repro.sparse.formats import SELL
        return SELL.from_csr(a, slice_height=slice_height).nbytes

    def cost_terms(self, fp, *, slice_height=32) -> CostTerms:
        return CostTerms(lockstep=float(fp.lockstep(slice_height)))

    @property
    def spmv_fn(self):
        from repro.kernels import ops
        return ops.sell_spmv

    @property
    def spmm_fn(self):
        from repro.kernels import ops
        return ops.sell_spmm

    def shard_unit(self, knobs=None) -> int:
        return int(self._knobs(knobs or {})["slice_height"])

    def pack(self, a, *, params=PAPER, artifacts=None, slice_height=32):
        from repro.kernels.sell_spmv import pack_sell
        return pack_sell(a, lane_width=int(slice_height))


class RgcsrSpec(FormatSpec):
    name = "rgcsr"
    named_knobs = ("group_size",)
    conformance_knobs = {"group_size": 8}

    @property
    def knob_domains(self):
        from repro.sparse.rgcsr import RGCSR_GROUP_SIZES
        return {"group_size": RGCSR_GROUP_SIZES}

    def nbytes_exact(self, fp, *, group_size=4) -> int:
        from repro.sparse.rgcsr import local_indptr_bytes
        G = int(group_size)
        ngroups = -(-fp.rows // G) if fp.rows else 0
        lb = local_indptr_bytes(fp.group_max_nnz(G))
        return (fp.nnz * (4 + fp.value_bytes) + ngroups * (G + 1) * lb
                + (ngroups + 1) * 4)

    def nbytes_constructed(self, a, *, params=PAPER, artifacts=None,
                           group_size=4) -> int:
        from repro.sparse.rgcsr import rgcsr_nbytes_exact
        return rgcsr_nbytes_exact(a.row_nnz(), group_size,
                                  a.values.dtype.itemsize)

    def cost_terms(self, fp, *, group_size=4) -> CostTerms:
        return CostTerms(lockstep=float(fp.lockstep(group_size)))

    @property
    def spmv_fn(self):
        from repro.kernels import ops
        return ops.rgcsr_spmv

    @property
    def spmm_fn(self):
        from repro.kernels import ops
        return ops.rgcsr_spmm

    def shard_unit(self, knobs=None) -> int:
        return int(self._knobs(knobs or {})["group_size"])

    def pack(self, a, *, params=PAPER, artifacts=None, group_size=4):
        from repro.kernels.rgcsr_spmv import pack_rgcsr
        from repro.sparse.rgcsr import RGCSR
        return pack_rgcsr(RGCSR.from_csr(a, int(group_size)))


class _DtansFamilySpec(FormatSpec):
    """Shared machinery of the entropy-coded families: artifact-
    memoized encodes, `ops.spmv` runners, serving `encode`."""

    decodes = True

    def _encode(self, a, *, params: DtansParams, **knobs):
        raise NotImplementedError

    def encode(self, a, *, params: DtansParams = PAPER, **knobs):
        return self._encode(a, params=params, **self._knobs(knobs))

    def _artifact(self, a, *, params: DtansParams,
                  artifacts: dict | None, **knobs):
        kn = self._knobs(knobs)
        enc = artifacts if artifacts is not None else {}
        key = self.artifact_key(kn)
        mat = enc.get(key)
        if not hasattr(mat, "nbytes"):       # miss or legacy int entry
            mat = self._encode(a, params=params, **kn)
            enc[key] = mat
        return mat

    def nbytes_constructed(self, a, *, params=PAPER, artifacts=None,
                           **knobs) -> int:
        return int(self._artifact(a, params=params, artifacts=artifacts,
                                  **knobs).nbytes)

    @property
    def spmv_fn(self):
        from repro.kernels import ops
        return ops.spmv

    @property
    def spmm_fn(self):
        from repro.kernels import ops
        return ops.spmm

    def pack(self, a, *, params=PAPER, artifacts=None, **knobs):
        from repro.kernels import ops
        # get_packed caches the pack on the encoded object, so repeat
        # measurements of a memoized artifact never re-pack.
        return ops.get_packed(self._artifact(a, params=params,
                                             artifacts=artifacts,
                                             **knobs))


class DtansSpec(_DtansFamilySpec):
    name = "dtans"
    knob_domains = {"lane_width": DTANS_LANE_WIDTHS,
                    "shared_table": DTANS_SHARED_TABLE}
    named_knobs = ("lane_width", "shared_table")
    conformance_knobs = {"lane_width": 16}

    def interleave_width(self, knobs=None):
        return int(self._knobs(knobs or {})["lane_width"])

    def nbytes_estimate(self, fp, *, params=PAPER, lane_width=32,
                        shared_table=True) -> int:
        from repro.autotune.cost_model import dtans_nbytes_estimate
        return dtans_nbytes_estimate(fp, lane_width=lane_width,
                                     shared_table=shared_table,
                                     params=params)

    def cost_terms(self, fp, *, lane_width=32,
                   shared_table=True) -> CostTerms:
        w = float(fp.lockstep(lane_width))
        return CostTerms(lockstep=w, decode=w)

    def _encode(self, a, *, params, lane_width, shared_table):
        from repro.core.csr_dtans import encode_matrix
        return encode_matrix(a, params=params, lane_width=int(lane_width),
                             shared_table=bool(shared_table))


class RgcsrDtansSpec(_DtansFamilySpec):
    name = "rgcsr_dtans"
    named_knobs = ("group_size", "shared_table")
    conformance_knobs = {"group_size": 8}

    @property
    def knob_domains(self):
        from repro.sparse.rgcsr import RGCSR_GROUP_SIZES
        # Shared table only in the default sweep: the group sweep
        # already multiplies the candidate set, and split tables never
        # paid off at narrow interleave widths (table bytes double,
        # stream bits do not).
        return {"group_size": RGCSR_GROUP_SIZES,
                "shared_table": (True,)}

    def interleave_width(self, knobs=None):
        return int(self._knobs(knobs or {})["group_size"])

    def nbytes_estimate(self, fp, *, params=PAPER, group_size=4,
                        shared_table=True) -> int:
        from repro.autotune.cost_model import rgcsr_dtans_nbytes_estimate
        return rgcsr_dtans_nbytes_estimate(fp, group_size=group_size,
                                           shared_table=shared_table,
                                           params=params)

    def cost_terms(self, fp, *, group_size=4,
                   shared_table=True) -> CostTerms:
        w = float(fp.lockstep(group_size))
        return CostTerms(lockstep=w, decode=w)

    def _encode(self, a, *, params, group_size, shared_table):
        from repro.core.rgcsr_dtans import encode_rgcsr_matrix
        return encode_rgcsr_matrix(a, group_size=int(group_size),
                                   params=params,
                                   shared_table=bool(shared_table))


def block_count(fp, block_shape) -> tuple[int, bool]:
    """(nonempty r x c blocks, exact?) from a fingerprint — exact for
    any shape via the fingerprint's lazily-derived block-fill feature;
    worst case one block per nonzero only for hand-built fingerprints
    without stashed CSR structure. THE single fallback policy for both
    blocked specs' sizing, cost terms and admit guard."""
    nb = fp.block_nonempty(tuple(block_shape))
    if nb is not None:
        return int(nb), True
    return int(fp.nnz), False


class BcsrSpec(FormatSpec):
    """Blocked CSR (`repro.sparse.bcsr`) — registered purely through
    this module: no dispatch site anywhere names it."""

    name = "bcsr"
    named_knobs = ("block_shape",)
    conformance_knobs = {"block_shape": (4, 4)}

    @property
    def knob_domains(self):
        from repro.sparse.bcsr import BCSR_BLOCK_SHAPES
        return {"block_shape": BCSR_BLOCK_SHAPES}

    def nbytes_exact(self, fp, *, block_shape=(2, 2)) -> int | None:
        from repro.sparse.bcsr import bcsr_nbytes_exact
        nb, exact = block_count(fp, block_shape)
        if not exact:
            return None
        return bcsr_nbytes_exact(nb, fp.rows, tuple(block_shape),
                                 fp.value_bytes)

    def nbytes_estimate(self, fp, *, params=PAPER,
                        block_shape=(2, 2)) -> int:
        from repro.sparse.bcsr import bcsr_nbytes_exact
        nb, _ = block_count(fp, block_shape)
        return bcsr_nbytes_exact(nb, fp.rows, tuple(block_shape),
                                 fp.value_bytes)

    def nbytes_constructed(self, a, *, params=PAPER, artifacts=None,
                           block_shape=(2, 2)) -> int:
        from repro.sparse.bcsr import (bcsr_nbytes_exact,
                                       count_nonempty_blocks)
        nb = count_nonempty_blocks(a.indptr, a.indices, a.shape,
                                   tuple(block_shape))
        return bcsr_nbytes_exact(nb, a.shape[0], tuple(block_shape),
                                 a.values.dtype.itemsize)

    def cost_terms(self, fp, *, block_shape=(2, 2)) -> CostTerms:
        r, c = block_shape
        nb, _ = block_count(fp, block_shape)
        return CostTerms(lockstep=float(nb * r * c))

    @property
    def spmv_fn(self):
        from repro.kernels import ops
        return ops.bcsr_spmv

    @property
    def spmm_fn(self):
        from repro.kernels import ops
        return ops.bcsr_spmm

    def shard_unit(self, knobs=None) -> int:
        return int(self._knobs(knobs or {})["block_shape"][0])

    def pack(self, a, *, params=PAPER, artifacts=None,
             block_shape=(2, 2)):
        from repro.kernels.bcsr_spmv import pack_bcsr
        from repro.sparse.bcsr import BCSR
        return pack_bcsr(BCSR.from_csr(a, tuple(block_shape)))


class BcsrDtansSpec(_DtansFamilySpec):
    """dtANS entropy coding over the blocked index layout — the
    existing decode machinery composing with a new `FormatSpec`, zero
    kernel changes (`BCSRdtANS` IS a `CSRdtANS`)."""

    name = "bcsr_dtans"
    named_knobs = ("block_shape", "shared_table")
    conformance_knobs = {"block_shape": (2, 2)}

    @property
    def knob_domains(self):
        from repro.sparse.bcsr import BCSR_BLOCK_SHAPES
        return {"block_shape": BCSR_BLOCK_SHAPES,
                "shared_table": (True,)}

    def interleave_width(self, knobs=None):
        return int(self._knobs(knobs or {})["block_shape"][0])

    def admit(self, fp, knobs) -> bool:
        """Skip block layouts whose fill-in dwarfs the nonzeros: the
        stream cannot win, and the oracle would pay a full encode of
        ``fill x nnz`` symbols to prove it. When the block count is not
        exactly known (a hand-built fingerprint without stashed
        structure), admit — the worst-case fallback count would veto
        every shape >= 2x2 regardless of the actual block structure,
        and the estimate-then-refine path can still decide."""
        r, c = knobs["block_shape"]
        blocks, exact = block_count(fp, knobs["block_shape"])
        if not exact:
            return True
        return blocks * r * c / max(fp.nnz, 1) <= BCSR_DTANS_MAX_FILL

    def nbytes_estimate(self, fp, *, params=PAPER, block_shape=(2, 2),
                        shared_table=True) -> int:
        from repro.autotune.cost_model import bcsr_dtans_nbytes_estimate
        return bcsr_dtans_nbytes_estimate(fp, block_shape=block_shape,
                                          shared_table=shared_table,
                                          params=params)

    def cost_terms(self, fp, *, block_shape=(2, 2),
                   shared_table=True) -> CostTerms:
        r, c = block_shape
        blocks, _ = block_count(fp, block_shape)
        w = float(blocks * r * c)
        return CostTerms(lockstep=w, decode=w)

    def _encode(self, a, *, params, block_shape, shared_table):
        from repro.core.bcsr_dtans import encode_bcsr_matrix
        return encode_bcsr_matrix(a, block_shape=tuple(block_shape),
                                  params=params,
                                  shared_table=bool(shared_table))


for _spec in (DenseSpec(), CsrSpec(), CooSpec(), SellSpec(),
              RgcsrSpec(), DtansSpec(), RgcsrDtansSpec(),
              BcsrSpec(), BcsrDtansSpec()):
    register(_spec)
del _spec
