"""Row-grouped CSR (RGCSR): CSR with rows partitioned into fixed groups.

Rows are partitioned into groups of ``group_size`` (G) consecutive rows.
Each group stores its rows' column indices as per-row *delta* streams
(same delta code as `repro.core.delta`, the front half of the CSR-dtANS
pipeline) and a *group-local* indptr whose entries are offsets relative
to the group start. Because a group holds at most G rows, the local
offsets fit in 16-bit integers whenever no group exceeds 65535 stored
entries — halving CSR's per-row pointer cost — and a lock-step kernel
processing one group per program runs each group only to its own longest
row, so skewed row-length distributions do not pay SELL's global-slice
padding in *bytes* (only in per-group compute).

The layout follows two row-grouping formats from the literature:

* Oberhuber, Suzuki, Vacata, "New Row-grouped CSR format for storing
  the sparse matrices on GPU with implementation in CUDA" (2011):
  rows -> fixed groups, per-group offsets, one thread-group per group.
* Koza, Matyka, Szkoda, Miroslaw, "Compressed Multi-Row Storage Format
  for Sparse Matrices on Graphics Processing Units" (CMRS, 2012):
  group-local pointers narrow enough for fast on-chip arithmetic.

Field map onto the paper's Fig. 2 CSR notation (indptr / indices /
values): ``group_ptr[g]`` plays indptr's role at group granularity
(absolute offset of group g's first stored entry); ``local_indptr``
refines it to rows within the group (indptr[i] == group_ptr[i // G] +
local_indptr[i % G] for row i); ``delta_indices`` carries indices
delta-encoded per row (d_0 = c_0, d_k = c_k - c_{k-1}, Section IV-A);
``values`` is unchanged.

Byte-exact accounting (`nbytes`) mirrors `formats.CSR`: 32-bit column
deltas, 32/64-bit values, 32-bit group pointers, and 16- or 32-bit
group-local indptr entries (16 whenever every group's nnz < 2**16).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delta import delta_decode_rows, delta_encode_rows
from repro.sparse.formats import CSR

#: Group sizes swept by the autotuner (`repro.autotune`), paper-Fig. 9
#: style: small groups localize row-length skew, large groups amortize
#: the per-group pointer overhead.
RGCSR_GROUP_SIZES = (4, 8, 16, 32)


def local_indptr_bytes(max_group_nnz: int) -> int:
    """Width of one group-local indptr entry: 2 bytes unless some group
    holds 2**16 or more stored entries."""
    return 2 if max_group_nnz < (1 << 16) else 4


def max_group_nnz(row_nnz: np.ndarray, group_size: int) -> int:
    """Largest total nnz in any group of ``group_size`` consecutive rows
    (decides the 16- vs 32-bit local indptr width). Shared by the format
    accounting below and `repro.autotune.fingerprint`, so the selector's
    'exact' sizes cannot drift from the format's own."""
    row_nnz = np.asarray(row_nnz, dtype=np.int64)
    m = int(row_nnz.size)
    if m == 0:
        return 0
    ng = (m + group_size - 1) // group_size
    padded = np.zeros(ng * group_size, dtype=np.int64)
    padded[:m] = row_nnz
    return int(padded.reshape(ng, group_size).sum(axis=1).max())


@dataclasses.dataclass
class RGCSR:
    """Row-grouped CSR with per-row delta-coded column indices."""

    group_size: int
    group_ptr: np.ndarray      # (ngroups+1,) absolute offsets (4 B each)
    local_indptr: np.ndarray   # (ngroups, G+1) group-local offsets
    delta_indices: np.ndarray  # (nnz,) per-row column deltas (4 B each)
    values: np.ndarray         # (nnz,) float32/float64
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.values.size)

    @property
    def n_groups(self) -> int:
        return int(self.group_ptr.size - 1)

    @property
    def max_group_nnz(self) -> int:
        return int(np.diff(self.group_ptr).max()) if self.n_groups else 0

    @property
    def nbytes(self) -> int:
        vb = self.values.dtype.itemsize
        lb = local_indptr_bytes(self.max_group_nnz)
        return (self.nnz * (4 + vb)
                + self.local_indptr.size * lb
                + (self.n_groups + 1) * 4)

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.local_indptr, axis=1).reshape(-1)[:self.shape[0]]

    @classmethod
    def from_csr(cls, a: CSR, group_size: int = 32) -> "RGCSR":
        if group_size < 1:
            raise ValueError(f"group_size must be >= 1, got {group_size}")
        m, _ = a.shape
        G = group_size
        ngroups = (m + G - 1) // G
        rnnz = np.zeros(ngroups * G, dtype=np.int64)
        rnnz[:m] = np.diff(a.indptr)
        per_group = rnnz.reshape(ngroups, G)
        local = np.zeros((ngroups, G + 1), dtype=np.int64)
        local[:, 1:] = np.cumsum(per_group, axis=1)
        group_ptr = np.zeros(ngroups + 1, dtype=np.int64)
        group_ptr[1:] = np.cumsum(local[:, -1])
        return cls(group_size=G, group_ptr=group_ptr, local_indptr=local,
                   delta_indices=delta_encode_rows(a.indptr, a.indices),
                   values=a.values.copy(), shape=a.shape)

    def to_csr(self) -> CSR:
        m, _ = self.shape
        indptr = (self.group_ptr[:-1, None]
                  + self.local_indptr[:, :-1]).reshape(-1)[:m]
        indptr = np.concatenate([indptr, self.group_ptr[-1:]])
        indices = delta_decode_rows(indptr, self.delta_indices)
        return CSR(indptr=indptr.astype(np.int64), indices=indices,
                   values=self.values.copy(), shape=self.shape)

    def to_dense(self) -> np.ndarray:
        return self.to_csr().to_dense()

    def spmv(self, x: np.ndarray, y: np.ndarray | None = None) -> np.ndarray:
        """Reference y = A x + y running the group-local layout directly
        (local indptr + delta prefix-sum), not via `to_csr`."""
        m, n = self.shape
        out = (np.zeros(m, dtype=self.values.dtype) if y is None
               else y.astype(self.values.dtype).copy())
        G = self.group_size
        for g in range(self.n_groups):
            base = int(self.group_ptr[g])
            for i in range(G):
                row = g * G + i
                if row >= m:
                    break
                lo = base + int(self.local_indptr[g, i])
                hi = base + int(self.local_indptr[g, i + 1])
                if hi == lo:
                    continue
                cols = np.cumsum(self.delta_indices[lo:hi])
                out[row] += self.values[lo:hi] @ x[cols]
        return out


def rgcsr_nbytes_exact(row_nnz: np.ndarray, group_size: int,
                       value_bytes: int) -> int:
    """`RGCSR.nbytes` from a row-nnz histogram alone (no construction).

    Single source of truth shared with `repro.autotune.cost_model` so the
    selector's "exact" sizes can never drift from the format's own
    accounting (asserted in tests/test_rgcsr.py).
    """
    row_nnz = np.asarray(row_nnz, dtype=np.int64)
    m = int(row_nnz.size)
    G = int(group_size)
    ngroups = (m + G - 1) // G
    nnz = int(row_nnz.sum())
    lb = local_indptr_bytes(max_group_nnz(row_nnz, G))
    return nnz * (4 + value_bytes) + ngroups * (G + 1) * lb \
        + (ngroups + 1) * 4
