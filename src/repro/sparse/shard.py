"""Row-partition a sparse matrix into per-device shards along decode-
slice boundaries (ROADMAP item 2; the distributed analogue of the
paper's independent decode slices).

Every format family in `repro.sparse.registry` packs its matrix as a
sequence of independent row units — dtANS decode slices of
``lane_width`` rows, RGCSR groups of ``group_size`` rows, BCSR block
rows of ``r`` rows, SELL slices of ``slice_height`` rows (plain CSR /
COO / dense have unit 1).  A shard plan splits the ROW range at
multiples of that unit, so no decode slice / group / block row ever
straddles two shards and each shard's packed artifact is exactly what
the single-device kernel would build for that row block:

    shard k owns rows [boundaries[k], boundaries[k+1])

`FormatSpec.shard` (the registry seam) builds the plan: it slices the
CSR (`csr_row_block`), packs each row block through the family's own
`FormatSpec.pack`, and records exact per-shard byte counts via
`FormatSpec.nbytes_constructed` — the numbers the sharded cost terms
(`repro.autotune.cost_model.candidate_time(n_shards=)`) price and obs
reports.  Because entropy decode is lossless and each row accumulates
its dot product in column order regardless of its neighbours or its
coding tables, a shard's kernel output is bit-identical to the same
rows of the single-device kernel output — the conformance suite pins
this at shards in {1, 2, 4} for every registered format.

This module holds only the layout (plan dataclass + boundary/slicing
helpers); execution lives in `repro.kernels.shard_ops` (`shard_map`
over the mesh ``model`` axis, or a sequential per-shard loop when no
mesh is given).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.formats import CSR


def shard_boundaries(m: int, n_shards: int, unit: int = 1) -> tuple:
    """Row boundaries of a balanced ``n_shards``-way split of ``m`` rows,
    every boundary a multiple of ``unit`` (the format's decode-slice /
    group / block-row height) so no unit straddles two shards.

    Balances whole units, not raw rows: ``ceil(m / unit)`` units are
    spread as evenly as possible (first ``n_units % n_shards`` shards
    get one extra).  Shards past the unit count are empty (zero rows) —
    legal, they contribute zeros to the reduction.  Returns a tuple of
    ``n_shards + 1`` ints, ``boundaries[0] == 0``,
    ``boundaries[-1] == m``.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1; got {n_shards}")
    if unit < 1:
        raise ValueError(f"shard unit must be >= 1; got {unit}")
    n_units = -(-m // unit) if m else 0
    base, extra = divmod(n_units, n_shards)
    bounds = [0]
    for k in range(n_shards):
        units_k = base + (1 if k < extra else 0)
        bounds.append(min(bounds[-1] + units_k * unit, m))
    bounds[-1] = m
    return tuple(bounds)


def csr_row_block(a: CSR, r0: int, r1: int) -> CSR:
    """The CSR sub-matrix of rows ``[r0, r1)`` (all columns kept — the
    shard contracts against the full broadcast x)."""
    if not (0 <= r0 <= r1 <= a.shape[0]):
        raise ValueError(f"row block [{r0}, {r1}) out of range for "
                         f"{a.shape[0]} rows")
    lo, hi = int(a.indptr[r0]), int(a.indptr[r1])
    return CSR(indptr=np.asarray(a.indptr[r0:r1 + 1]) - lo,
               indices=a.indices[lo:hi],
               values=a.values[lo:hi],
               shape=(r1 - r0, a.shape[1]))


@dataclasses.dataclass
class ShardPlan:
    """One format's row partition of one matrix across ``n_shards``
    devices: per-shard packed artifacts plus exact per-shard sizes.

    Built by `repro.sparse.registry.FormatSpec.shard`; executed by
    `repro.kernels.shard_ops.shard_spmv` / `shard_spmm`.  ``shards[k]``
    is the family's `pack` product for rows
    ``[boundaries[k], boundaries[k+1])``; empty shards hold the pack of
    a zero-row matrix and contribute zeros.
    """

    fmt: str                 # registered format family
    knobs: tuple             # ((name, value), ...) configuration
    n_shards: int
    unit: int                # row alignment (decode-slice height)
    boundaries: tuple        # (n_shards + 1,) row offsets
    shards: tuple            # per-shard packed artifacts
    shard_nbytes: tuple      # exact per-shard format bytes
    shape: tuple             # (m, n) of the WHOLE matrix
    dtype: object            # value dtype

    def __post_init__(self):
        if len(self.boundaries) != self.n_shards + 1:
            raise ValueError(
                f"{self.n_shards}-shard plan needs {self.n_shards + 1} "
                f"boundaries; got {len(self.boundaries)}")
        if len(self.shards) != self.n_shards:
            raise ValueError(f"plan holds {len(self.shards)} shard "
                             f"artifacts for n_shards={self.n_shards}")

    @property
    def shard_rows(self) -> tuple:
        """Rows owned by each shard."""
        return tuple(self.boundaries[k + 1] - self.boundaries[k]
                     for k in range(self.n_shards))

    @property
    def total_nbytes(self) -> int:
        """Sum of the exact per-shard sizes (>= the unsharded artifact's
        size for the entropy formats: each shard carries its own coding
        tables — the fixed cost `candidate_time(n_shards=)` sees through
        the per-shard byte counts)."""
        return int(sum(self.shard_nbytes))

    @property
    def max_shard_nbytes(self) -> int:
        """Largest single shard — the per-device HBM the plan needs."""
        return int(max(self.shard_nbytes)) if self.shard_nbytes else 0

    def knobs_dict(self) -> dict:
        return dict(self.knobs)
