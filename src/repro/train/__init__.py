# Training substrate: trainer loop, checkpointing, elasticity.
