"""Sharded, asynchronous, atomic checkpointing.

Layout:  <dir>/step_<N>/shard_<host>.npz  +  <dir>/step_<N>/MANIFEST.json
Atomicity: writes go to  step_<N>.tmp/  and are renamed only after fsync —
a crash mid-save can never corrupt the latest-complete checkpoint.
Async: `save_async` snapshots to host memory synchronously (cheap) and
writes in a daemon thread, overlapping I/O with the next training steps.
Restore picks the newest step with a valid manifest; torn checkpoints are
skipped (fault-tolerance path tested in tests/test_train_substrate.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(step: int, tree, ckpt_dir: str, host: int = 0,
         extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrs = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    path = os.path.join(tmp, f"shard_{host}.npz")
    with open(path, "wb") as f:
        np.savez(f, **arrs)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "shapes": [list(np.asarray(x).shape) for x in leaves],
        "extra": extra or {},
    }
    mpath = os.path.join(tmp, "MANIFEST.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot-then-write-in-background; at most one write in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree, extra=None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # device -> host snapshot

        def work():
            save(step, host_tree, self.ckpt_dir, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            mpath = os.path.join(ckpt_dir, name, "MANIFEST.json")
            if os.path.exists(mpath):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def restore_latest(ckpt_dir: str, tree_like, host: int = 0):
    """Restore newest valid checkpoint into the structure of ``tree_like``.
    Returns (step, tree) or (None, None). Torn checkpoints are skipped."""
    for step in reversed(list_steps(ckpt_dir)):
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "MANIFEST.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(d, f"shard_{host}.npz"))
            leaves = [data[f"leaf_{i}"]
                      for i in range(manifest["n_leaves"])]
            treedef = jax.tree.structure(tree_like)
            if treedef.num_leaves != len(leaves):
                raise ValueError("leaf count mismatch")
            return step, jax.tree.unflatten(treedef, leaves)
        except Exception:
            continue  # torn/corrupt: try the previous one
    return None, None
