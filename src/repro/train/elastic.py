"""Elastic scaling & straggler mitigation.

On a real fleet the control plane detects node failure / slow pods and the
job must (a) continue with fewer data-parallel replicas or (b) absorb new
ones. Because every piece of run state here is either replicated (step),
deterministic-by-construction (data pipeline: batch = f(seed, step, shard))
or a pytree with named shardings (params/optimizer), elasticity reduces to
ONE operation: re-placing the state pytrees under a new mesh.

`reshard(tree, new_mesh, pspecs)` is that operation (device_put with the
new NamedShardings; XLA moves bytes). `shrink_data_axis` recomputes the
per-shard batch split — the pipeline needs no migration because shards are
stateless functions.

Straggler mitigation layers (documented design, monitor implemented in
trainer.py):
  1. per-step deadline = straggler_factor x EMA(step time); slow steps are
     recorded (Trainer.straggler_steps);
  2. at scale, the recommended policy is pod-level: a pod that misses K
     consecutive deadlines is ejected (shrink DP by one pod = this module's
     reshard with pod axis reduced) and re-admitted after health checks;
  3. checkpoint cadence bounds lost work to ckpt_every steps; the data
     pipeline replays the exact token stream after restore.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding


def reshard(tree, mesh, pspec_tree):
    """Re-place a state pytree onto ``mesh`` with matching PartitionSpecs."""
    return jax.tree.map(
        lambda x, spec: jax.device_put(x, NamedSharding(mesh, spec)),
        tree, pspec_tree)


def shrink_data_axis(global_batch: int, old_shards: int,
                     new_shards: int) -> int:
    """Per-shard batch after an elastic resize; global batch is preserved
    when divisible, otherwise rounded down to the nearest multiple."""
    if global_batch % new_shards == 0:
        return global_batch // new_shards
    return max(1, global_batch // new_shards)
