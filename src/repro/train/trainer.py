"""Training loop with microbatched gradient accumulation, fault tolerance,
and straggler monitoring.

Scale features (DESIGN.md §5):
  * gradient accumulation via `lax.scan` over microbatches — the per-chip
    peak activation memory is O(microbatch), enabling the 405B train_4k cell;
  * gradient compression (bf16 + error feedback) before the DP reduction;
  * async checkpoint every `ckpt_every` steps + restore-from-latest restart;
  * straggler monitor: per-step wall time EMA; steps slower than
    `straggler_factor` x EMA are logged (on a real fleet this signal feeds
    the pod-level replica-skip / hot-spare path, train/elastic.py).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ArchConfig
from repro.optim import make_optimizer
from repro.optim.grad_compress import compress, init_error_state
from repro.train.checkpoint import AsyncCheckpointer, restore_latest


@dataclasses.dataclass
class TrainConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    microbatches: int = 1
    acc_dtype: str = "float32"   # grad-accumulation dtype (bf16 halves
                                 # the accumulator HBM for the 405B cell)
    grad_compress: bool = False
    ckpt_every: int = 50
    ckpt_dir: str = ""
    straggler_factor: float = 3.0


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, opt):
    """Returns train_step(params, opt_state, err, batch) ->
    (params, opt_state, err, metrics). Batch leading dim is split into
    ``tcfg.microbatches`` chunks scanned with gradient accumulation."""

    def loss_of(params, mb):
        return api.loss_fn(params, cfg, mb)

    def train_step(params, opt_state, err, batch):
        n = tcfg.microbatches

        def split(x):
            b = x.shape[0]
            return x.reshape((n, b // n) + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        acc_dt = jnp.dtype(tcfg.acc_dtype)

        def acc_fn(carry, mb):
            gsum, lsum = carry
            (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(acc_dt), gsum, g)
            return (gsum, lsum + loss), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, dtype=acc_dt), params)
        (gsum, lsum), _ = jax.lax.scan(acc_fn, (g0, jnp.float32(0.0)), mbs)
        grads = jax.tree.map(
            lambda g: g.astype(jnp.float32) / n, gsum)
        if tcfg.grad_compress:
            grads, err = compress(grads, err)
        new_params, new_opt = opt.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_params, new_opt, err, {"loss": lsum / n, "gnorm": gnorm}

    return train_step


class Trainer:
    """Single-controller training driver (used by examples + launch/train)."""

    def __init__(self, cfg: ArchConfig, tcfg: TrainConfig, pipeline,
                 rng=None):
        self.cfg, self.tcfg, self.pipeline = cfg, tcfg, pipeline
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = api.init_params(cfg, rng)
        self.opt = make_optimizer(tcfg.optimizer, lr=tcfg.lr)
        self.opt_state = self.opt.init(self.params)
        self.err = (init_error_state(self.params)
                    if tcfg.grad_compress else {})
        self.step = 0
        self._step_fn = jax.jit(make_train_step(cfg, tcfg, self.opt),
                                donate_argnums=(0, 1, 2))
        self.ckpt = (AsyncCheckpointer(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)
        self._ema = None
        self.straggler_steps: list[int] = []
        self.history: list[float] = []

    # --- fault tolerance --------------------------------------------------
    def try_restore(self) -> bool:
        if not self.ckpt:
            return False
        self.ckpt.wait()   # an async save may still be in flight
        state = {"params": self.params, "opt": self.opt_state,
                 "err": self.err}
        step, tree = restore_latest(self.tcfg.ckpt_dir, state)
        if step is None:
            return False
        self.params = jax.tree.map(jnp.asarray, tree["params"])
        self.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
        self.err = jax.tree.map(jnp.asarray, tree["err"])
        self.step = step
        return True

    def run(self, num_steps: int, log_every: int = 10,
            fail_at: int | None = None) -> list[float]:
        """Train; ``fail_at`` injects a simulated crash (tests/examples)."""
        while self.step < num_steps:
            if fail_at is not None and self.step == fail_at:
                fail_at = None
                raise RuntimeError(f"injected failure at step {self.step}")
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in
                     self.pipeline.batch(self.step).items()}
            self.params, self.opt_state, self.err, metrics = self._step_fn(
                self.params, self.opt_state, self.err, batch)
            loss = float(metrics["loss"])
            self.history.append(loss)
            dt = time.time() - t0
            if self._ema is None:
                self._ema = dt
            if dt > self.tcfg.straggler_factor * self._ema:
                self.straggler_steps.append(self.step)
            self._ema = 0.9 * self._ema + 0.1 * dt
            self.step += 1
            if self.ckpt and self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save_async(self.step, {
                    "params": self.params, "opt": self.opt_state,
                    "err": self.err})
            if log_every and self.step % log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
        if self.ckpt:
            self.ckpt.wait()
        return self.history
