"""Shared test fixtures.

Multi-device meshes on a CPU host need
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set BEFORE jax
initializes its backends (the `repro.launch.mesh.make_debug_mesh`
contract: the flag lives in the test process, never globally).  conftest
imports before any test module, so setting it here covers every
collected test; an externally provided device-count flag (e.g. a CI leg
exporting its own) is respected.

The 512-device production-mesh flag stays confined to the
`test_dryrun.py` SUBPROCESS — 8 host devices is the ceiling for
in-process tests.
"""

import os

_DEVICE_FLAG = "--xla_force_host_platform_device_count=8"
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags + " " + _DEVICE_FLAG).strip()

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def make_model_mesh():
    """Factory fixture: ``make_model_mesh(k)`` returns a 1-D mesh with a
    k-device ``model`` axis (skipping if the host exposes fewer devices
    — e.g. when an external XLA_FLAGS pinned a smaller count)."""
    import jax

    from repro.launch.mesh import make_debug_mesh

    def make(k: int):
        if len(jax.devices()) < k:
            pytest.skip(f"needs {k} host devices, have "
                        f"{len(jax.devices())}")
        return make_debug_mesh((k,), ("model",))

    return make
