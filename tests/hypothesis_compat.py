"""Optional-`hypothesis` shim for the test suite.

`hypothesis` is a dev-only dependency (requirements-dev.txt). When it is
missing, the property-based tests must *skip* instead of breaking
collection of the whole module. Importing from this module gives either
the real `given`/`settings`/`st`, or stand-ins whose decorated tests call
``pytest.importorskip("hypothesis")`` at run time and therefore report as
skipped.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any `st.<name>(...)` call at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        if args and callable(args[0]) and not kwargs:
            return args[0]
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            # *args signature on purpose: pytest must not see the
            # hypothesis-provided parameters (`data=`, `seed=`, ...) and
            # go looking for fixtures with those names.
            def skipped(*a, **k):
                pytest.importorskip("hypothesis")

            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
