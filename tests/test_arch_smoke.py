"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward + one train-gradient step on CPU, asserting
output shapes and no NaNs. Full configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get, get_smoke
from repro.models import api

B, S = 2, 16


def _batch(cfg, rng):
    ks = jax.random.split(rng, 3)
    b = {
        "inputs": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.family in ("vlm", "encdec"):
        b["frontend"] = jax.random.normal(
            ks[2], (B, cfg.n_frontend_tokens, cfg.d_model),
            dtype=jnp.float32)
    return b


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    name = request.param
    cfg = get_smoke(name)
    rng = jax.random.PRNGKey(hash(name) % (2 ** 31))
    params = api.init_params(cfg, rng)
    return name, cfg, params, _batch(cfg, rng)


class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        name, cfg, params, batch = arch
        logits, aux = jax.jit(
            lambda p, b: api.forward(p, cfg, b))(params, batch)
        assert logits.shape == (B, S, cfg.vocab), name
        assert not bool(jnp.isnan(logits).any()), f"{name}: NaN logits"
        assert jnp.isfinite(jnp.asarray(aux)), name

    def test_train_gradient_step(self, arch):
        name, cfg, params, batch = arch
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: api.loss_fn(p, cfg, batch)[0]))(params)
        assert np.isfinite(float(loss)), name
        leaves = jax.tree.leaves(grads)
        assert leaves, name
        for g in leaves:
            assert not bool(jnp.isnan(g).any()), f"{name}: NaN grad"
        # at least one nonzero gradient
        assert any(float(jnp.abs(g).max()) > 0 for g in leaves), name

    def test_decode_step_if_applicable(self, arch):
        name, cfg, params, batch = arch
        cache = api.make_decode_cache(cfg, B, S)
        tok = batch["inputs"][:, :1]
        if cfg.family == "encdec":
            cache["memory"] = jax.random.normal(
                jax.random.PRNGKey(0),
                cache["memory"].shape).astype(cache["memory"].dtype)
        logits, new_cache = jax.jit(
            lambda p, c, t: api.decode_step(p, cfg, c, t, jnp.int32(3)))(
            params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab), name
        assert not bool(jnp.isnan(logits).any()), name
        assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


class TestFullConfigMetadata:
    """Pure-metadata checks of the FULL configs (no allocation)."""

    def test_all_archs_registered(self):
        assert len(ARCH_IDS) == 10

    @pytest.mark.parametrize("name", ARCH_IDS)
    def test_full_config_fields(self, name):
        cfg = get(name)
        assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab > 0
        if cfg.family == "moe":
            assert cfg.n_experts > 0 and cfg.top_k > 0
        if cfg.family in ("ssm", "hybrid"):
            assert cfg.ssm_state > 0 and cfg.subquadratic
        if cfg.family in ("vlm", "encdec"):
            assert cfg.frontend

    def test_expected_param_counts(self):
        """Analytic parameter counts match the advertised model sizes."""
        def dense_params(c):
            hd = c.hd
            n_mats = 3 if c.mlp_gated else 2
            per = (c.d_model * (c.n_heads * hd)            # wq
                   + 2 * c.d_model * (c.n_kv_heads * hd)   # wk, wv
                   + (c.n_heads * hd) * c.d_model          # wo
                   + n_mats * c.d_model * c.d_ff           # mlp
                   + 2 * c.d_model)                        # norms
            emb = c.vocab * c.d_model * (1 if c.tie_embeddings else 2)
            return per * c.n_layers + emb

        c = get("smollm-135m")
        assert abs(dense_params(c) - 135e6) / 135e6 < 0.15
        c = get("yi-9b")
        assert abs(dense_params(c) - 8.8e9) / 8.8e9 < 0.15
        c = get("llama3-405b")
        assert abs(dense_params(c) - 405e9) / 405e9 < 0.05
        c = get("granite-34b")
        assert abs(dense_params(c) - 34e9) / 34e9 < 0.15
        # qwen3 MoE: experts dominate
        c = get("qwen3-moe-30b-a3b")
        moe = c.n_layers * c.n_experts * 3 * c.d_model * c.d_ff
        assert abs(moe - 29e9) / 29e9 < 0.15
