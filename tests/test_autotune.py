"""Tests for repro.autotune: fingerprint determinism, cost-model
monotonicity, cache round-trips, selector-vs-oracle agreement on a
synthetic suite (paper Fig. 9's selection question), and a frozen
decision snapshot so cost-model edits cannot silently flip selections."""

import json
import os

import numpy as np
import pytest

from repro.autotune import (DecisionCache, RGCSR_GROUP_SIZES, V5E,
                            bcsr_dtans_nbytes_estimate, candidates,
                            choose_dtans_config, clear_memo,
                            dtans_config_name, dtans_nbytes_estimate,
                            fingerprint, format_names, get_format,
                            lockstep_elems, model_time,
                            oracle_best, rgcsr_dtans_nbytes_estimate,
                            rgcsr_nbytes, select, spmv_bytes)
from repro.autotune.cost_model import (DTANS_LANE_WIDTHS,
                                       DTANS_SHARED_TABLE, coo_nbytes,
                                       csr_nbytes, sell_nbytes)
from repro.autotune.search import Decision
from repro.core.bcsr_dtans import encode_bcsr_matrix
from repro.core.csr_dtans import encode_matrix
from repro.core.rgcsr_dtans import encode_rgcsr_matrix
from repro.sparse.bcsr import BCSR, BCSR_BLOCK_SHAPES
from repro.sparse.formats import COO, CSR, SELL
from repro.sparse.prune import codebook_quantize, magnitude_prune
from repro.sparse.random_graphs import (banded, barabasi_albert,
                                        block_sparse, erdos_renyi,
                                        stencil_2d, watts_strogatz)
from repro.sparse.rgcsr import RGCSR

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


def _f32(a: CSR) -> CSR:
    return CSR(a.indptr, a.indices, a.values.astype(np.float32), a.shape)


def _powerlaw(m: int = 900, n: int = 900, seed: int = 11) -> CSR:
    """Zipf row lengths: the skewed-row-length case RGCSR exists for."""
    rng = np.random.default_rng(seed)
    lens = np.minimum(rng.zipf(1.6, size=m), n // 2)
    rows = np.repeat(np.arange(m), lens)
    cols = np.concatenate([rng.choice(n, size=int(k), replace=False)
                           for k in lens])
    vals = np.round(rng.standard_normal(rows.size) * 2) / 2 + 0.25
    return CSR.from_coo(rows, cols, vals, (m, n))


def _mini_suite() -> dict:
    """The 12-matrix synthetic selection suite (paper-Fig. 9 families
    plus the block-structured case BCSR exists for)."""
    rng = np.random.default_rng(7)
    w = (rng.standard_normal((512, 512)) / 22).astype(np.float32)
    nn = codebook_quantize(magnitude_prune(w, 0.85), bits=8)
    er = erdos_renyi(1200, 9, rng)
    rand_vals = CSR(er.indptr, er.indices,
                    rng.standard_normal(er.nnz), er.shape)
    return {
        "stencil": stencil_2d(40),
        "banded": banded(2500, 6),
        "er": erdos_renyi(1500, 10, rng),
        "er_dense": erdos_renyi(700, 25, rng),
        "ws": watts_strogatz(1500, 5, 0.1, rng),
        "ba": barabasi_albert(1500, 8, rng),
        "nn": nn,
        "rand_vals": rand_vals,
        "tiny": erdos_renyi(120, 5, rng),
        "single_row": CSR.from_dense(
            np.concatenate([np.ones((1, 300)),
                            np.zeros((59, 300))]).astype(np.float64)),
        "powerlaw": _powerlaw(),
        "blocked": block_sparse(300, 300, (4, 4), 0.035,
                                np.random.default_rng(21)),
    }


class TestFingerprint:
    def test_deterministic(self):
        a = _f32(stencil_2d(30))
        fp1, fp2 = fingerprint(a), fingerprint(a)
        assert fp1 == fp2
        assert fp1.key() == fp2.key()

    def test_distinct_matrices_distinct_keys(self):
        rng = np.random.default_rng(0)
        keys = {fingerprint(a if a.values.dtype == np.float64 else a).key()
                for a in (stencil_2d(30), banded(900, 4),
                          erdos_renyi(900, 6, rng))}
        assert len(keys) == 3

    def test_value_change_changes_key(self):
        a = stencil_2d(20)
        b = CSR(a.indptr, a.indices, a.values * 2.0, a.shape)
        assert fingerprint(a).key() != fingerprint(b).key()

    def test_features_exact(self):
        a = _f32(banded(600, 5))
        fp = fingerprint(a)
        assert fp.nnz == a.nnz
        assert (fp.rows, fp.cols) == a.shape
        assert fp.row_nnz_max == int(a.row_nnz().max())
        assert fp.sell_padded_nnz == SELL.from_csr(a).indices.size

    def test_empty_matrix(self):
        a = CSR.from_dense(np.zeros((8, 9)))
        fp = fingerprint(a)
        assert fp.nnz == 0 and fp.key()


class TestCostModel:
    def test_more_bytes_more_time(self):
        """Monotonicity: modeled time never decreases with bytes."""
        for warm in (True, False):
            times = [model_time(b, 10_000, warm=warm, decode=False)
                     for b in np.linspace(1e4, 1e9, 50)]
            assert all(t2 >= t1 for t1, t2 in zip(times, times[1:]))

    def test_decode_term_additive(self):
        t0 = model_time(10**6, 10**5, warm=True, decode=False)
        t1 = model_time(10**6, 10**5, warm=True, decode=True)
        assert t1 == pytest.approx(
            t0 + 10**5 * V5E.decode_ops_per_nnz / V5E.vpu_rate)

    def test_baseline_sizes_exact(self):
        a = _f32(watts_strogatz(800, 4, 0.05, np.random.default_rng(3)))
        fp = fingerprint(a)
        assert csr_nbytes(fp) == a.nbytes
        assert coo_nbytes(fp) == COO.from_csr(a).nbytes
        assert sell_nbytes(fp) == SELL.from_csr(a).nbytes

    @pytest.mark.parametrize("lane_width", DTANS_LANE_WIDTHS)
    @pytest.mark.parametrize("shared", DTANS_SHARED_TABLE)
    def test_dtans_estimate_close(self, lane_width, shared):
        a = _f32(erdos_renyi(900, 8, np.random.default_rng(4)))
        est = dtans_nbytes_estimate(fingerprint(a), lane_width=lane_width,
                                    shared_table=shared)
        act = encode_matrix(a, lane_width=lane_width,
                            shared_table=shared).nbytes
        assert abs(est - act) / act < 0.15

    def test_candidates_sorted(self):
        """Default candidate set: every selectable registry format
        (bcsr_dtans joins only where its fill-in guard admits it)."""
        fp = fingerprint(_f32(stencil_2d(25)))
        cands = candidates(fp)
        times = [c.modeled_time for c in cands]
        assert times == sorted(times)
        want = set(format_names(selectable=True)) - {"bcsr_dtans"}
        got = {c.fmt for c in cands}
        assert want <= got <= want | {"bcsr_dtans"}

    @pytest.mark.parametrize("G", RGCSR_GROUP_SIZES)
    def test_rgcsr_size_exact(self, G):
        """The selector's 'exact' RGCSR bytes equal the constructed
        format's own accounting — for uniform and skewed matrices."""
        for a in (_f32(watts_strogatz(700, 4, 0.05,
                                      np.random.default_rng(3))),
                  _f32(_powerlaw(400, 400, seed=5))):
            assert rgcsr_nbytes(fingerprint(a), G) == \
                RGCSR.from_csr(a, G).nbytes

    @pytest.mark.parametrize("G", RGCSR_GROUP_SIZES)
    def test_rgcsr_dtans_estimate_close(self, G):
        a = _f32(erdos_renyi(900, 8, np.random.default_rng(4)))
        est = rgcsr_dtans_nbytes_estimate(fingerprint(a), group_size=G)
        act = encode_rgcsr_matrix(a, group_size=G).nbytes
        assert abs(est - act) / act < 0.15

    def test_off_sweep_group_size_exact(self):
        """Group sizes outside RGCSR_GROUP_SIZES are exact too now: the
        fingerprint's row-nnz RLE derives any width (the old
        optimistic-nnz fallback is gone)."""
        a = _f32(erdos_renyi(8000, 10, np.random.default_rng(12)))
        fp = fingerprint(a)
        cand = [c for c in candidates(fp, formats=("rgcsr",),
                                      group_sizes=(64,))
                if c.fmt == "rgcsr"][0]
        true_b = RGCSR.from_csr(a, 64).nbytes
        assert cand.exact_size
        assert cand.nbytes == true_b
        dec = select(a, formats=("rgcsr",), group_sizes=(64,),
                     cache=DecisionCache(path=None))
        assert dec.exact_size and dec.nbytes == true_b

    def test_lockstep_elems_matches_sell(self):
        """lockstep work at width C == SELL(C)'s stored element count."""
        a = _f32(_powerlaw(300, 300, seed=9))
        rnnz = a.row_nnz()
        for c in (4, 32):
            assert lockstep_elems(rnnz, c) == \
                SELL.from_csr(a, slice_height=c).indices.size

    @pytest.mark.parametrize("width", [1, 3, 5, 7, 23, 48, 100, 1000])
    def test_lockstep_exact_for_arbitrary_widths(self, width):
        """`Fingerprint.lockstep` is exact for ANY width — verified
        against the stored element count of an actually-constructed
        SELL at that slice height (the former {4,8,16,32,128}-only
        fast path plus optimistic-nnz fallback is gone)."""
        a = _f32(_powerlaw(230, 300, seed=3))
        fp = fingerprint(a)
        assert fp.lockstep(width) == \
            SELL.from_csr(a, slice_height=width).indices.size
        assert fp.group_max_nnz(width) == \
            int(np.diff(RGCSR.from_csr(a, width).group_ptr).max())

    @pytest.mark.parametrize("bs", BCSR_BLOCK_SHAPES)
    def test_bcsr_size_exact(self, bs):
        """The selector's 'exact' BCSR bytes equal the constructed
        format's own accounting (block-fill histogram feature)."""
        for a in (_f32(stencil_2d(25)),
                  _f32(block_sparse(60, 50, (4, 4), 0.1))):
            fp = fingerprint(a)
            spec = get_format("bcsr")
            assert spec.nbytes_exact(fp, block_shape=bs) == \
                BCSR.from_csr(a, bs).nbytes

    def test_bcsr_dtans_estimate_close(self):
        """Fingerprint-only BCSR-dtANS size estimate within 15% of the
        real encode, for every admitted block shape."""
        a = _f32(block_sparse(80, 80, (4, 4), 0.08,
                              np.random.default_rng(5)))
        fp = fingerprint(a)
        spec = get_format("bcsr_dtans")
        shapes = [kn["block_shape"] for kn in spec.knob_grid(fp)]
        assert shapes, "no admitted block shape on a blocked matrix?"
        for bs in shapes:
            est = bcsr_dtans_nbytes_estimate(fp, block_shape=bs)
            act = encode_bcsr_matrix(a, block_shape=bs).nbytes
            assert abs(est - act) / act < 0.15

    def test_bcsr_dtans_fill_guard(self):
        """Scattered nonzeros (ER) blow up block fill-in: the knob grid
        must refuse to offer (and the oracle to encode) those layouts."""
        a = _f32(erdos_renyi(900, 8, np.random.default_rng(4)))
        fp = fingerprint(a)
        assert get_format("bcsr_dtans").knob_grid(fp) == []

    def test_off_sweep_block_shape_exact_and_admitted(self):
        """Block shapes outside BCSR_BLOCK_SHAPES are exact too (the
        fingerprint derives any shape's block count lazily), and an
        explicitly requested off-sweep shape must not be vetoed by
        bcsr_dtans's fill guard on a genuinely block-structured
        matrix."""
        a = _f32(block_sparse(60, 60, (3, 3), 0.08,
                              np.random.default_rng(9)))
        fp = fingerprint(a)
        true_b = BCSR.from_csr(a, (3, 3)).nbytes
        assert get_format("bcsr").nbytes_exact(
            fp, block_shape=(3, 3)) == true_b
        assert get_format("bcsr_dtans").admit(
            fp, {"block_shape": (3, 3), "shared_table": True})
        dec = select(a, formats=("bcsr", "bcsr_dtans"),
                     block_shapes=((3, 3),),
                     cache=DecisionCache(path=None))
        assert dec.block_shape == (3, 3) and dec.exact_size

    def test_fully_pruned_formats_raise_diagnosable_error(self):
        """When `admit` prunes every candidate of the requested formats
        (only possible since matrix-adaptive knob grids exist), select
        and the oracle must raise a named error, not IndexError."""
        from repro.autotune import oracle_best
        a = _f32(erdos_renyi(900, 8, np.random.default_rng(4)))
        with pytest.raises(ValueError, match="no admitted candidate"):
            select(a, formats=("bcsr_dtans",),
                   cache=DecisionCache(path=None))
        with pytest.raises(ValueError, match="no admitted candidate"):
            oracle_best(a, formats=("bcsr_dtans",))


class TestCache:
    def test_memory_roundtrip(self):
        c = DecisionCache(path=None)
        c.put("k", {"fmt": "csr"})
        assert c.get("k") == {"fmt": "csr"}
        assert "k" in c and len(c) == 1

    def test_disk_roundtrip(self, tmp_path):
        p = tmp_path / "sub" / "autotune.json"
        c = DecisionCache(path=p)
        c.put("k1", {"fmt": "sell", "nbytes": 10})
        del c
        c2 = DecisionCache(path=p)
        assert c2.get("k1") == {"fmt": "sell", "nbytes": 10}

    def test_corrupt_file_is_empty_cache(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text("{not json")
        c = DecisionCache(path=p)
        assert c.get("x") is None
        c.put("x", {"a": 1})        # and it heals on write
        assert DecisionCache(path=p).get("x") == {"a": 1}

    def test_interleaved_writers_merge(self, tmp_path):
        """Two processes sharing one cache file must union their keys:
        the second writer re-reads the disk under its atomic rename
        instead of clobbering it with its own memo."""
        p = tmp_path / "shared.json"
        c1, c2 = DecisionCache(path=p), DecisionCache(path=p)
        assert c1.get("x") is None        # both memos load pre-write
        assert c2.get("x") is None
        c1.put("k1", {"fmt": "csr"})
        c2.put("k2", {"fmt": "sell"})     # unaware of k1 until now
        fresh = DecisionCache(path=p)
        assert fresh.get("k1") == {"fmt": "csr"}
        assert fresh.get("k2") == {"fmt": "sell"}
        # the merging writer also adopted the other process's key
        assert c2.get("k1") == {"fmt": "csr"}

    def test_interleaved_writers_last_write_wins_per_key(self, tmp_path):
        p = tmp_path / "shared.json"
        c1, c2 = DecisionCache(path=p), DecisionCache(path=p)
        c1.get("x"), c2.get("x")
        c1.put("k", {"fmt": "csr"})
        c2.put("k", {"fmt": "sell"})
        assert DecisionCache(path=p).get("k") == {"fmt": "sell"}

    def test_unwritable_path_degrades_to_memory(self, tmp_path):
        ro = tmp_path / "ro"
        ro.mkdir()
        ro.chmod(0o500)
        c = DecisionCache(path=ro / "sub" / "c.json")
        c.put("k", {"fmt": "csr"})       # must not raise
        assert c.get("k") == {"fmt": "csr"}
        ro.chmod(0o700)

    def test_select_hits_disk_cache(self, tmp_path):
        a = _f32(erdos_renyi(500, 6, np.random.default_rng(5)))
        cache = DecisionCache(path=tmp_path / "c.json")
        clear_memo()
        d1 = select(a, cache=cache)
        assert len(cache) == 1
        clear_memo()                     # force the disk path
        d2 = select(a, cache=cache)
        assert d2 == d1
        assert isinstance(d2, Decision)

    def test_machine_constants_in_cache_key(self):
        """A recalibrated MachineModel must not hit stale decisions."""
        a = _f32(erdos_renyi(400, 6, np.random.default_rng(9)))
        cache = DecisionCache(path=None)
        clear_memo()
        d1 = select(a, cache=cache)
        slow = V5E.__class__(hbm_bw=V5E.hbm_bw / 100,
                             cache_bw=V5E.cache_bw / 100)  # name still "v5e"
        d2 = select(a, machine=slow, cache=cache)
        assert len(cache) == 2                    # distinct keys
        assert d2.modeled_time != d1.modeled_time

    def test_memo_does_not_shadow_new_cache(self):
        a = _f32(banded(300, 3))
        clear_memo()
        c1, c2 = DecisionCache(path=None), DecisionCache(path=None)
        select(a, cache=c1)
        select(a, cache=c2)
        assert len(c1) == 1 and len(c2) == 1

    def test_schema_drift_is_cache_miss(self):
        a = _f32(banded(300, 3))
        cache = DecisionCache(path=None)
        clear_memo()
        d1 = select(a, cache=cache)
        key = next(iter(cache._load()))
        cache.put(key, {"fmt": "csr", "bogus_old_field": 1})  # stale schema
        clear_memo()
        assert select(a, cache=cache) == d1       # recomputed, not crash

    def test_decision_dict_roundtrip(self):
        a = _f32(banded(400, 4))
        d = select(a, cache=DecisionCache(path=None), use_cache=True)
        assert Decision.from_dict(d.to_dict()) == d


class TestSelector:
    #: Encoded-candidate memo shared across the selector tests (the
    #: exhaustive oracle is the expensive part of this module).
    _ENC: dict = {}

    @pytest.mark.parametrize("warm", [True, False], ids=["warm", "cold"])
    def test_selector_matches_modeled_argmin(self, warm):
        """>= 90% agreement with the exact oracle, tiny regret elsewhere
        (the ISSUE's acceptance bar, on the mini synthetic suite)."""
        cache = DecisionCache(path=None)
        agree, total, regrets = 0, 0, []
        for name, a64 in _mini_suite().items():
            a = _f32(a64)
            dec = select(a, warm=warm, cache=cache)
            best, t_best, times = oracle_best(
                a, warm=warm, encode_cache=self._ENC.setdefault(name, {}))
            t_pick = times[dec.config_name]
            regrets.append(t_pick / t_best - 1.0)
            agree += dec.config_name == best
            total += 1
        assert agree / total >= 0.9, f"agreement {agree}/{total}"
        assert max(regrets) < 0.1, f"max regret {max(regrets):.3f}"

    def test_snapshot_decisions_and_zero_regret(self):
        """Decision snapshot: `select()` on the 12-matrix suite must
        (a) match the frozen choices in
        tests/goldens/autotune_decisions.json — a cost-model edit that
        flips a selection fails here and forces a deliberate regen
        (REPRO_REGEN_GOLDENS=1) — and (b) keep selector-vs-oracle regret
        at zero with the full registry candidate set (bcsr/bcsr_dtans
        included). Also pins two acceptance bars: a skewed-row-length
        matrix selects an rgcsr format, and the block-structured matrix
        selects a bcsr variant."""
        path = os.path.join(GOLDEN_DIR, "autotune_decisions.json")
        cache = DecisionCache(path=None)
        got: dict = {}
        for warm, tag in ((True, "warm"), (False, "cold")):
            got[tag] = {}
            for name, a64 in _mini_suite().items():
                a = _f32(a64)
                dec = select(a, warm=warm, cache=cache)
                best, t_best, times = oracle_best(
                    a, warm=warm,
                    encode_cache=self._ENC.setdefault(name, {}))
                regret = times[dec.config_name] / t_best - 1.0
                assert regret <= 1e-12, \
                    f"{tag}/{name}: pick={dec.config_name} " \
                    f"oracle={best} regret={regret:.4g}"
                got[tag][name] = dec.config_name
        skewed = {"powerlaw", "single_row"}
        assert any(got["warm"][s].startswith("rgcsr") for s in skewed)
        assert got["warm"]["blocked"].startswith("bcsr")
        if os.environ.get("REPRO_REGEN_GOLDENS"):
            os.makedirs(GOLDEN_DIR, exist_ok=True)
            with open(path, "w") as f:
                json.dump(got, f, indent=1, sort_keys=True)
        with open(path) as f:
            want = json.load(f)
        assert got == want, "selection flipped vs snapshot; if this is " \
            "an intended cost-model change, rerun with " \
            "REPRO_REGEN_GOLDENS=1 and review the diff"

    def test_refinement_budget_gives_exact_sizes(self):
        a = _f32(erdos_renyi(600, 7, np.random.default_rng(6)))
        cache = DecisionCache(path=None)
        dec = select(a, formats=("dtans",), budget=4, cache=cache)
        act = encode_matrix(a, lane_width=dec.lane_width,
                            shared_table=dec.shared_table).nbytes
        assert dec.exact_size and dec.nbytes == act

    def test_choose_dtans_config(self):
        a = _f32(banded(800, 6))
        dec = choose_dtans_config(a, cache=DecisionCache(path=None))
        assert dec.fmt in format_names(selectable=True, decodes=True)
        # lane_width is always the interleave width the matrix was
        # encoded with (== group size / block height for the aligned
        # families) — what the registry's spec derives from the knobs.
        spec = get_format(dec.fmt)
        assert dec.lane_width == spec.interleave_width(dec.knobs_dict())
        if dec.fmt == "rgcsr_dtans":
            assert dec.lane_width == dec.group_size
        if dec.fmt == "bcsr_dtans":
            assert dec.lane_width == dec.block_shape[0]

    def test_batched_selection_zero_regret_and_flip(self):
        """The ISSUE's batched acceptance bar: `select(batch=B)` prices
        decode amortization — on the synthetic suite at least one
        matrix's winning format differs between B=1 and B=32 (per-RHS
        contraction work overtakes the amortized per-pass costs), and
        selector-vs-oracle regret stays 0 at both batch sizes."""
        cache = DecisionCache(path=None)
        flipped = []
        for name, a64 in _mini_suite().items():
            a = _f32(a64)
            picks = {}
            for B in (1, 32):
                clear_memo()
                dec = select(a, warm=True, batch=B, cache=cache)
                assert dec.batch == B
                best, t_best, times = oracle_best(
                    a, warm=True, batch=B,
                    encode_cache=self._ENC.setdefault(name, {}))
                regret = times[dec.config_name] / t_best - 1.0
                assert regret <= 1e-12, \
                    f"{name}@B={B}: pick={dec.config_name} " \
                    f"oracle={best} regret={regret:.4g}"
                picks[B] = dec.config_name
            if picks[1] != picks[32]:
                flipped.append((name, picks[1], picks[32]))
        assert flipped, "no matrix changed its winning format " \
                        "between B=1 and B=32"

    def test_batch_amortizes_decode_not_contraction(self):
        """`work_time(terms, batch=B)` scales the contraction terms
        with B but charges the decode term ONCE per pass — the fused
        SpMM kernels' decode-once/contract-B shape; and `spmm_bytes`
        pays the matrix once but x/y per RHS."""
        from repro.autotune.cost_model import spmm_bytes, work_time
        a = _f32(erdos_renyi(600, 7, np.random.default_rng(6)))
        fp = fingerprint(a)
        spec = get_format("dtans")
        terms = spec.cost_terms(fp)
        assert terms.decode > 0
        per_rhs_ops = (terms.lockstep * V5E.spmv_ops_per_elem
                       / V5E.vpu_rate)
        assert work_time(terms, batch=8) == pytest.approx(
            work_time(terms, batch=1) + 7 * per_rhs_ops)
        b = spec.nbytes_estimate(fp)
        assert spmm_bytes(b, fp.cols, fp.rows, fp.value_bytes, 8) == \
            b + 8 * (fp.cols + fp.rows) * fp.value_bytes
        assert spmm_bytes(b, fp.cols, fp.rows, fp.value_bytes) == \
            spmv_bytes(b, fp.cols, fp.rows, fp.value_bytes)

    def test_batch_in_cache_key(self):
        """Decisions priced for different batch sizes must never serve
        each other from the cache."""
        a = _f32(banded(400, 4))
        cache = DecisionCache(path=None)
        clear_memo()
        select(a, cache=cache)
        select(a, batch=32, cache=cache)
        assert len(cache) == 2

    def test_batch_rejects_nonpositive(self):
        with pytest.raises(ValueError, match="batch"):
            select(_f32(banded(300, 3)), batch=0,
                   cache=DecisionCache(path=None))

    def test_memo_hit_is_fast_and_identical(self):
        import time
        a = _f32(stencil_2d(30))
        cache = DecisionCache(path=None)
        clear_memo()
        d1 = select(a, cache=cache)
        t0 = time.perf_counter()
        for _ in range(100):
            d2 = select(a, cache=cache)
        per_call = (time.perf_counter() - t0) / 100
        assert d2 is d1
        assert per_call < 1e-3     # microseconds, not a re-search


class TestServingIntegration:
    def test_sparse_linear_auto(self):
        rng = np.random.default_rng(8)
        w = (rng.standard_normal((128, 320)) / 12).astype(np.float32)
        from repro.serving.sparse_linear import SparseLinear
        sl = SparseLinear.from_dense(w, sparsity=0.8, auto=True,
                                     autotune_cache=DecisionCache(path=None))
        assert sl.decision is not None
        assert sl.decision.fmt in ("dtans", "rgcsr_dtans")
        assert sl.mat.lane_width == sl.decision.lane_width
        if sl.decision.fmt == "rgcsr_dtans":
            from repro.core.rgcsr_dtans import RGCSRdtANS
            assert isinstance(sl.mat, RGCSRdtANS)
            assert sl.mat.group_size == sl.decision.group_size
        x = rng.standard_normal((2, 128)).astype(np.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
