"""BCSR format family: construction, byte accounting, the Pallas
kernel, block-filled entropy coding (BCSR-dtANS), and property-based
round-trips — the blocked mirror of tests/test_rgcsr.py."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.bcsr_dtans import BCSRdtANS, encode_bcsr_matrix
from repro.core.csr_dtans import decode_matrix, spmv_gold
from repro.kernels import ops
from repro.kernels.bcsr_spmv import bcsr_spmv_ref, pack_bcsr
from repro.sparse.bcsr import (BCSR, BCSR_BLOCK_SHAPES, bcsr_nbytes_exact,
                               block_fill_csr, count_nonempty_blocks)
from repro.sparse.formats import CSR
from repro.sparse.random_graphs import (banded, block_sparse, erdos_renyi,
                                        stencil_2d)


def _assert_same_csr(a: CSR, b: CSR):
    assert a.shape == b.shape
    assert np.array_equal(a.indptr, b.indptr)
    assert np.array_equal(a.indices, b.indices)
    assert np.array_equal(a.values, b.values)  # bit-exact (lossless)


def _random_csr(rng, m, n, density, dtype=np.float64):
    d = rng.integers(-3, 4, size=(m, n)).astype(dtype)
    d[rng.random((m, n)) >= density] = 0
    return CSR.from_dense(d)


class TestBCSRFormat:
    @pytest.mark.parametrize("bs", BCSR_BLOCK_SHAPES)
    def test_roundtrip(self, bs):
        a = erdos_renyi(100, 6, np.random.default_rng(1))
        b = BCSR.from_csr(a, bs)
        _assert_same_csr(a, b.to_csr())
        np.testing.assert_array_equal(b.to_dense(), a.to_dense())

    def test_roundtrip_empty_and_awkward(self):
        """Edge blocks (shape not a multiple of r/c), empty matrices,
        single dense rows."""
        for d in (np.zeros((8, 9)),
                  np.diag(np.r_[np.zeros(5), np.arange(1.0, 7.0)]),
                  np.ones((3, 41))):
            a = CSR.from_dense(d)
            for bs in ((1, 1), (2, 2), (4, 4), (4, 2), (8, 8)):
                b = BCSR.from_csr(a, bs)
                _assert_same_csr(a, b.to_csr())
                np.testing.assert_array_equal(b.to_dense(), d)

    @pytest.mark.parametrize("bs", BCSR_BLOCK_SHAPES)
    def test_nbytes_matches_block_count_formula(self, bs):
        a = stencil_2d(15)
        b = BCSR.from_csr(a, bs)
        nb = count_nonempty_blocks(a.indptr, a.indices, a.shape, bs)
        assert b.n_blocks == nb
        assert b.nbytes == bcsr_nbytes_exact(nb, a.shape[0], bs, 8)

    def test_fully_blocked_matrix_beats_csr_bytes(self):
        """On a perfectly block-structured matrix the per-element index
        cost drops to 4 / (r*c) bytes — the format's reason to exist."""
        a = block_sparse(50, 50, (4, 4), 0.1, np.random.default_rng(2))
        b = BCSR.from_csr(a, (4, 4))
        assert b.nnz_stored == a.nnz              # zero fill-in
        assert b.nbytes < a.nbytes

    def test_spmv_reference(self):
        rng = np.random.default_rng(3)
        a = _random_csr(rng, 45, 37, 0.2)
        b = BCSR.from_csr(a, (4, 4))
        x = rng.standard_normal(37)
        y0 = rng.standard_normal(45)
        np.testing.assert_allclose(b.spmv(x, y0), a.to_dense() @ x + y0,
                                   rtol=1e-12)

    def test_block_fill_csr_preserves_dense(self):
        rng = np.random.default_rng(4)
        a = _random_csr(rng, 30, 22, 0.15)
        for bs in ((2, 2), (4, 4), (3, 5)):
            f = block_fill_csr(a, bs)
            np.testing.assert_array_equal(f.to_dense(), a.to_dense())
            assert f.nnz >= a.nnz
            # filled rows cover whole blocks: every stored run is c wide
            # except where the matrix boundary cuts a block
            nb = count_nonempty_blocks(a.indptr, a.indices, a.shape, bs)
            assert f.nnz <= nb * bs[0] * bs[1]


class TestBCSRKernel:
    @pytest.mark.parametrize("bs", [(2, 2), (4, 4), (8, 8)])
    @pytest.mark.parametrize("dtype", [np.float64, np.float32])
    def test_kernel_vs_ref_and_dense(self, bs, dtype):
        rng = np.random.default_rng(5)
        a = _random_csr(rng, 66, 43, 0.15, dtype)
        pb = pack_bcsr(BCSR.from_csr(a, bs))
        x = rng.standard_normal(43).astype(dtype)
        y_k = np.asarray(ops.bcsr_spmv(pb, x))
        y_r = np.asarray(bcsr_spmv_ref(pb.block_cols, pb.values, x)
                         ).reshape(-1)[:66]
        rtol = 1e-12 if dtype == np.float64 else 1e-5
        np.testing.assert_allclose(y_k, y_r, rtol=rtol)
        np.testing.assert_allclose(y_k, a.to_dense() @ x, rtol=rtol,
                                   atol=1e-5 if dtype == np.float32 else 0)

    def test_accumulate_y(self):
        rng = np.random.default_rng(6)
        a = _random_csr(rng, 33, 29, 0.2, np.float32)
        pb = pack_bcsr(BCSR.from_csr(a, (4, 4)))
        x = rng.standard_normal(29).astype(np.float32)
        y0 = rng.standard_normal(33).astype(np.float32)
        got = np.asarray(ops.bcsr_spmv(pb, x, y0))
        np.testing.assert_allclose(got, a.to_dense() @ x + y0, rtol=1e-5,
                                   atol=1e-5)


class TestBCSRdtANS:
    @pytest.mark.parametrize("bs", BCSR_BLOCK_SHAPES)
    def test_roundtrip_is_block_filled(self, bs):
        """decode(encode_bcsr(a)) == block_fill(a) bit-exactly, and the
        filled matrix's dense form equals the original's."""
        a = erdos_renyi(60, 5, np.random.default_rng(7))
        mat = encode_bcsr_matrix(a, block_shape=bs)
        assert isinstance(mat, BCSRdtANS)
        dec = decode_matrix(mat)
        _assert_same_csr(block_fill_csr(a, bs), dec)
        np.testing.assert_array_equal(dec.to_dense(), a.to_dense())

    def test_slices_align_with_block_rows(self):
        """The defining property: one decode slice per block row."""
        a = banded(64, 4)
        mat = encode_bcsr_matrix(a, block_shape=(4, 4))
        assert mat.lane_width == 4
        assert mat.n_block_rows == 16
        assert mat.slice_offsets.size == mat.n_block_rows + 1

    def test_nbytes_accounting(self):
        """Block-count metadata replaces per-row lengths: base CSR-dtANS
        accounting minus 4 B/row plus 2 B/block-row."""
        a = banded(640, 5)
        mat = encode_bcsr_matrix(a, block_shape=(4, 4))
        from repro.core.csr_dtans import CSRdtANS
        base = CSRdtANS.nbytes.fget(mat)
        assert mat.nbytes == base - 640 * 4 + mat.n_block_rows * 2

    def test_spmv_gold_and_kernel(self):
        rng = np.random.default_rng(8)
        a = _random_csr(rng, 52, 40, 0.15)
        mat = encode_bcsr_matrix(a, block_shape=(2, 2))
        x = rng.standard_normal(40)
        want = a.to_dense() @ x
        np.testing.assert_allclose(spmv_gold(mat, x), want, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(ops.spmv(mat, x)), want,
                                   rtol=1e-9)


class TestPropertyRoundtrips:
    """Property-based bit-exactness (skips when hypothesis is absent;
    the CI no-hypothesis leg exercises the shim path)."""

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2 ** 31))
    def test_bcsr_random(self, seed):
        rng = np.random.default_rng(seed)
        m, n = int(rng.integers(1, 70)), int(rng.integers(1, 70))
        a = _random_csr(rng, m, n, float(rng.uniform(0.01, 0.4)))
        bs = (int(rng.integers(1, 9)), int(rng.integers(1, 9)))
        b = BCSR.from_csr(a, bs)
        _assert_same_csr(a, b.to_csr())
        x = rng.standard_normal(n)
        np.testing.assert_allclose(b.spmv(x), a.to_dense() @ x,
                                   rtol=1e-10, atol=1e-10)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2 ** 31))
    def test_bcsr_dtans_random(self, seed):
        rng = np.random.default_rng(seed)
        m, n = int(rng.integers(1, 50)), int(rng.integers(1, 50))
        a = _random_csr(rng, m, n, float(rng.uniform(0.01, 0.3)))
        bs = (int(rng.integers(1, 6)), int(rng.integers(1, 6)))
        mat = encode_bcsr_matrix(a, block_shape=bs)
        _assert_same_csr(block_fill_csr(a, bs), decode_matrix(mat))
