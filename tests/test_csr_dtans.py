"""Matrix-level CSR-dtANS tests: lossless roundtrip, SpMVM gold, sizing."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.csr_dtans import decode_matrix, encode_matrix, spmv_gold
from repro.sparse.formats import CSR, COO, SELL, best_baseline_nbytes
from repro.sparse.random_graphs import banded, erdos_renyi, stencil_2d


def _assert_same_csr(a: CSR, b: CSR):
    assert a.shape == b.shape
    assert np.array_equal(a.indptr, b.indptr)
    assert np.array_equal(a.indices, b.indices)
    assert np.array_equal(a.values, b.values)  # bit-exact (lossless)


class TestFormats:
    def test_csr_dense_roundtrip(self):
        rng = np.random.default_rng(0)
        d = rng.standard_normal((17, 23))
        d[rng.random(d.shape) < 0.7] = 0
        a = CSR.from_dense(d)
        np.testing.assert_array_equal(a.to_dense(), d)

    def test_coo_sell_sizes(self):
        a = stencil_2d(20)
        coo = COO.from_csr(a)
        sell = SELL.from_csr(a)
        assert coo.nnz == a.nnz
        assert sell.indices.size >= a.nnz  # padding never shrinks
        # uniform rows: SELL beats COO (paper Section III-A comparison)
        assert sell.nbytes < coo.nbytes

    def test_best_baseline_picks_min(self):
        a = banded(300, 4)
        name, nb = best_baseline_nbytes(a)
        assert nb == min(a.nbytes, COO.from_csr(a).nbytes,
                         SELL.from_csr(a).nbytes)


class TestMatrixRoundtrip:
    @pytest.mark.parametrize("dtype", [np.float64, np.float32])
    @pytest.mark.parametrize("shared", [True, False],
                             ids=["shared-table", "two-tables"])
    def test_stencil(self, dtype, shared):
        a = stencil_2d(30, dtype=np.float64)
        a = CSR(a.indptr, a.indices, a.values.astype(dtype), a.shape)
        mat = encode_matrix(a, lane_width=32, shared_table=shared)
        _assert_same_csr(a, decode_matrix(mat))

    @pytest.mark.parametrize("lane_width", [1, 3, 32, 128])
    def test_lane_widths(self, lane_width):
        rng = np.random.default_rng(1)
        a = erdos_renyi(150, 7, rng)
        mat = encode_matrix(a, lane_width=lane_width)
        _assert_same_csr(a, decode_matrix(mat))

    def test_empty_and_dense_rows(self):
        d = np.zeros((40, 50))
        d[3, :] = 1.5       # dense row
        d[7, 9] = -2.0      # single-nnz row; other rows empty
        d[39, 49] = 1.0
        a = CSR.from_dense(d)
        mat = encode_matrix(a, lane_width=16)
        _assert_same_csr(a, decode_matrix(mat))

    def test_escape_heavy_values(self):
        rng = np.random.default_rng(2)
        d = rng.standard_normal((128, 128))
        d[rng.random(d.shape) < 0.5] = 0
        a = CSR.from_dense(d)
        mat = encode_matrix(a, lane_width=32)
        assert mat.esc_count_by_domain[1] > 0  # raw float64s escape
        _assert_same_csr(a, decode_matrix(mat))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2 ** 31))
    def test_property_random_matrices(self, seed):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(1, 70))
        n = int(rng.integers(1, 70))
        density = float(rng.uniform(0.01, 0.4))
        d = rng.integers(-3, 4, size=(m, n)).astype(np.float64)
        d[rng.random((m, n)) >= density] = 0
        a = CSR.from_dense(d)
        mat = encode_matrix(a, lane_width=int(rng.integers(1, 40)))
        _assert_same_csr(a, decode_matrix(mat))
        x = rng.standard_normal(n)
        np.testing.assert_allclose(spmv_gold(mat, x), d @ x, atol=1e-9)


class TestSpmvGold:
    def test_against_dense(self):
        rng = np.random.default_rng(3)
        a = erdos_renyi(300, 9, rng)
        mat = encode_matrix(a, lane_width=64)
        x = rng.standard_normal(300)
        np.testing.assert_allclose(spmv_gold(mat, x), a.to_dense() @ x,
                                   rtol=1e-12)

    def test_accumulate_semantics(self):
        """Paper Section III-A: SpMVM computes y = A x + y."""
        rng = np.random.default_rng(4)
        a = banded(100, 3)
        mat = encode_matrix(a)
        x = rng.standard_normal(100)
        y0 = rng.standard_normal(100)
        np.testing.assert_allclose(spmv_gold(mat, x, y0),
                                   a.to_dense() @ x + y0, rtol=1e-12)


class TestCompression:
    def test_structured_matrix_compresses(self):
        """Paper Table I: matrices with >= 10 annzpr and enough nonzeros
        compress vs the best cuSPARSE format."""
        a = erdos_renyi(3000, 12, np.random.default_rng(5))
        mat = encode_matrix(a)
        _, bb = best_baseline_nbytes(a)
        assert mat.nbytes < bb

    def test_tiny_matrix_does_not(self):
        """Paper Fig. 6: constant table overhead dominates small matrices."""
        a = stencil_2d(8)
        mat = encode_matrix(a)
        _, bb = best_baseline_nbytes(a)
        assert mat.nbytes > bb

    def test_size_accounting_fields(self):
        a = banded(600, 5)
        mat = encode_matrix(a)
        assert mat.nbytes >= mat.stream.size * 4 + a.shape[0] * 4
        assert (mat.stream < mat.params.W).all()
