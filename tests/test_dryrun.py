"""Distribution-layer tests.

In-process tests use a small forced-device-count SUBPROCESS (the 512-device
XLA flag must never leak into the main test process — smoke tests and
benches see 1 device). The subprocess compiles one small arch on a debug
mesh and asserts sharding + no-f64 discipline.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np
from jax.sharding import Mesh
from repro.launch.steps import build_cell, batch_struct
from repro.launch.sharding import ShardingRules
from repro import configs

mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))

# compile a REDUCED smollm train cell on the debug mesh
import repro.launch.steps as steps
import repro.models.config as mc
cfg = configs.get_smoke("smollm-135m").with_(dtype="bfloat16")
import repro.configs as C
orig_get = C.get
C.get = lambda name: cfg            # reduced config under the launcher
steps.configs.get = C.get
mc.SHAPES["train_4k"] = mc.ShapeConfig("train_4k", 64, 8, "train")
cell = build_cell("smollm-135m", "train_4k", mesh, dp_only=False)
lowered = cell.lower(mesh)
txt = lowered.as_text()
compiled = lowered.compile()
out = {
    "ok": True,
    "f64_leak": "f64[" in txt,
    "has_sharding": "sharding" in txt,
    "mem": int(compiled.memory_analysis().temp_size_in_bytes),
}
# decode cell too (cache sharding path)
mc.SHAPES["decode_32k"] = mc.ShapeConfig("decode_32k", 128, 8, "decode")
cell2 = build_cell("smollm-135m", "decode_32k", mesh, dp_only=False)
cell2.lower(mesh).compile()
out["decode_ok"] = True
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def subproc_result():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


class TestDistributed:
    def test_train_cell_compiles_on_mesh(self, subproc_result):
        assert subproc_result["ok"]

    def test_no_f64_leak_in_model_hlo(self, subproc_result):
        """x64 is enabled package-wide for the dtANS codec; model code must
        stay in explicit 32-bit dtypes."""
        assert not subproc_result["f64_leak"]

    def test_decode_cell_compiles_on_mesh(self, subproc_result):
        assert subproc_result["decode_ok"]


class TestMeshAndRules:
    def test_mesh_requires_devices(self):
        from repro.launch.mesh import make_production_mesh
        with pytest.raises(RuntimeError):
            make_production_mesh()  # only 1 device in this process

    def test_param_specs_divisibility_guard(self):
        import jax
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.configs import get
        from repro.launch.sharding import ShardingRules
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")
        rules = ShardingRules(get("smollm-135m"), FakeMesh())
        # stacked layer param (30, d, d): dim0 = layers stays unsharded;
        # wq out dim 576 = 16x36 -> TP-sharded on "model"
        leaf = type("L", (), {"shape": (30, 576, 576)})()
        from jax.tree_util import DictKey
        spec = rules.param_spec((DictKey("layers"), DictKey("attn"),
                                 DictKey("wq")), leaf)
        assert spec == P(None, None, "model")

    def test_skip_policy(self):
        from repro.launch.steps import cell_is_skipped
        assert cell_is_skipped("llama3-405b", "long_500k")
        assert cell_is_skipped("mamba2-130m", "long_500k") is None
        assert cell_is_skipped("zamba2-7b", "long_500k") is None
        assert cell_is_skipped("yi-9b", "train_4k") is None


class TestDryRunArtifacts:
    """Validate recorded dry-run artifacts when present (the full matrix
    is produced by launch/dryrun.py runs, not by pytest)."""

    DDIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")

    def test_artifacts_cover_the_assignment(self):
        if not os.path.isdir(self.DDIR):
            pytest.skip("dry-run artifacts not generated yet")
        recs = [json.load(open(os.path.join(self.DDIR, f)))
                for f in os.listdir(self.DDIR) if f.endswith(".json")]
        assert len(recs) >= 80, "40 cells x 2 meshes expected"
        bad = [(r["arch"], r["shape"], r["mesh"]) for r in recs
               if r["status"] == "error"]
        assert not bad, f"failed cells: {bad}"
        ok = [r for r in recs if r["status"] == "ok"]
        assert len(ok) >= 64
        for r in ok:
            assert r["roofline"]["dominant"] in ("compute", "memory",
                                                 "collective")
            assert not r.get("dtype_leak"), (r["arch"], r["shape"])
