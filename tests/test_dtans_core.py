"""Unit + property tests for the dtANS codec (paper Algorithms 1-3, Sec. IV)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.dtans import decode_scalar, encode_scalar, encoded_bits
from repro.core.dtans_vec import (StackedTables, decode_lanes,
                                  interleave_slice_with_pattern)
from repro.core.entropy import entropy_bits, stream_entropy_bits
from repro.core.params import PAPER, TOY, DtansParams
from repro.core.tables import build_table, table_cross_entropy


def _table_from(u, params, esc_raw_bits=32):
    syms, counts = np.unique(u, return_counts=True)
    if syms.size == 0:
        syms, counts = np.asarray([0], np.uint64), np.asarray([1])
    return build_table(syms.astype(np.uint64), counts, params,
                       esc_raw_bits=esc_raw_bits)


class TestParams:
    def test_paper_constraints(self):
        assert PAPER.K ** PAPER.l == PAPER.W ** PAPER.o  # exact unpack
        assert PAPER.M ** PAPER.l == PAPER.W ** PAPER.f  # tight digit bound

    def test_invalid_rejected(self):
        with pytest.raises(ValueError):
            DtansParams(w_bits=32, k_bits=12, l=8, o=4, f=2)  # K^l < W^o
        with pytest.raises(ValueError):
            DtansParams(w_bits=32, k_bits=12, l=8, o=3, f=1)  # M^l > W^f


class TestTables:
    def test_multiplicity_cap_and_budget(self):
        rng = np.random.default_rng(0)
        u = rng.choice(50, size=5000,
                       p=(lambda p: p / p.sum())(
                           1.0 / np.arange(1, 51) ** 1.5)).astype(np.uint64)
        t = _table_from(u, PAPER)
        assert t.slot_base.max() <= PAPER.M
        assert t.used_slots <= PAPER.K
        # consecutive slots per symbol, digits 0..base-1
        for sym, fs in list(t.first_slot.items())[:10]:
            b = t.slot_base[fs]
            assert (t.slot_symbol[fs:fs + b] == sym).all()
            assert (t.slot_digit[fs:fs + b] == np.arange(b)).all()

    def test_cross_entropy_close_to_entropy(self):
        rng = np.random.default_rng(1)
        u = rng.choice(200, size=20000,
                       p=(lambda p: p / p.sum())(
                           1.0 / np.arange(1, 201))).astype(np.uint64)
        syms, counts = np.unique(u, return_counts=True)
        t = build_table(syms, counts, PAPER)
        H = entropy_bits(counts)
        Hp = table_cross_entropy(t, syms, counts)
        # M-cap floors bits/sym at log2(K/M) = 4; allow that plus slack
        assert Hp >= H - 1e-9
        assert Hp <= max(H, 4.0) + 0.15

    def test_single_symbol_corpus(self):
        t = _table_from(np.zeros(10, np.uint64), PAPER)
        assert t.base_of(0) == PAPER.M  # capped at M, not K
        u = np.zeros(37, dtype=np.uint64)
        enc = encode_scalar(u, PAPER, [t])
        assert np.array_equal(decode_scalar(enc, PAPER, [t]), u)


class TestScalarRoundtrip:
    @pytest.mark.parametrize("params", [PAPER, TOY],
                             ids=["paper", "toy"])
    @pytest.mark.parametrize("n", [0, 1, 2, 7, 8, 9, 63, 64, 257])
    def test_lengths(self, params, n):
        rng = np.random.default_rng(n)
        u = rng.integers(0, 5, size=n).astype(np.uint64)
        t = _table_from(u, params)
        enc = encode_scalar(u, params, [t])
        assert np.array_equal(decode_scalar(enc, params, [t]), u)

    def test_escape_roundtrip(self):
        rng = np.random.default_rng(3)
        # alphabet far larger than K forces escapes
        u = rng.integers(0, 1 << 20, size=10000).astype(np.uint64)
        t = _table_from(u, PAPER)
        enc = encode_scalar(u, PAPER, [t])
        assert sum(e.size for e in enc.esc) > 0
        assert np.array_equal(decode_scalar(enc, PAPER, [t]), u)

    def test_two_tables_interleaved_domains(self):
        rng = np.random.default_rng(4)
        l = PAPER.l
        pattern = np.tile([0, 1], l // 2)
        u = np.empty(400, dtype=np.uint64)
        u[0::2] = rng.integers(0, 8, size=200)       # "delta" domain
        u[1::2] = rng.integers(100, 164, size=200)   # "value" domain
        k = np.arange(u.size) % l
        t0 = _table_from(u[pattern[k] == 0], PAPER)
        t1 = _table_from(u[pattern[k] == 1], PAPER)
        enc = encode_scalar(u, PAPER, [t0, t1], pattern)
        assert np.array_equal(decode_scalar(enc, PAPER, [t0, t1], pattern), u)

    def test_compression_near_cross_entropy(self):
        """Achieved bits/symbol tracks H' = H(P, P') (paper eq. (2))."""
        rng = np.random.default_rng(5)
        p = 1.0 / np.arange(1, 65) ** 1.0
        p /= p.sum()
        u = rng.choice(64, size=50000, p=p).astype(np.uint64)
        syms, counts = np.unique(u, return_counts=True)
        t = build_table(syms, counts, PAPER)
        Hp = table_cross_entropy(t, syms, counts)
        enc = encode_scalar(u, PAPER, [t])
        bps = encoded_bits(enc, PAPER) / u.size
        # within 5% + per-stream constant (o words head + tail padding)
        assert bps <= Hp * 1.05 + (PAPER.o * 32 + 256) / u.size
        assert bps >= Hp * 0.95  # sanity: can't beat cross-entropy

    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def test_property_roundtrip_paper(self, data):
        n = data.draw(st.integers(0, 120))
        nsym = data.draw(st.integers(1, 5000))
        seed = data.draw(st.integers(0, 2 ** 31))
        rng = np.random.default_rng(seed)
        u = rng.integers(0, nsym, size=n).astype(np.uint64)
        t = _table_from(u if n else np.zeros(1, np.uint64), PAPER)
        enc = encode_scalar(u, PAPER, [t])
        assert np.array_equal(decode_scalar(enc, PAPER, [t]), u)

    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def test_property_roundtrip_toy(self, data):
        """Tiny word size stresses the conditional-load machinery."""
        n = data.draw(st.integers(0, 60))
        seed = data.draw(st.integers(0, 2 ** 31))
        rng = np.random.default_rng(seed)
        u = rng.integers(0, 4, size=n).astype(np.uint64)
        t = _table_from(u if n else np.zeros(1, np.uint64), TOY)
        enc = encode_scalar(u, TOY, [t])
        assert np.array_equal(decode_scalar(enc, TOY, [t]), u)


class TestVectorizedLanes:
    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_property_lockstep_equals_scalar(self, data):
        lanes = data.draw(st.integers(1, 24))
        seed = data.draw(st.integers(0, 2 ** 31))
        nsym = data.draw(st.sampled_from([4, 300, 100000]))
        rng = np.random.default_rng(seed)
        us = [rng.integers(0, nsym, size=int(rng.integers(0, 90)))
              .astype(np.uint64) for _ in range(lanes)]
        allu = (np.concatenate(us) if sum(u.size for u in us)
                else np.zeros(1, np.uint64))
        t = _table_from(allu, PAPER)
        pattern = np.zeros(PAPER.l, dtype=np.int64)
        encs = [encode_scalar(u, PAPER, [t], pattern) for u in us]
        sl = interleave_slice_with_pattern(encs, PAPER, pattern, 1)
        out = decode_lanes(sl, PAPER, StackedTables.stack([t]), pattern)
        for i, u in enumerate(us):
            assert np.array_equal(out[i, :u.size], u), f"lane {i}"

    def test_stream_is_fully_consumed(self):
        rng = np.random.default_rng(7)
        us = [rng.integers(0, 30, size=rng.integers(1, 64))
              .astype(np.uint64) for _ in range(16)]
        t = _table_from(np.concatenate(us), PAPER)
        pattern = np.zeros(PAPER.l, dtype=np.int64)
        encs = [encode_scalar(u, PAPER, [t], pattern) for u in us]
        sl = interleave_slice_with_pattern(encs, PAPER, pattern, 1)
        assert sl.stream.size == sum(e.n_words for e in encs)
        assert (sl.stream < PAPER.W).all()


class TestDelta:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2 ** 31))
    def test_roundtrip(self, seed):
        from repro.core.delta import delta_decode_rows, delta_encode_rows
        rng = np.random.default_rng(seed)
        m, n = int(rng.integers(1, 40)), int(rng.integers(1, 200))
        dense = (rng.random((m, n)) < 0.2).astype(np.float64)
        from repro.sparse.formats import CSR
        a = CSR.from_dense(dense)
        d = delta_encode_rows(a.indptr, a.indices)
        assert (d >= 0).all()
        back = delta_decode_rows(a.indptr, d)
        assert np.array_equal(back, a.indices)

    def test_entropy_reduction_on_structure(self):
        """Fig. 4's premise: deltas of structured sparsity have lower
        entropy than raw column indices."""
        from repro.core.delta import delta_encode_rows
        from repro.sparse.random_graphs import stencil_2d
        a = stencil_2d(60)
        h_raw = stream_entropy_bits(a.indices)
        h_delta = stream_entropy_bits(delta_encode_rows(a.indptr, a.indices))
        assert h_delta < 0.6 * h_raw
