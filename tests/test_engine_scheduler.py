"""Scheduler + per-slot position tests for the serving engine.

The regression pinned here: `Engine.step` used to decode every slot at
``pos.max()`` (wrong KV read/write positions once prompt lengths
differ) and `_fill_slots` replayed prompts token-by-token through the
pooled decode, feeding zero tokens through every *other* slot and
overwriting their live KV at those positions (cross-slot cache
corruption on every mid-flight refill). The conformance bar: pooled
decode over mixed-length prompts with mid-flight refills must be
token-identical to running each request alone.
"""

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import get_smoke
from repro.models import api
from repro.serving.engine import AdmissionError, Engine, QueueFullError

MIXED_LENS = (1, 3, 7, 12, 5, 2)     # > slots=4 => mid-flight refills
MAX_NEW = 5


def _params_for(arch, vocab=64, seed=0):
    cfg = get_smoke(arch).with_(vocab=vocab)
    return cfg, api.init_params(cfg, jax.random.PRNGKey(seed))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=n) for n in lens]


def _sequential_outputs(cfg, params, prompts, head=None):
    """Ground truth: each request alone in a slots=1 engine (the same
    engine instance, so slot-reset on refill is exercised too)."""
    eng = Engine(cfg, params, slots=1, max_seq=32, sparse_head=head,
                 metrics=obs.MetricsRegistry())
    out = {}
    for p in prompts:
        r = eng.submit(p, MAX_NEW)
        eng.run_until_drained()
        out[r.rid] = list(r.out)
    return out


class TestMixedLengthConformance:
    """slots=4, prompt lengths {1, 3, 7, 12, ...} with mid-flight
    refills == slots=1 sequential, dense and compressed heads, across
    the transformer and hybrid families."""

    @pytest.fixture(scope="class", params=["smollm-135m", "zamba2-7b"])
    def setup(self, request):
        cfg, params = _params_for(request.param)
        head = Engine.compress_lm_head(cfg, params, sparsity=0.6,
                                       value_bits=5, lane_width=32)
        return cfg, params, head

    @pytest.mark.parametrize("use_sparse_head", [False, True],
                             ids=["dense", "compressed"])
    def test_pooled_equals_sequential(self, setup, use_sparse_head):
        cfg, params, head = setup
        head = head if use_sparse_head else None
        prompts = _prompts(cfg, MIXED_LENS)
        want = _sequential_outputs(cfg, params, prompts, head=head)
        eng = Engine(cfg, params, slots=4, max_seq=32, sparse_head=head,
                     metrics=obs.MetricsRegistry())
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        done = eng.run_until_drained()
        assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
        for r in reqs:
            assert list(r.out) == want[r.rid], (
                f"rid={r.rid} prompt_len={len(r.prompt)}: pooled decode "
                f"diverged from the solo run — cross-slot KV corruption "
                f"or wrong per-slot position")

    def test_mid_flight_refill_does_not_corrupt_neighbor(self, setup):
        """Explicit shape of the old bug: a long request is mid-decode
        when a refill prefills a new request into the neighboring slot;
        the long request's tokens must be unchanged vs running alone."""
        cfg, params, _ = setup
        prompts = _prompts(cfg, (9,), seed=3)
        want = _sequential_outputs(cfg, params, prompts)
        eng = Engine(cfg, params, slots=2, max_seq=32,
                     metrics=obs.MetricsRegistry())
        long_req = eng.submit(prompts[0], 8)
        eng.step()
        eng.step()          # long request is now mid-flight
        rng = np.random.default_rng(4)
        eng.submit(rng.integers(0, cfg.vocab, size=4), 2)
        eng.run_until_drained()
        assert list(long_req.out)[:MAX_NEW] == want[long_req.rid]


class TestAdmissionControl:
    @pytest.fixture(scope="class")
    def setup(self):
        return _params_for("smollm-135m", vocab=32, seed=1)

    def test_empty_prompt_rejected(self, setup):
        cfg, params = setup
        eng = Engine(cfg, params, slots=2, max_seq=16,
                     metrics=obs.MetricsRegistry())
        with pytest.raises(AdmissionError, match="empty prompt"):
            eng.submit(np.array([], dtype=np.int32), 4)
        # a rejected request never enters the queue or the counters
        assert eng.queue == []
        assert eng.metrics.counter("engine.rejections").value == 1
        assert eng.metrics.counter(
            "engine.rejections.empty_prompt").value == 1
        assert eng.metrics.counter(
            "engine.requests_submitted").value == 0

    def test_zero_max_new_tokens_rejected(self, setup):
        cfg, params = setup
        eng = Engine(cfg, params, slots=2, max_seq=16,
                     metrics=obs.MetricsRegistry())
        with pytest.raises(AdmissionError, match="max_new_tokens"):
            eng.submit(np.array([1, 2]), 0)

    def test_over_max_seq_rejected_at_boundary(self, setup):
        """prompt_len + max_new == max_seq is admitted and drains;
        one past is rejected at submit (not a later crash or a silent
        out-of-range KV scatter)."""
        cfg, params = setup
        eng = Engine(cfg, params, slots=1, max_seq=12,
                     metrics=obs.MetricsRegistry())
        with pytest.raises(AdmissionError, match="max_seq"):
            eng.submit(np.arange(9) % cfg.vocab, 4)      # 13 > 12
        r = eng.submit(np.arange(8) % cfg.vocab, 4)      # 12 == 12
        # prove the boundary: positions never reach max_seq mid-run
        max_pos = -1
        while eng.queue or any(s is not None for s in eng.active):
            eng.step()
            max_pos = max(max_pos, int(eng.pos.max()))
        assert r.done and len(r.out) == 4
        # last KV write lands at max_seq - 2 (the post-increment value
        # max_seq - 1 is reset to -1 when the request completes)
        assert max_pos == eng.max_seq - 2

    def test_unbounded_position_walk_is_unreachable(self, setup):
        """The old engine accepted any request and let `pos` walk past
        `max_seq` (out-of-range KV scatter). Every admitted request now
        has prompt_len + max_new <= max_seq, so the defensive overrun
        check in `step` can never fire."""
        cfg, params = setup
        eng = Engine(cfg, params, slots=2, max_seq=10,
                     metrics=obs.MetricsRegistry())
        rng = np.random.default_rng(5)
        for _ in range(4):
            eng.submit(rng.integers(0, cfg.vocab, size=5), 5)
        eng.run_until_drained()      # RuntimeError if a slot overran
        assert int(eng.pos.max()) == -1

    def test_queue_limit_fifo(self, setup):
        cfg, params = setup
        eng = Engine(cfg, params, slots=1, max_seq=16, max_queue=2,
                     metrics=obs.MetricsRegistry())
        rng = np.random.default_rng(6)
        r1 = eng.submit(rng.integers(0, cfg.vocab, size=2), 1)
        r2 = eng.submit(rng.integers(0, cfg.vocab, size=2), 1)
        with pytest.raises(QueueFullError, match="max_queue"):
            eng.submit(rng.integers(0, cfg.vocab, size=2), 1)
        assert eng.metrics.counter(
            "engine.rejections.queue_full").value == 1
        done = eng.run_until_drained()
        # FIFO: admitted requests complete in submission order
        assert [r.rid for r in done] == [r1.rid, r2.rid]
        # queue drained => new submits are admitted again
        eng.submit(rng.integers(0, cfg.vocab, size=2), 1)
        eng.run_until_drained()

    def test_scheduler_metrics(self, setup):
        cfg, params = setup
        eng = Engine(cfg, params, slots=2, max_seq=16,
                     metrics=obs.MetricsRegistry())
        rng = np.random.default_rng(7)
        for _ in range(3):
            eng.submit(rng.integers(0, cfg.vocab, size=3), 2)
        eng.run_until_drained()
        snap = eng.metrics.snapshot()
        assert snap["counters"]["engine.refills_total"] == 3
        assert snap["counters"]["engine.rejections"] == 0
        # per-slot position gauges exist and read -1 once drained
        for s in range(eng.slots):
            assert snap["gauges"][f"engine.slot_pos.{s}"] == -1.0


class TestSampling:
    """greedy=False wires temperature/top-k sampling to a seeded
    per-engine generator (the `greedy` flag used to be stored and never
    read — argmax was hardcoded)."""

    @pytest.fixture(scope="class")
    def setup(self):
        return _params_for("smollm-135m", vocab=48, seed=2)

    def _drain_one(self, cfg, params, **kw):
        eng = Engine(cfg, params, slots=2, max_seq=32,
                     metrics=obs.MetricsRegistry(), **kw)
        r = eng.submit(np.array([1, 2, 3]), 6)
        eng.run_until_drained()
        return list(r.out)

    def test_seeded_sampling_reproduces(self, setup):
        cfg, params = setup
        a = self._drain_one(cfg, params, greedy=False, temperature=0.8,
                            top_k=5, sample_seed=7)
        b = self._drain_one(cfg, params, greedy=False, temperature=0.8,
                            top_k=5, sample_seed=7)
        c = self._drain_one(cfg, params, greedy=False, temperature=0.8,
                            top_k=5, sample_seed=8)
        assert a == b
        assert a != c
        assert all(0 <= t < cfg.vocab for t in a)

    def test_top_k_one_is_greedy(self, setup):
        """top_k=1 truncates the distribution to the argmax — sampling
        must then reproduce the greedy stream exactly, any seed."""
        cfg, params = setup
        greedy = self._drain_one(cfg, params, greedy=True)
        sampled = self._drain_one(cfg, params, greedy=False,
                                  temperature=1.3, top_k=1,
                                  sample_seed=99)
        assert sampled == greedy

    def test_sampling_pooled_with_mixed_lengths(self, setup):
        """The sampling path composes with per-slot positions: a pooled
        mixed-length drain under greedy=False completes and stays
        reproducible under the same seed."""
        cfg, params = setup
        outs = []
        for _ in range(2):
            eng = Engine(cfg, params, slots=3, max_seq=32, greedy=False,
                         temperature=0.9, top_k=8, sample_seed=11,
                         metrics=obs.MetricsRegistry())
            reqs = [eng.submit(p, 4)
                    for p in _prompts(cfg, (2, 6, 9, 4), seed=8)]
            eng.run_until_drained()
            outs.append([list(r.out) for r in reqs])
        assert outs[0] == outs[1]


class TestSparseLinearMetricsIsolation:
    """`SparseLinear.apply` used to record into the process default
    registry unconditionally, ignoring the `metrics=` isolation the
    Engine offers — dense-vs-compressed benchmark runs
    cross-contaminated each other's `serving.*` instruments."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg, params = _params_for("smollm-135m", vocab=48, seed=3)
        head = Engine.compress_lm_head(cfg, params, sparsity=0.6,
                                       value_bits=5, lane_width=32)
        return cfg, params, head

    def test_apply_threads_registry(self, setup):
        _, _, head = setup
        reg_a, reg_b = obs.MetricsRegistry(), obs.MetricsRegistry()
        x = np.ones((2, head.d_in), dtype=np.float32)
        head.apply(x, metrics=reg_a)
        head.apply(x, metrics=reg_b)
        head.apply(x, metrics=reg_b)
        assert reg_a.counter("serving.sparse_apply_calls").value == 1
        assert reg_b.counter("serving.sparse_apply_calls").value == 2
        assert reg_b.histogram("serving.apply_batch").count == 2

    def test_engine_isolates_head_metrics(self, setup):
        """Two engines sharing ONE compressed head, each with its own
        registry: every head record lands in its engine's registry and
        the process default sees none of them."""
        cfg, params, head = setup
        default_before = obs.default_registry().counter(
            "serving.sparse_apply_calls").value
        regs = [obs.MetricsRegistry(), obs.MetricsRegistry()]
        rng = np.random.default_rng(9)
        for reg in regs:
            eng = Engine(cfg, params, slots=2, max_seq=16,
                         sparse_head=head, metrics=reg)
            eng.submit(rng.integers(0, cfg.vocab, size=3), 2)
            eng.run_until_drained()
        for reg in regs:
            assert reg.counter("serving.sparse_apply_calls").value > 0
        assert obs.default_registry().counter(
            "serving.sparse_apply_calls").value == default_before

    def test_default_registry_still_default(self, setup):
        """Un-threaded callers keep the old behavior: records land in
        the process default registry."""
        _, _, head = setup
        before = obs.default_registry().counter(
            "serving.sparse_apply_calls").value
        head.apply(np.ones((1, head.d_in), dtype=np.float32))
        assert obs.default_registry().counter(
            "serving.sparse_apply_calls").value == before + 1


class TestEncdecPerSlot:
    """The encdec family threads the same per-slot position vector
    (cross-attention reads the per-slot memory; self-attention KV
    scatters at pos[s])."""

    def test_mixed_length_drain(self):
        cfg, params = _params_for("seamless-m4t-large-v2", vocab=48,
                                  seed=4)
        prompts = _prompts(cfg, (2, 5, 3), seed=10)
        want = _sequential_outputs(cfg, params, prompts)
        eng = Engine(cfg, params, slots=2, max_seq=32,
                     metrics=obs.MetricsRegistry())
        reqs = [eng.submit(p, MAX_NEW) for p in prompts]
        eng.run_until_drained()
        for r in reqs:
            assert list(r.out) == want[r.rid]
