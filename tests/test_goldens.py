"""Golden bitstream vectors: frozen encoded CSR-dtANS outputs.

Compression-ratio tests only notice encoder drift when it changes a
*size*; a change to table layout, slot assignment, escape handling or
interleave order that keeps sizes identical would sail through while
silently breaking every stored bitstream in the wild. These tests pin
the exact encoded words (streams, escape streams, offsets, table
layout) of small deterministic matrices.

If an encoder change is INTENTIONAL (a format-version bump), regenerate
with ``REPRO_REGEN_GOLDENS=1 pytest tests/test_goldens.py`` and review
the golden diff like any other code change.
"""

import hashlib
import json
import os

import numpy as np
import pytest

from repro.core.bcsr_dtans import encode_bcsr_matrix
from repro.core.csr_dtans import decode_matrix, encode_matrix
from repro.core.params import TOY
from repro.core.rgcsr_dtans import encode_rgcsr_matrix
from repro.sparse.bcsr import block_fill_csr
from repro.sparse.formats import CSR
from repro.sparse.random_graphs import stencil_2d

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


def _quantized_f32() -> CSR:
    """Escape-light float32 matrix with a fixed value codebook."""
    rng = np.random.default_rng(42)
    d = np.round(rng.standard_normal((12, 18)) * 2) / 4
    d[rng.random(d.shape) < 0.55] = 0
    return CSR.from_dense(d.astype(np.float32))


def _escape_heavy_f64() -> CSR:
    """Raw float64 mantissas: every value escapes the table."""
    rng = np.random.default_rng(43)
    d = rng.standard_normal((9, 11))
    d[rng.random(d.shape) < 0.5] = 0
    return CSR.from_dense(d)


CASES = {
    # name -> (matrix factory, encode kwargs). The escape case uses the
    # paper's worked-example TOY parameters (K = 8): with production
    # K = 4096 every value of a small golden fits in-table, and goldens
    # must stay small, so TOY is the only way to pin escape handling.
    "stencil6_f64_w32_shared": (lambda: stencil_2d(6),
                                dict(lane_width=32, shared_table=True)),
    "stencil6_f64_w8_split": (lambda: stencil_2d(6),
                              dict(lane_width=8, shared_table=False)),
    "quant_f32_w16_shared": (_quantized_f32,
                             dict(lane_width=16, shared_table=True)),
    "escapes_f64_w4_toy": (_escape_heavy_f64,
                           dict(lane_width=4, shared_table=True,
                                params=TOY)),
    "rgcsr_stencil6_f64_G8": (lambda: stencil_2d(6),
                              dict(group_size=8, shared_table=True)),
    "bcsr_stencil6_f64_B2x2": (lambda: stencil_2d(6),
                               dict(block_shape=(2, 2),
                                    shared_table=True)),
}


def _encode(name):
    factory, kw = CASES[name]
    a = factory()
    if "block_shape" in kw:
        return block_fill_csr(a, kw["block_shape"]), \
            encode_bcsr_matrix(a, **kw)
    if "group_size" in kw:
        return a, encode_rgcsr_matrix(a, **kw)
    return a, encode_matrix(a, **kw)


def _table_digest(t) -> str:
    """SHA-1 over the full slot layout (dtype-pinned): any reordering,
    multiplicity or escape-slot change flips the digest without storing
    K x 4 arrays in the golden file."""
    h = hashlib.sha1()
    for arr, dt in ((t.slot_symbol, np.uint64), (t.slot_digit, np.int64),
                    (t.slot_base, np.int64), (t.slot_is_esc, np.uint8)):
        h.update(np.ascontiguousarray(np.asarray(arr).astype(dt))
                 .tobytes())
    return h.hexdigest()


def _payload(mat) -> dict:
    """Every byte the format owns: streams/offsets verbatim, the K-slot
    table layouts as digests (JSON-stable)."""
    out = {
        "nbytes": int(mat.nbytes),
        "lane_width": int(mat.lane_width),
        "shape": list(mat.shape),
        "dtype": np.dtype(mat.dtype).name,
        "row_nnz": mat.row_nnz.tolist(),
        "stream": mat.stream.tolist(),
        "slice_offsets": mat.slice_offsets.tolist(),
        "esc_streams": [e.tolist() for e in mat.esc_streams],
        "esc_offsets": mat.esc_offsets.tolist(),
        "pattern": mat.pattern.tolist(),
        "tables": [{
            "layout_sha1": _table_digest(t),
            "esc_first": int(t.esc_first),
            "esc_base": int(t.esc_base),
            "esc_raw_bits": int(t.esc_raw_bits),
            "used_slots": int(t.used_slots),
            "K": int(t.K), "M": int(t.M),
        } for t in mat.tables],
    }
    if hasattr(mat, "group_size"):
        out["group_size"] = int(mat.group_size)
    if hasattr(mat, "block_shape"):
        out["block_shape"] = list(mat.block_shape)
        out["n_blocks"] = int(mat.n_blocks)
    return out


@pytest.mark.parametrize("name", list(CASES), ids=list(CASES))
def test_golden_bitstream(name):
    a, mat = _encode(name)
    got = _payload(mat)
    path = os.path.join(GOLDEN_DIR, f"bitstream_{name}.json")
    if os.environ.get("REPRO_REGEN_GOLDENS"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
    with open(path) as f:
        want = json.load(f)
    assert got == want, (
        f"encoded bitstream for {name!r} drifted from the golden vector; "
        f"if intentional, regenerate with REPRO_REGEN_GOLDENS=1 and "
        f"review the diff")
    # goldens must stay decodable, not just frozen
    dec = decode_matrix(mat)
    assert np.array_equal(dec.indices, a.indices)
    assert np.array_equal(dec.values, a.values)


def test_goldens_cover_escape_and_table_modes():
    """The golden set must keep covering: escapes present, escape-free,
    shared and split tables, and the group-aligned variant."""
    encs = {name: _encode(name)[1] for name in CASES}
    assert any(m.esc_count_by_domain.sum() > 0 for m in encs.values())
    assert any(m.esc_count_by_domain.sum() == 0 for m in encs.values())
    assert any(len(m.tables) == 1 for m in encs.values())
    assert any(len(m.tables) == 2 for m in encs.values())
    assert any(hasattr(m, "group_size") for m in encs.values())
    assert any(hasattr(m, "block_shape") for m in encs.values())
