"""Pallas kernel validation: interpret-mode vs pure-jnp oracle vs numpy gold.

Sweeps shapes, dtypes, lane widths, table sharing and escape pressure for
each kernel, per the kernel-validation contract (assert_allclose against
ref.py oracles).
"""

import numpy as np
import pytest

from repro.core.csr_dtans import encode_matrix, spmv_gold
from repro.kernels import ops
from repro.kernels.pack import pack_matrix
from repro.kernels.ref import decode_ref, spmv_ref
from repro.kernels.sell_spmv import pack_sell, sell_spmv_ref
from repro.sparse.formats import CSR
from repro.sparse.random_graphs import banded, erdos_renyi, stencil_2d


def _rng(seed=0):
    return np.random.default_rng(seed)


def _random_csr(m, n, density, dtype, seed, quantized=False):
    rng = _rng(seed)
    d = rng.standard_normal((m, n)).astype(dtype)
    if quantized:  # low-entropy values (compressible, no escapes)
        d = np.round(d * 2) / 2
    d[rng.random((m, n)) >= density] = 0
    return CSR.from_dense(d)


_CASES = [
    # (name, matrix factory, lane_width, shared_table)
    ("stencil-f64", lambda: stencil_2d(16), 32, True),
    ("stencil-f64-2tab", lambda: stencil_2d(16), 32, False),
    ("er-f64", lambda: erdos_renyi(200, 6, _rng(1)), 128, True),
    ("banded-f32",
     lambda: (lambda b: CSR(b.indptr, b.indices,
                            b.values.astype(np.float32), b.shape))(
         banded(150, 4)), 64, True),
    ("random-f64-escapes", lambda: _random_csr(90, 70, 0.3, np.float64, 2),
     16, True),
    ("random-f32-escapes", lambda: _random_csr(90, 70, 0.3, np.float32, 3),
     16, True),
    ("quantized-f32", lambda: _random_csr(120, 80, 0.2, np.float32, 4,
                                          quantized=True), 32, True),
    ("tall-skinny", lambda: _random_csr(400, 9, 0.5, np.float64, 5), 128,
     True),
    ("wide", lambda: _random_csr(9, 400, 0.4, np.float64, 6), 8, True),
    ("empty-rows", lambda: CSR.from_dense(
        np.diag(np.r_[np.zeros(10), np.arange(1.0, 11.0)])), 16, True),
]


@pytest.fixture(scope="module", params=_CASES, ids=[c[0] for c in _CASES])
def case(request):
    name, factory, lw, shared = request.param
    a = factory()
    mat = encode_matrix(a, lane_width=lw, shared_table=shared)
    return name, a, mat, pack_matrix(mat)


class TestDtansSpmvKernel:
    def test_kernel_vs_gold(self, case):
        _, a, mat, pm = case
        rng = _rng(10)
        x = rng.standard_normal(a.shape[1]).astype(a.values.dtype)
        y_k = np.asarray(ops.spmv(pm, x))
        y_g = spmv_gold(mat, x)
        rtol = 1e-12 if a.values.dtype == np.float64 else 1e-4
        np.testing.assert_allclose(y_k, y_g, rtol=rtol, atol=1e-6)

    def test_kernel_vs_ref_oracle(self, case):
        _, a, _, pm = case
        rng = _rng(11)
        x = rng.standard_normal(a.shape[1]).astype(a.values.dtype)
        np.testing.assert_allclose(np.asarray(ops.spmv(pm, x)),
                                   np.asarray(spmv_ref(pm, x)),
                                   rtol=1e-12, atol=1e-30)

    def test_accumulate_y(self, case):
        _, a, _, pm = case
        rng = _rng(12)
        x = rng.standard_normal(a.shape[1]).astype(a.values.dtype)
        y0 = rng.standard_normal(a.shape[0]).astype(a.values.dtype)
        got = np.asarray(ops.spmv(pm, x, y0))
        rtol = 1e-12 if a.values.dtype == np.float64 else 1e-4
        np.testing.assert_allclose(got, a.to_dense() @ x + y0, rtol=rtol,
                                   atol=1e-6)


class TestDtansDecodeKernel:
    def test_kernel_vs_ref_oracle(self, case):
        _, _, _, pm = case
        ck, vk = ops.decode(pm)
        cr, vr = decode_ref(pm)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), rtol=0)

    def test_reconstructs_matrix(self, case):
        _, a, mat, pm = case
        cols, vals = ops.decode(pm)
        cols, vals = np.asarray(cols), np.asarray(vals)
        dense = np.zeros(a.shape, dtype=a.values.dtype)
        m = a.shape[0]
        L = pm.lane_width
        for i in range(m):
            s, lane = divmod(i, L)
            sel = cols[s, lane] >= 0
            dense[i, cols[s, lane][sel]] = vals[s, lane][sel]
        np.testing.assert_array_equal(dense, a.to_dense())


class TestSellKernel:
    @pytest.mark.parametrize("dtype", [np.float64, np.float32])
    @pytest.mark.parametrize("lw", [8, 128])
    def test_vs_dense_and_ref(self, dtype, lw):
        rng = _rng(20)
        a = _random_csr(130, 75, 0.15, dtype, 21)
        ps = pack_sell(a, lane_width=lw)
        x = rng.standard_normal(75).astype(dtype)
        y_k = np.asarray(ops.sell_spmv(ps, x))
        y_r = np.asarray(sell_spmv_ref(ps.indices, ps.values, x)
                         ).reshape(-1)[:130]
        rtol = 1e-12 if dtype == np.float64 else 1e-5
        np.testing.assert_allclose(y_k, y_r, rtol=rtol)
        np.testing.assert_allclose(y_k, a.to_dense() @ x, rtol=rtol,
                                   atol=1e-5 if dtype == np.float32 else 0)
