"""Tests for repro.autotune.measure: the kernel-timing harness, the
measured-refinement path of select(), MachineModel calibration, and
named machine profiles (persistence + cache-key invalidation)."""

import numpy as np
import pytest

from repro.autotune import (DecisionCache, V5E, MachineModel, calibrate,
                            clear_memo, dtans_config_name, list_profiles,
                            load_profile, measure_named,
                            parse_config_name, rgcsr_config_name,
                            rgcsr_dtans_config_name, save_profile, select,
                            spmv_runner, time_kernel)
from repro.sparse.formats import CSR
from repro.sparse.random_graphs import banded, erdos_renyi


def _f32(a: CSR) -> CSR:
    return CSR(a.indptr, a.indices, a.values.astype(np.float32), a.shape)


def _small(seed: int = 2) -> CSR:
    return _f32(erdos_renyi(220, 5, np.random.default_rng(seed)))


class TestHarness:
    def test_time_kernel_counts_calls_and_is_positive(self):
        import jax.numpy as jnp
        calls = []

        def fn():
            calls.append(1)
            return jnp.zeros(())

        t = time_kernel(fn, warmup=2, repeats=3)
        assert len(calls) == 5
        assert t > 0.0

    def test_time_kernel_rejects_zero_repeats(self):
        with pytest.raises(ValueError):
            time_kernel(lambda: None, repeats=0)

    @pytest.mark.parametrize("fmt,kw", [
        ("csr", {}),
        ("coo", {}),
        ("dense", {}),
        ("sell", {}),
        ("sell", {"slice_height": 16}),
        ("rgcsr", {"group_size": 8}),
        ("dtans", {"lane_width": 32}),
        ("rgcsr_dtans", {"group_size": 8}),
        ("bcsr", {"block_shape": (4, 4)}),
        ("bcsr_dtans", {"block_shape": (2, 2)}),
    ])
    def test_runner_output_matches_dense(self, fmt, kw):
        """Every registered runner computes y = A x — a timing harness
        that measures a wrong kernel measures nothing."""
        a = _small()
        x = np.random.default_rng(0).standard_normal(
            a.shape[1]).astype(np.float32)
        got = np.asarray(spmv_runner(a, fmt, x=x, **kw)())
        want = a.to_dense() @ x
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_unknown_format_raises(self):
        with pytest.raises(ValueError):
            spmv_runner(_small(), "blocked_ellpack")

    def test_artifacts_memoize_encodes(self):
        a = _small()
        arts: dict = {}
        spmv_runner(a, "dtans", lane_width=32, artifacts=arts)
        mat = arts[("dtans", 32, True)]
        spmv_runner(a, "dtans", lane_width=32, artifacts=arts)
        assert arts[("dtans", 32, True)] is mat

    def test_parse_config_name_roundtrip(self):
        assert parse_config_name("csr") == {"fmt": "csr"}
        assert parse_config_name("sell") == {"fmt": "sell"}
        assert parse_config_name("dense") == {"fmt": "dense"}
        assert parse_config_name(dtans_config_name(32, False)) == {
            "fmt": "dtans", "lane_width": 32, "shared_table": False}
        assert parse_config_name(rgcsr_config_name(8)) == {
            "fmt": "rgcsr", "group_size": 8}
        assert parse_config_name(rgcsr_dtans_config_name(16, True)) == {
            "fmt": "rgcsr_dtans", "group_size": 16, "shared_table": True}
        assert parse_config_name("bcsr[B=4x4]") == {
            "fmt": "bcsr", "block_shape": (4, 4)}
        assert parse_config_name("bcsr_dtans[B=2x2,shared]") == {
            "fmt": "bcsr_dtans", "block_shape": (2, 2),
            "shared_table": True}
        assert parse_config_name("sell[C=16]") == {
            "fmt": "sell", "slice_height": 16}
        with pytest.raises(ValueError):
            parse_config_name("alphasparse")
        with pytest.raises(ValueError):
            parse_config_name("sell[G=8]")     # knob of another format

    def test_measure_named(self):
        t = measure_named(_small(), "sell", warmup=0, repeats=1)
        assert t > 0.0

    @pytest.mark.parametrize("fmt,kw", [
        ("csr", {}),
        ("dense", {}),
        ("sell", {"slice_height": 16}),
        ("rgcsr", {"group_size": 8}),
        ("dtans", {"lane_width": 32}),
        ("bcsr", {"block_shape": (4, 4)}),
    ])
    def test_batched_runner_output_matches_dense(self, fmt, kw):
        """spmv_runner(batch=B) drives the format's multi-RHS path
        (fused SpMM kernels / batched scatter-add / dense A @ X) and
        must compute Y = A X."""
        a = _small()
        X = np.random.default_rng(1).standard_normal(
            (a.shape[1], 4)).astype(np.float32)
        got = np.asarray(spmv_runner(a, fmt, x=X, batch=4, **kw)())
        want = a.to_dense() @ X
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_batched_runner_default_x_shape(self):
        a = _small()
        got = np.asarray(spmv_runner(a, "sell", batch=3)())
        assert got.shape == (a.shape[0], 3)

    def test_batched_runner_rejects_shape_mismatch(self):
        a = _small()
        x1 = np.ones(a.shape[1], dtype=np.float32)
        with pytest.raises(ValueError, match="batch=3 needs x of shape"):
            spmv_runner(a, "sell", x=x1, batch=3)
        with pytest.raises(ValueError, match="batch must be >= 1"):
            spmv_runner(a, "sell", batch=0)

    def test_measure_named_batched(self):
        t = measure_named(_small(), "sell", batch=4, warmup=0,
                          repeats=1)
        assert t > 0.0


class TestMeasuredSelect:
    def test_measure_requires_budget(self):
        with pytest.raises(ValueError):
            select(_small(), measure=True,
                   cache=DecisionCache(path=None))

    def test_measured_decision_fields(self):
        a = _small(3)
        clear_memo()
        dec = select(a, budget=2, measure=True, measure_warmup=0,
                     measure_repeats=1, cache=DecisionCache(path=None))
        assert dec.measured_time is not None and dec.measured_time > 0
        assert dec.refined
        # The winner leads the leaderboard and carries its measurement
        # in the 4th slot; measured rows rank by wall clock.
        assert dec.leaderboard[0][0] == dec.config_name
        assert dec.leaderboard[0][3] == dec.measured_time
        measured_rows = [r for r in dec.leaderboard if r[3] is not None]
        assert len(measured_rows) == 2
        assert measured_rows[0][3] <= measured_rows[1][3]

    def test_measured_select_at_batch(self):
        """measure=True at batch=B times the BATCHED runners (the
        kernels serving actually runs at that pool size)."""
        a = _small(6)
        clear_memo()
        dec = select(a, budget=2, measure=True, measure_warmup=0,
                     measure_repeats=1, batch=4,
                     cache=DecisionCache(path=None))
        assert dec.batch == 4
        assert dec.measured_time is not None and dec.measured_time > 0

    def test_measured_and_modeled_key_separately(self):
        """A measured decision must never be served for a modeled query
        (different currencies) — distinct cache keys."""
        a = _small(4)
        cache = DecisionCache(path=None)
        clear_memo()
        select(a, budget=2, cache=cache)
        select(a, budget=2, measure=True, measure_warmup=0,
               measure_repeats=1, cache=cache)
        assert len(cache) == 2

    def test_measured_decision_cached_without_remeasure(self, monkeypatch):
        a = _small(5)
        cache = DecisionCache(path=None)
        clear_memo()
        d1 = select(a, budget=2, measure=True, measure_warmup=0,
                    measure_repeats=1, cache=cache)
        from repro.autotune import measure as measure_mod

        def boom(*a, **kw):
            raise AssertionError("cache hit must not re-measure")

        monkeypatch.setattr(measure_mod, "measure_candidate", boom)
        clear_memo()                      # force the disk-cache path
        d2 = select(a, budget=2, measure=True, measure_warmup=0,
                    measure_repeats=1, cache=cache)
        assert d2 == d1
        assert d2.measured_time == d1.measured_time


class TestCalibration:
    def _mats(self):
        rng = np.random.default_rng(6)
        return {"er": _f32(erdos_renyi(260, 5, rng)),
                "banded": _f32(banded(500, 4))}

    def test_fit_shrinks_error_and_changes_signature(self):
        res = calibrate(self._mats(), warmup=0, repeats=1)
        # In-sample, the fitted constants must beat the hand-tuned
        # defaults (the modeled currency is orders of magnitude off the
        # interpret-mode harness; calibration's whole job is closing
        # that gap).
        assert res.err_after < res.err_before
        assert res.model.signature() != V5E.signature()
        assert res.model.name == "v5e-calibrated"
        # Fitted constants stay physical.
        assert res.model.hbm_bw > 0
        assert res.model.cache_bw >= res.model.hbm_bw
        assert res.model.spmv_ops_per_elem > 0
        assert res.model.row_seq_penalty >= 1.0
        # Fixed datasheet terms are inherited, not fit.
        assert res.model.cache_bytes == V5E.cache_bytes
        assert res.model.vpu_rate == V5E.vpu_rate

    def test_points_and_dict_shape(self):
        res = calibrate(self._mats(), warmup=0, repeats=1)
        # matrices x configs x batches (the B=1 and B=8 design rows)
        assert len(res.points) == 2 * 5 * 2
        assert {p.batch for p in res.points} == {1, 8}
        d = res.to_dict()
        assert set(d) == {"model", "err_before", "err_after", "points"}
        assert all(np.isfinite(p.modeled_after) for p in res.points)

    def test_calibration_work_matches_packed_slice_height(self):
        """Bugfix regression: the calibration design row must charge the
        lock-step work of the slice height the SELL candidate was
        actually packed with (from its knobs via the registry), not a
        hard-coded module constant."""
        from repro.autotune import fingerprint
        a = self._mats()["er"]
        fp = fingerprint(a)
        for cfg, width in (("sell", 32), ("sell[C=16]", 16),
                           ("sell[C=8]", 8)):
            res = calibrate({"er": a}, configs=(cfg,), batches=(1,),
                            warmup=0, repeats=1)
            (p,) = res.points
            assert p.config_name == cfg
            assert p.work_elems == fp.lockstep(width)

    def test_calibrated_model_drives_select(self):
        res = calibrate(self._mats(), warmup=0, repeats=1)
        cache = DecisionCache(path=None)
        a = _small(7)
        clear_memo()
        d1 = select(a, cache=cache)
        d2 = select(a, machine=res.model, cache=cache)
        assert len(cache) == 2       # distinct keys: stale-proof
        assert d2.machine == res.model.name
        assert d1.machine == V5E.name


class TestProfiles:
    def _model(self, name="prof-test"):
        return MachineModel(name=name, hbm_bw=1e11, cache_bw=4e11,
                            cache_bytes=1e6, vpu_rate=1e12,
                            decode_ops_per_nnz=20.0,
                            spmv_ops_per_elem=2.0, row_seq_penalty=4.0)

    def test_save_load_roundtrip(self, tmp_path):
        p = tmp_path / "profiles.json"
        m = self._model()
        assert save_profile(m, meta={"src": "test"}, path=p) == str(p)
        assert load_profile("prof-test", path=p) == m
        entry = list_profiles(p)["prof-test"]
        assert entry["meta"] == {"src": "test"}
        assert entry["signature"] == m.signature()

    def test_saves_merge_per_name(self, tmp_path):
        p = tmp_path / "profiles.json"
        save_profile(self._model("a"), path=p)
        save_profile(self._model("b"), path=p)
        assert set(list_profiles(p)) == {"a", "b"}

    def test_load_missing_raises(self, tmp_path):
        with pytest.raises(KeyError):
            load_profile("nope", path=tmp_path / "absent.json")
        save_profile(self._model("a"), path=tmp_path / "p.json")
        with pytest.raises(KeyError):
            load_profile("nope", path=tmp_path / "p.json")

    def test_save_strict_on_unwritable_path(self, tmp_path, monkeypatch):
        """Unlike the decision cache (which degrades to memory-only),
        losing a freshly fitted profile must be loud. chmod tricks don't
        work under root CI, so fail the atomic rename itself."""
        def boom(src, dst):
            raise OSError("simulated unwritable path")

        from repro.autotune import cache as cache_mod
        monkeypatch.setattr(cache_mod.os, "replace", boom)
        with pytest.raises(OSError, match="simulated"):
            save_profile(self._model(), path=tmp_path / "p.json")

    def test_save_strict_on_unreadable_existing_file(self, tmp_path,
                                                     monkeypatch):
        """A momentarily unreadable profile file must NOT be treated as
        empty under strict mode — that would atomically replace it with
        only the new profile, silently discarding every saved one."""
        import builtins
        p = tmp_path / "profiles.json"
        save_profile(self._model("keep-me"), path=p)
        real_open = builtins.open

        def flaky_open(file, *a, **kw):
            if str(file) == str(p):
                raise PermissionError("simulated EACCES")
            return real_open(file, *a, **kw)

        monkeypatch.setattr(builtins, "open", flaky_open)
        with pytest.raises(OSError, match="EACCES"):
            save_profile(self._model("new"), path=p)
        monkeypatch.undo()
        assert set(list_profiles(p)) == {"keep-me"}

    def test_from_dict_rejects_unknown_fields(self):
        with pytest.raises(ValueError):
            MachineModel.from_dict({"name": "x", "warp_size": 32})

    def test_env_var_overrides_default_path(self, monkeypatch, tmp_path):
        from repro.autotune import default_profiles_path
        monkeypatch.setenv("REPRO_MACHINE_PROFILES",
                           str(tmp_path / "env.json"))
        assert default_profiles_path() == str(tmp_path / "env.json")

    def test_profile_change_invalidates_decisions(self, tmp_path):
        """The ISSUE's acceptance bar: a fitted profile round-trips
        through save/load and its signature keys the decision cache, so
        decisions made under other constants are never served."""
        p = tmp_path / "profiles.json"
        save_profile(self._model(), path=p)
        loaded = load_profile("prof-test", path=p)
        cache = DecisionCache(path=None)
        a = _small(8)
        clear_memo()
        select(a, cache=cache)                     # default V5E
        select(a, machine=loaded, cache=cache)     # fitted profile
        assert len(cache) == 2
        keys = list(cache._load())
        assert any(loaded.signature() in k for k in keys)
        assert any(V5E.signature() in k for k in keys)
