"""Observability layer tests: metrics registry exactness, trace
nesting, engine/autotune instrumentation wiring, timing dispersion."""

import json
import math
import warnings

import numpy as np
import pytest

from repro import obs
from repro.autotune import (DecisionCache, TimingSample, calibrate,
                            clear_memo, select, time_kernel)
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.sparse.formats import CSR
from repro.sparse.random_graphs import erdos_renyi


def _small(seed: int = 2) -> CSR:
    a = erdos_renyi(220, 5, np.random.default_rng(seed))
    return CSR(a.indptr, a.indices, a.values.astype(np.float32), a.shape)


class TestHistogram:
    @pytest.mark.parametrize("samples", [
        [1.0], [3.0, 1.0, 2.0], list(range(100)),
        list(np.random.default_rng(0).standard_normal(512)),
        list(np.random.default_rng(1).lognormal(size=333)),
    ])
    def test_quantiles_match_numpy_while_bounded(self, samples):
        h = Histogram("t")
        for s in samples:
            h.observe(s)
        for q in (0.0, 0.25, 0.5, 0.95, 0.99, 1.0):
            assert h.quantile(q) == pytest.approx(
                float(np.percentile(np.asarray(samples, float), 100 * q,
                                    method="linear")), rel=0, abs=0)

    def test_reservoir_bounded_with_exact_aggregates(self):
        h = Histogram("t", capacity=8)
        xs = np.random.default_rng(3).uniform(0, 10, size=200)
        for x in xs:
            h.observe(x)
        # Reservoir stays bounded; count/total/min/max stay exact.
        assert len(h._samples) == 8
        assert h.count == 200
        assert h.total == pytest.approx(xs.sum())
        assert h.min == xs.min() and h.max == xs.max()
        # Quantiles remain sane (within observed range) after overflow.
        assert xs.min() <= h.quantile(0.5) <= xs.max()

    def test_reservoir_deterministic_across_runs(self):
        def fill():
            h = Histogram("same-name", capacity=16)
            for i in range(500):
                h.observe(float(i))
            return sorted(h._samples)
        assert fill() == fill()

    def test_empty_histogram(self):
        h = Histogram("t")
        assert math.isnan(h.quantile(0.5))
        assert math.isnan(h.mean)
        assert h.snapshot()["count"] == 0

    def test_bad_args(self):
        with pytest.raises(ValueError):
            Histogram("t", capacity=0)
        with pytest.raises(ValueError):
            Histogram("t").quantile(1.5)


class TestRegistry:
    def test_get_or_create_identity(self):
        r = MetricsRegistry()
        assert r.counter("a") is r.counter("a")
        assert r.gauge("g") is r.gauge("g")
        assert r.histogram("h") is r.histogram("h")

    def test_snapshot_is_detached_copy(self):
        r = MetricsRegistry()
        r.counter("c").add(2)
        r.gauge("g").set(7.5)
        r.histogram("h").observe(1.0)
        snap = r.snapshot()
        r.counter("c").add(100)
        r.gauge("g").set(0.0)
        r.histogram("h").observe(99.0)
        # The snapshot keeps the values from snapshot time...
        assert snap["counters"]["c"] == 2
        assert snap["gauges"]["g"] == 7.5
        assert snap["histograms"]["h"]["count"] == 1
        # ...is JSON-serializable, and mutating it leaves the registry
        # untouched.
        json.dumps(snap)
        snap["counters"]["c"] = -1
        assert r.counter("c").value == 102

    def test_null_registry_noops(self):
        obs.NULL.counter("x").add(5)
        obs.NULL.gauge("x").set(5)
        obs.NULL.histogram("x").observe(5)
        assert obs.NULL.counter("x").value == 0
        assert obs.NULL.histogram("x").count == 0
        assert obs.NULL.snapshot() == {"counters": {}, "gauges": {},
                                       "histograms": {}}

    def test_isolated_registries_dont_share(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.counter("c").add(1)
        assert b.counter("c").value == 0


class TestTrace:
    def test_span_nesting_in_jsonl(self, tmp_path):
        p = tmp_path / "trace.jsonl"
        obs.configure_trace(p)
        try:
            assert obs.trace_active()
            assert obs.trace_path() == str(p)
            with obs.span("outer", k="v") as outer_id:
                obs.event("mark", x=1)
                with obs.span("inner") as inner_id:
                    assert inner_id != outer_id
        finally:
            obs.configure_trace(None)
        recs = [json.loads(line) for line in p.read_text().splitlines()]
        by_name = {r["name"]: r for r in recs}
        assert len(recs) == 3
        # Children close (and serialize) before parents; parent ids
        # stitch the tree back together.
        assert [r["name"] for r in recs] == ["mark", "inner", "outer"]
        assert by_name["outer"]["parent"] is None
        assert by_name["inner"]["parent"] == by_name["outer"]["id"]
        assert by_name["mark"]["parent"] == by_name["outer"]["id"]
        assert by_name["outer"]["k"] == "v"
        assert by_name["mark"]["type"] == "event"
        assert by_name["inner"]["dur_s"] >= 0.0

    def test_span_records_error_and_propagates(self, tmp_path):
        p = tmp_path / "trace.jsonl"
        obs.configure_trace(p)
        try:
            with pytest.raises(RuntimeError):
                with obs.span("boom"):
                    raise RuntimeError("x")
        finally:
            obs.configure_trace(None)
        (rec,) = [json.loads(line) for line in p.read_text().splitlines()]
        assert rec["error"] == "RuntimeError"

    def test_disabled_path_yields_none(self):
        obs.configure_trace(None)
        assert not obs.trace_active()
        with obs.span("off") as sid:
            assert sid is None
        obs.event("off")      # must not raise


class TestEngineMetrics:
    @pytest.fixture(scope="class")
    def drained(self):
        import jax

        from repro.configs import get_smoke
        from repro.models import api
        from repro.serving.engine import Engine
        cfg = get_smoke("smollm-135m").with_(vocab=32)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        reg = MetricsRegistry()
        eng = Engine(cfg, params, slots=2, max_seq=32, metrics=reg)
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(0, 32, size=3), 3)
                for _ in range(3)]
        done = eng.run_until_drained()
        return reg, eng, reqs, done

    def test_step_metrics_after_drain(self, drained):
        reg, eng, reqs, _ = drained
        snap = reg.snapshot()
        c, h = snap["counters"], snap["histograms"]
        assert c["engine.requests_submitted"] == 3
        assert c["engine.requests_completed"] == 3
        assert c["engine.tokens_total"] == sum(len(r.out) for r in reqs)
        assert c["engine.steps_total"] == h["engine.step_s"]["count"] > 0
        for name in ("engine.step_s", "engine.decode_s",
                     "engine.refill_s", "engine.prefill_s"):
            assert h[name]["min"] >= 0.0
        # step wall time bounds its decode component
        assert h["engine.step_s"]["p50"] >= h["engine.decode_s"]["min"]
        occ = h["engine.occupancy"]
        assert 0.0 < occ["min"] and occ["max"] <= 1.0
        assert snap["gauges"]["engine.queue_depth"] == 0

    def test_latency_timestamps_and_histograms(self, drained):
        reg, _, reqs, _ = drained
        h = reg.snapshot()["histograms"]
        for r in reqs:
            assert r.t_submit is not None
            assert r.t_first is not None and r.t_first >= r.t_submit
            assert r.t_done is not None and r.t_done >= r.t_first
        assert h["engine.ttft_s"]["count"] == 3
        assert h["engine.e2e_s"]["count"] == 3
        assert h["engine.e2e_s"]["max"] >= h["engine.ttft_s"]["min"]


class TestDrainTruncation:
    @pytest.fixture(scope="class")
    def engine_factory(self):
        import jax

        from repro.configs import get_smoke
        from repro.models import api
        from repro.serving.engine import Engine
        cfg = get_smoke("smollm-135m").with_(vocab=32)
        params = api.init_params(cfg, jax.random.PRNGKey(1))

        def make():
            return Engine(cfg, params, slots=2, max_seq=32,
                          metrics=MetricsRegistry())
        return make

    def test_truncation_raises_by_default(self, engine_factory):
        eng = engine_factory()
        eng.submit(np.array([1, 2]), 8)
        with pytest.raises(RuntimeError, match="max_steps=1"):
            eng.run_until_drained(max_steps=1)

    def test_truncation_warn_sets_flag_and_counts(self, engine_factory):
        eng = engine_factory()
        eng.submit(np.array([1, 2]), 8)
        with pytest.warns(UserWarning, match="truncated"):
            eng.run_until_drained(max_steps=1, on_truncate="warn")
        assert eng.truncated
        assert eng.metrics.counter("engine.drain_truncations").value == 1
        # A later full drain completes and clears the flag.
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            done = eng.run_until_drained()
        assert not eng.truncated
        assert len(done) == 1 and done[0].done

    def test_clean_drain_does_not_warn_or_flag(self, engine_factory):
        eng = engine_factory()
        eng.submit(np.array([1, 2]), 2)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            eng.run_until_drained()
        assert not eng.truncated

    def test_invalid_on_truncate_rejected(self, engine_factory):
        with pytest.raises(ValueError, match="on_truncate"):
            engine_factory().run_until_drained(on_truncate="ignore")


class TestDecisionCacheCounters:
    def _counts(self):
        c = obs.default_registry().snapshot()["counters"]
        return (c.get("autotune.decision_cache.hits", 0),
                c.get("autotune.decision_cache.misses", 0))

    def test_cold_then_warm_select(self):
        a = _small(21)
        cache = DecisionCache(path=None)
        clear_memo()
        h0, m0 = self._counts()
        d1 = select(a, warm=True, cache=cache)
        h1, m1 = self._counts()
        assert m1 > m0                      # cold lookup missed
        assert h1 == h0
        clear_memo()                        # force the persistent cache
        d2 = select(a, warm=True, cache=cache)
        h2, m2 = self._counts()
        assert h2 > h1                      # warm lookup hit
        assert m2 == m1
        assert d2.config_name == d1.config_name

    def test_memo_hit_skips_cache_lookup(self):
        a = _small(22)
        cache = DecisionCache(path=None)
        clear_memo()
        select(a, warm=True, cache=cache)
        h1, m1 = self._counts()
        select(a, warm=True, cache=cache)   # in-process memo hit
        assert self._counts() == (h1, m1)


class TestTimingSample:
    def test_structure_and_float_compat(self):
        import jax.numpy as jnp
        t = time_kernel(lambda: jnp.zeros(()), warmup=1, repeats=5)
        assert isinstance(t, TimingSample)
        assert isinstance(t, float)
        assert t.n == 5
        assert t.iqr >= 0.0
        assert 0.0 < t.min <= t.median == float(t)
        assert json.dumps(t) == json.dumps(float(t))

    def test_from_samples(self):
        t = TimingSample.from_samples([3.0, 1.0, 2.0])
        assert float(t) == 2.0
        assert t.min == 1.0 and t.n == 3
        assert t.iqr == pytest.approx(1.0)
        assert not t.noisy
        noisy = TimingSample(1.0, iqr=0.9, min=0.5, n=3)
        assert noisy.noisy and noisy.rel_iqr == pytest.approx(0.9)

    def test_calibrate_carries_dispersion_and_weights(self):
        res = calibrate({"er": _small(23)}, warmup=0, repeats=1)
        assert all(p.measured_iqr >= 0.0 for p in res.points)
        assert all(0.0 < p.weight <= 1.0 for p in res.points)
        # to_dict keeps its documented top-level shape.
        assert set(res.to_dict()) == {"model", "err_before",
                                      "err_after", "points"}
