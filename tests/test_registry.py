"""The FormatSpec registry seam: config-string round-trips for every
registered format, registry-derived groupings, and — the acceptance
bar — a toy format registered HERE (not in any dispatch site) showing
up in the conformance path discovery, the candidate sweep, the
exhaustive oracle and `select()` without a single edit to
search/oracle/measure/serving code."""

import numpy as np
import pytest

from repro.autotune import (DecisionCache, candidates, clear_memo,
                            fingerprint, format_names, get_format,
                            iter_formats, oracle_times, parse_config,
                            select)
from repro.autotune.measure import measure_named, spmv_runner
from repro.sparse.formats import CSR
from repro.sparse.random_graphs import erdos_renyi, stencil_2d
from repro.sparse.registry import CostTerms, FormatSpec, register, unregister


def _f32(a: CSR) -> CSR:
    return CSR(a.indptr, a.indices, a.values.astype(np.float32), a.shape)


class TestRegistryBasics:
    def test_builtin_formats_registered(self):
        names = format_names()
        assert len(names) >= 8
        for want in ("dense", "csr", "coo", "sell", "rgcsr", "dtans",
                     "rgcsr_dtans", "bcsr", "bcsr_dtans"):
            assert want in names

    def test_dense_not_selectable(self):
        assert "dense" not in format_names(selectable=True)
        assert not get_format("dense").selectable

    def test_decode_formats(self):
        assert set(format_names(decodes=True)) == {
            "dtans", "rgcsr_dtans", "bcsr_dtans"}

    def test_unknown_format_raises(self):
        with pytest.raises(ValueError, match="unknown format"):
            get_format("alphasparse")

    def test_config_name_roundtrip_every_format(self):
        """encode_knobs / decode_knobs invert each other over every
        registered format's full knob grid."""
        for spec in iter_formats():
            for knobs in spec.knob_grid():
                name = spec.encode_knobs(knobs)
                spec2, parsed = parse_config(name)
                assert spec2 is spec
                assert spec.normalize_knobs(parsed) == knobs

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register(get_format("csr"))

    def test_unknown_knob_rejected(self):
        with pytest.raises(ValueError, match="unknown knobs"):
            get_format("csr").normalize_knobs({"group_size": 4})


class ToyDiagSpec(FormatSpec):
    """Minimal example format: stores only the main diagonal's nonzero
    pattern positions (lossy for anything off-diagonal — fine for the
    seam test, which runs it on a diagonal-only corpus)."""

    name = "toy_diag"
    knob_domains = {"stride": (1, 2)}
    named_knobs = ()

    def nbytes_exact(self, fp, *, stride=1):
        return fp.nnz * fp.value_bytes + 8 * stride

    def nbytes_constructed(self, a, *, params=None, artifacts=None,
                           stride=1):
        return a.nnz * a.values.dtype.itemsize + 8 * stride

    def cost_terms(self, fp, *, stride=1):
        return CostTerms(lockstep=float(fp.nnz))

    def pack(self, a, *, params=None, artifacts=None, stride=1):
        d = a.to_dense()
        return np.diagonal(d).copy(), d.shape

    def runner(self, packed, x, *, interpret=True):
        diag, shape = packed
        m, n = shape
        k = min(m, n)
        out = np.zeros(m, dtype=diag.dtype)

        def run():
            out[:k] = diag * np.asarray(x, dtype=diag.dtype)[:k]
            return out

        return run


@pytest.fixture
def toy_spec():
    spec = ToyDiagSpec()
    register(spec)
    try:
        yield spec
    finally:
        unregister("toy_diag")
        clear_memo()


class TestToyFormatJoinsEverything:
    """A format registered in a test — zero edits anywhere else — must
    surface in every registry consumer."""

    def test_joins_conformance_path_discovery(self, toy_spec):
        from test_spmv_conformance import registry_spmv_paths
        paths = registry_spmv_paths()
        assert "registry:toy_diag" in paths
        d = np.diag(np.arange(1.0, 7.0))
        a = CSR.from_dense(d)
        x = np.arange(6.0)
        got = np.asarray(paths["registry:toy_diag"](a, x))
        np.testing.assert_allclose(got, d @ x)

    def test_joins_spmm_path_discovery(self, toy_spec):
        """A spec with only the single-vector contract still joins the
        batched sweep: `FormatSpec.spmm_runner`'s generic per-column
        fallback drives it (no spmm_fn override anywhere)."""
        from test_spmv_conformance import registry_spmm_paths
        paths = registry_spmm_paths()
        assert "registry:toy_diag" in paths
        d = np.diag(np.arange(1.0, 7.0))
        a = CSR.from_dense(d)
        X = np.arange(18.0).reshape(6, 3)
        got = np.asarray(paths["registry:toy_diag"](a, X))
        np.testing.assert_allclose(got, d @ X)

    def test_joins_batched_timing_harness(self, toy_spec):
        a = CSR.from_dense(np.diag(np.arange(1.0, 9.0)))
        X = np.arange(16.0).reshape(8, 2)
        fn = spmv_runner(a, "toy_diag", x=X, batch=2)
        np.testing.assert_allclose(np.asarray(fn()), a.to_dense() @ X)
        assert measure_named(a, "toy_diag", batch=2, warmup=0,
                             repeats=1) >= 0.0

    def test_joins_candidate_sweep_and_select(self, toy_spec):
        a = _f32(stencil_2d(12))
        fp = fingerprint(a)
        cands = candidates(fp)                 # default = full registry
        toy = [c for c in cands if c.fmt == "toy_diag"]
        assert len(toy) == 2                   # stride sweep
        assert {c.config_name for c in toy} == {"toy_diag",
                                                "toy_diag[stride=2]"}
        dec = select(a, formats=("toy_diag",),
                     cache=DecisionCache(path=None))
        assert dec.fmt == "toy_diag"
        assert dec.exact_size

    def test_joins_oracle(self, toy_spec):
        a = _f32(stencil_2d(10))
        times = oracle_times(a)
        assert "toy_diag" in times
        assert "toy_diag[stride=2]" in times

    def test_joins_timing_harness(self, toy_spec):
        a = CSR.from_dense(np.diag(np.arange(1.0, 9.0)))
        x = np.arange(8.0)
        fn = spmv_runner(a, "toy_diag", x=x)
        np.testing.assert_allclose(np.asarray(fn()), a.to_dense() @ x)
        assert measure_named(a, "toy_diag[stride=2]", warmup=0,
                             repeats=1) >= 0.0


class ToyGroupedSpec(ToyDiagSpec):
    """Toy spec REUSING a built-in override knob name (group_size) with
    its own domain — select() must sweep the spec's domain, not clobber
    it with the built-in RGCSR sweep."""

    name = "toy_grouped"
    knob_domains = {"group_size": (64, 128)}
    named_knobs = ("group_size",)

    def nbytes_exact(self, fp, *, group_size=64):
        return fp.nnz * fp.value_bytes + group_size

    def nbytes_constructed(self, a, *, params=None, artifacts=None,
                           group_size=64):
        return a.nnz * a.values.dtype.itemsize + group_size

    def cost_terms(self, fp, *, group_size=64):
        return CostTerms(lockstep=float(fp.nnz))

    def pack(self, a, *, params=None, artifacts=None, group_size=64):
        return super().pack(a)


def test_select_sweeps_third_party_knob_domain():
    """select()'s built-in sweep defaults must not override a
    third-party format's own domain for a same-named knob."""
    register(ToyGroupedSpec())
    try:
        a = _f32(stencil_2d(10))
        clear_memo()
        dec = select(a, formats=("toy_grouped",),
                     cache=DecisionCache(path=None))
        names = {row[0] for row in dec.leaderboard}
        assert names == {"toy_grouped[G=64]", "toy_grouped[G=128]"}
        assert oracle_times(a, formats=("toy_grouped",)).keys() == names
    finally:
        unregister("toy_grouped")
        clear_memo()


class TestKnobOverrides:
    """The generic `knob_overrides=` parameter (ROADMAP open item):
    narrows ANY spec's knob domain by name — third-party knobs without
    a dedicated keyword included — on both select() and the oracle."""

    def test_narrows_third_party_knob(self, toy_spec):
        a = _f32(stencil_2d(10))
        clear_memo()
        dec = select(a, formats=("toy_diag",),
                     knob_overrides={"stride": (2,)},
                     cache=DecisionCache(path=None))
        assert [row[0] for row in dec.leaderboard] == ["toy_diag[stride=2]"]
        times = oracle_times(a, formats=("toy_diag",),
                             knob_overrides={"stride": (2,)})
        assert set(times) == {"toy_diag[stride=2]"}

    def test_matches_legacy_sugar(self):
        """knob_overrides={'group_size': ...} and the deprecated
        group_sizes= sugar must produce identical sweeps."""
        a = _f32(stencil_2d(12))
        clear_memo()
        d1 = select(a, formats=("rgcsr",), group_sizes=(8, 16),
                    cache=DecisionCache(path=None))
        clear_memo()
        d2 = select(a, formats=("rgcsr",),
                    knob_overrides={"group_size": (8, 16)},
                    cache=DecisionCache(path=None))
        assert d1.leaderboard == d2.leaderboard
        assert d1.config_name == d2.config_name

    def test_sugar_wins_on_conflict(self):
        """When both spell the same knob, the explicit named keyword
        wins (documented deprecation path)."""
        a = _f32(stencil_2d(12))
        clear_memo()
        dec = select(a, formats=("rgcsr",), group_sizes=(8,),
                     knob_overrides={"group_size": (4, 16)},
                     cache=DecisionCache(path=None))
        assert [row[0] for row in dec.leaderboard] == ["rgcsr[G=8]"]

    def test_overrides_enter_cache_key(self):
        a = _f32(stencil_2d(12))
        cache = DecisionCache(path=None)
        clear_memo()
        select(a, formats=("rgcsr",), cache=cache)
        select(a, formats=("rgcsr",),
               knob_overrides={"group_size": (8,)}, cache=cache)
        assert len(cache) == 2

    def test_ignored_for_foreign_knobs(self):
        """Overrides naming knobs a format does not declare leave that
        format's sweep untouched (same contract as FormatSpec.knob_grid)."""
        a = _f32(stencil_2d(12))
        clear_memo()
        dec = select(a, formats=("sell",),
                     knob_overrides={"group_size": (8,)},
                     cache=DecisionCache(path=None))
        assert dec.config_name == "sell"


class ToyModeSpec(ToyDiagSpec):
    """Toy spec with a STRING-valued knob — config names must round-trip
    for non-integer third-party knob values too."""

    name = "toy_mode"
    knob_domains = {"mode": ("fast", "safe")}
    named_knobs = ("mode",)

    def nbytes_exact(self, fp, *, mode="fast"):
        return fp.nnz * fp.value_bytes

    def cost_terms(self, fp, *, mode="fast"):
        return CostTerms(lockstep=float(fp.nnz))


def test_string_knob_config_roundtrip():
    register(ToyModeSpec())
    try:
        spec = get_format("toy_mode")
        name = spec.encode_knobs({"mode": "safe"})
        assert name == "toy_mode[mode=safe]"
        spec2, knobs = parse_config(name)
        assert spec2 is spec and knobs == {"mode": "safe"}
    finally:
        unregister("toy_mode")


class TestStrideKnobRendering:
    def test_unknown_stride_component(self):
        with pytest.raises(ValueError):
            parse_config("csr[stride=2]")
