"""RGCSR format family: construction, byte accounting, kernels, and
property-based bit-exact round-trips (CSR-dtANS and RGCSR-dtANS),
including symmetric/pattern matrices loaded through `repro.sparse.io`."""

import io

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.csr_dtans import decode_matrix, encode_matrix, spmv_gold
from repro.core.rgcsr_dtans import RGCSRdtANS, encode_rgcsr_matrix
from repro.kernels import ops
from repro.kernels.rgcsr_spmv import pack_rgcsr, rgcsr_spmv_ref
from repro.sparse.formats import CSR, all_format_nbytes
from repro.sparse.io import load_mtx
from repro.sparse.rgcsr import (RGCSR, RGCSR_GROUP_SIZES,
                                local_indptr_bytes, rgcsr_nbytes_exact)
from repro.sparse.random_graphs import banded, erdos_renyi, stencil_2d


def _assert_same_csr(a: CSR, b: CSR):
    assert a.shape == b.shape
    assert np.array_equal(a.indptr, b.indptr)
    assert np.array_equal(a.indices, b.indices)
    assert np.array_equal(a.values, b.values)  # bit-exact (lossless)


def _random_csr(rng, m, n, density, dtype=np.float64):
    d = rng.integers(-3, 4, size=(m, n)).astype(dtype)
    d[rng.random((m, n)) >= density] = 0
    return CSR.from_dense(d)


class TestRGCSRFormat:
    @pytest.mark.parametrize("G", RGCSR_GROUP_SIZES)
    def test_roundtrip(self, G):
        a = erdos_renyi(200, 7, np.random.default_rng(1))
        _assert_same_csr(a, RGCSR.from_csr(a, G).to_csr())

    def test_roundtrip_empty_and_awkward(self):
        for d in (np.zeros((8, 9)),
                  np.diag(np.r_[np.zeros(5), np.arange(1.0, 7.0)]),
                  np.ones((3, 40))):
            a = CSR.from_dense(d)
            for G in (1, 4, 32):
                r = RGCSR.from_csr(a, G)
                _assert_same_csr(a, r.to_csr())
                np.testing.assert_array_equal(r.to_dense(), d)

    @pytest.mark.parametrize("G", RGCSR_GROUP_SIZES)
    def test_nbytes_matches_histogram_formula(self, G):
        a = stencil_2d(25)
        r = RGCSR.from_csr(a, G)
        assert r.nbytes == rgcsr_nbytes_exact(a.row_nnz(), G,
                                              a.values.dtype.itemsize)
        assert all_format_nbytes(a)[f"rgcsr[G={G}]"] == r.nbytes

    def test_local_indptr_width_promotes(self):
        assert local_indptr_bytes(2 ** 16 - 1) == 2
        assert local_indptr_bytes(2 ** 16) == 4
        # one dense row of 70000 nnz forces 4-byte local offsets
        rnnz = np.array([70000, 3, 3, 3])
        b4 = rgcsr_nbytes_exact(rnnz, 4, 8)
        assert b4 == 70009 * 12 + 1 * 5 * 4 + 2 * 4

    def test_spmv_reference(self):
        rng = np.random.default_rng(2)
        a = _random_csr(rng, 90, 70, 0.2)
        r = RGCSR.from_csr(a, 8)
        x = rng.standard_normal(70)
        y0 = rng.standard_normal(90)
        np.testing.assert_allclose(r.spmv(x, y0), a.to_dense() @ x + y0,
                                   rtol=1e-12)

    def test_group_size_one_and_giant(self):
        a = banded(60, 3)
        for G in (1, 128):  # G > m: a single group
            r = RGCSR.from_csr(a, G)
            _assert_same_csr(a, r.to_csr())


class TestRGCSRKernel:
    @pytest.mark.parametrize("G", [4, 32])
    @pytest.mark.parametrize("dtype", [np.float64, np.float32])
    def test_kernel_vs_ref_and_dense(self, G, dtype):
        rng = np.random.default_rng(3)
        a = _random_csr(rng, 130, 75, 0.15, dtype)
        r = RGCSR.from_csr(a, G)
        pr = pack_rgcsr(r)
        x = rng.standard_normal(75).astype(dtype)
        y_k = np.asarray(ops.rgcsr_spmv(pr, x))
        y_r = np.asarray(rgcsr_spmv_ref(pr.deltas, pr.values, pr.nnz, x)
                         ).reshape(-1)[:130]
        rtol = 1e-12 if dtype == np.float64 else 1e-5
        np.testing.assert_allclose(y_k, y_r, rtol=rtol)
        np.testing.assert_allclose(y_k, a.to_dense() @ x, rtol=rtol,
                                   atol=1e-5 if dtype == np.float32 else 0)


class TestRGCSRdtANS:
    @pytest.mark.parametrize("G", RGCSR_GROUP_SIZES)
    def test_roundtrip_bit_exact(self, G):
        a = erdos_renyi(150, 7, np.random.default_rng(4))
        mat = encode_rgcsr_matrix(a, group_size=G)
        assert isinstance(mat, RGCSRdtANS)
        assert mat.n_groups == -(-a.shape[0] // G)
        _assert_same_csr(a, decode_matrix(mat))

    def test_slices_align_with_groups(self):
        """The defining property: one decode slice per row group."""
        a = banded(100, 4)
        mat = encode_rgcsr_matrix(a, group_size=8)
        assert mat.lane_width == mat.group_size == 8
        assert mat.slice_offsets.size == mat.n_groups + 1

    def test_nbytes_beats_csr_dtans_on_row_metadata(self):
        """Group-local 16-bit row lengths: 2 bytes/row less than the
        ungrouped format at the same interleave width."""
        a = banded(640, 5)
        rg = encode_rgcsr_matrix(a, group_size=32)
        un = encode_matrix(a, lane_width=32)
        assert rg.stream.size == un.stream.size      # same streams
        assert rg.nbytes == un.nbytes - a.shape[0] * 2

    def test_spmv_gold_and_kernel(self):
        rng = np.random.default_rng(5)
        a = _random_csr(rng, 120, 90, 0.15)
        mat = encode_rgcsr_matrix(a, group_size=16)
        x = rng.standard_normal(90)
        want = a.to_dense() @ x
        np.testing.assert_allclose(spmv_gold(mat, x), want, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(ops.spmv(mat, x)), want,
                                   rtol=1e-9)


def _mtx_symmetric(seed: int, pattern: bool) -> CSR:
    """A symmetric (or symmetric-pattern) MatrixMarket file -> CSR, via
    the `repro.sparse.io` text round-trip."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 30))
    nnz = int(rng.integers(1, 4 * n))
    r = rng.integers(0, n, size=nnz)
    c = rng.integers(0, n, size=nnz)
    lo, hi = np.minimum(r, c), np.maximum(r, c)   # lower triangle
    field = "pattern" if pattern else "integer"
    lines = [f"%%MatrixMarket matrix coordinate {field} symmetric",
             f"{n} {n} {nnz}"]
    for i in range(nnz):
        entry = f"{hi[i] + 1} {lo[i] + 1}"
        if not pattern:
            entry += f" {int(rng.integers(-5, 6))}"
        lines.append(entry)
    return load_mtx(io.StringIO("\n".join(lines) + "\n"))


class TestPropertyRoundtrips:
    """Property-based bit-exactness (skips when hypothesis is absent;
    the CI no-hypothesis leg exercises the shim path)."""

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2 ** 31))
    def test_rgcsr_random(self, seed):
        rng = np.random.default_rng(seed)
        m, n = int(rng.integers(1, 80)), int(rng.integers(1, 80))
        a = _random_csr(rng, m, n, float(rng.uniform(0.01, 0.4)))
        G = int(rng.integers(1, 40))
        _assert_same_csr(a, RGCSR.from_csr(a, G).to_csr())

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 31))
    def test_csr_dtans_and_rgcsr_dtans_random(self, seed):
        rng = np.random.default_rng(seed)
        m, n = int(rng.integers(1, 60)), int(rng.integers(1, 60))
        a = _random_csr(rng, m, n, float(rng.uniform(0.01, 0.4)))
        _assert_same_csr(a, decode_matrix(
            encode_matrix(a, lane_width=int(rng.integers(1, 40)))))
        _assert_same_csr(a, decode_matrix(
            encode_rgcsr_matrix(a, group_size=int(rng.integers(1, 40)))))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 31), pattern=st.booleans())
    def test_mtx_symmetric_roundtrip(self, seed, pattern):
        """Symmetric / pattern matrices from `repro.sparse.io` survive
        both entropy formats bit-exactly."""
        a = _mtx_symmetric(seed, pattern)
        _assert_same_csr(a, decode_matrix(encode_matrix(a,
                                                        lane_width=16)))
        _assert_same_csr(a, decode_matrix(
            encode_rgcsr_matrix(a, group_size=8)))
