"""Roofline infrastructure tests: the trip-count-aware HLO walker and the
roofline-term arithmetic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                   collective_bytes, model_flops)


def _scan_matmul(length=100, n=128):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=length)
        return out
    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return jax.jit(f).lower(sds, sds).compile()


class TestHloWalker:
    def test_xla_cost_analysis_misses_trip_counts(self):
        """Documents WHY the walker exists."""
        c = _scan_matmul()
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):  # newer jax: one entry per module
            ca = ca[0] if ca else {}
        xla_flops = float(ca.get("flops", 0.0))
        assert xla_flops < 2 * 128 ** 3 * 2  # body counted ~once

    def test_walker_multiplies_trip_counts(self):
        c = _scan_matmul()
        costs = analyze(c.as_text())
        expected = 2 * 128 ** 3 * 100
        assert abs(costs.flops - expected) / expected < 0.05

    def test_dot_flops_from_contracting_dims(self):
        a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
        c = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
        costs = analyze(c.as_text())
        assert abs(costs.flops - 2 * 64 * 256 * 32) / costs.flops < 0.05

    def test_nested_scan(self):
        def f(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                c, _ = jax.lax.scan(inner, c, None, length=5)
                return c, None
            out, _ = jax.lax.scan(outer, x, None, length=7)
            return out
        sds = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        c = jax.jit(f).lower(sds, sds).compile()
        costs = analyze(c.as_text())
        expected = 2 * 32 ** 3 * 35
        assert abs(costs.flops - expected) / expected < 0.1

    def test_sliced_stack_not_fully_charged(self):
        """A scanned weight stack read via dynamic-slice must be charged
        at slice size, not stack size."""
        def f(x, stack):
            def body(c, w):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, stack)
            return out
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        stack = jax.ShapeDtypeStruct((50, 64, 64), jnp.float32)
        c = jax.jit(f).lower(x, stack).compile()
        costs = analyze(c.as_text())
        # 50 iterations x (~4 buffers x 16KB) — full-stack charging would
        # be 50 x 820KB = 41 MB; assert we stay well under that
        assert costs.bytes < 2e7


class TestRooflineTerms:
    def test_term_arithmetic_and_dominance(self):
        r = Roofline.from_costs(flops=PEAK_FLOPS, hbm_bytes=HBM_BW / 2,
                                coll_bytes=ICI_BW)
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(0.5)
        assert r.collective_s == pytest.approx(0.25)
        assert r.dominant == "compute"

    def test_model_flops_dense_vs_moe(self):
        from repro.configs import get
        from repro.models.config import SHAPES
        dense = model_flops(get("yi-9b"), SHAPES["train_4k"], "train")
        # 6 * N * D
        assert dense == pytest.approx(6 * 8.83e9 * 256 * 4096, rel=0.05)
        moe = model_flops(get("qwen3-moe-30b-a3b"), SHAPES["train_4k"],
                          "train")
        # active ~3B of 30B total: far below 6*30e9*D
        assert moe < 6 * 15e9 * 256 * 4096

    def test_collective_regex_parser(self):
        text = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %ar = f32[16]{0} all-reduce(%p), replica_groups={}, to_apply=%add
  ROOT %ag = f32[64]{0} all-gather(%ar), dimensions={0}
}
"""
        out = collective_bytes(text)
        assert out["counts"]["all-reduce"] == 1
        assert out["weighted"]["all-reduce"] == 2 * 16 * 4  # ring 2x
        assert out["counts"]["all-gather"] == 1
