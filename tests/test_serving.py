"""Serving tests: SparseLinear correctness + compression, engine batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import api
from repro.serving.engine import Engine
from repro.serving.sparse_linear import SparseLinear


@pytest.fixture(scope="module")
def sl():
    rng = np.random.default_rng(0)
    w = (rng.standard_normal((256, 640)) / 10).astype(np.float32)
    return SparseLinear.from_dense(w, sparsity=0.7, value_bits=6,
                                   lane_width=32)


class TestSparseLinear:
    def test_apply_matches_dense_reference(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 256),
                              dtype=jnp.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_single_vector_path(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 256),
                              dtype=jnp.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_compresses_vs_dense(self, sl):
        assert sl.compression_vs_dense > 1.5
        assert sl.compressed_bytes < sl.dense_bytes

    def test_3d_input(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 256),
                              dtype=jnp.float32)
        assert sl.apply(x).shape == (2, 3, 640)


class TestSparseLinearDtype:
    """The serving path must honor the packed matrix dtype end to end —
    the batched decode-gather path used to cast to float32 regardless
    (sparse_linear.py batched `apply`), silently discarding float64
    precision the single-vector SpMV path preserved."""

    @pytest.fixture(scope="class")
    def sl64(self):
        rng = np.random.default_rng(11)
        w = (rng.standard_normal((96, 200)) / 10).astype(np.float64)
        return SparseLinear.from_dense(w, sparsity=0.7, value_bits=6,
                                       lane_width=32)

    def test_float64_preserved_through_encode(self, sl64):
        assert sl64.mat.dtype == np.float64

    def test_float64_batched_regression(self, sl64):
        rng = np.random.default_rng(12)
        x = rng.standard_normal((4, 96))          # float64
        got = np.asarray(sl64.apply(x))
        want = np.asarray(sl64.apply_dense_reference(x))
        assert got.dtype == np.float64
        # float64 tolerance: a float32 contraction fails this by ~1e-7
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)

    def test_float64_single_vector(self, sl64):
        rng = np.random.default_rng(13)
        x = rng.standard_normal((1, 96))
        got = np.asarray(sl64.apply(x))
        want = np.asarray(sl64.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


class TestSparseLinearFusedSpmm:
    """The batched serving path runs the fused Pallas SpMM kernel for
    EVERY entropy-coded registry family — no decode+gather fallback
    remains — and B=1 results are bit-identical to `ops.spmv`."""

    @staticmethod
    def _sl_for(spec):
        """A SparseLinear whose artifact is built by ``spec`` (block-
        structured weight so every family's admit/encode succeeds)."""
        from repro.kernels.pack import pack_matrix
        from repro.sparse.formats import CSR, best_baseline_nbytes
        rng = np.random.default_rng(20)
        d_in, d_out = 64, 96
        w = np.zeros((d_out, d_in), dtype=np.float32)  # W^T layout
        rows = rng.integers(0, d_out // 4, size=40)
        cols = rng.integers(0, d_in // 4, size=40)
        for r, c in zip(rows, cols):                   # 4x4 blocks
            w[4 * r:4 * r + 4, 4 * c:4 * c + 4] = \
                np.round(rng.standard_normal((4, 4))) / 2
        pruned = CSR.from_dense(w)
        mat = spec.encode(pruned)
        return SparseLinear(
            mat=mat, packed=pack_matrix(mat), d_in=d_in, d_out=d_out,
            dense_bytes=w.size * 4,
            baseline_bytes=best_baseline_nbytes(pruned)[1])

    def _specs(self):
        from repro.sparse.registry import iter_formats
        specs = iter_formats(decodes=True)
        assert {s.name for s in specs} >= {"dtans", "rgcsr_dtans",
                                           "bcsr_dtans"}
        return specs

    def test_batched_apply_every_decode_family(self):
        rng = np.random.default_rng(21)
        for spec in self._specs():
            sl = self._sl_for(spec)
            x = rng.standard_normal((8, sl.d_in)).astype(np.float32)
            got = np.asarray(sl.apply(x))
            want = np.asarray(sl.apply_dense_reference(x))
            np.testing.assert_allclose(
                got, want, rtol=1e-4, atol=1e-5,
                err_msg=f"{spec.name}: batched apply diverges")

    def test_b1_bit_identical_to_spmv(self):
        from repro.kernels import ops
        rng = np.random.default_rng(22)
        for spec in self._specs():
            sl = self._sl_for(spec)
            x = rng.standard_normal((1, sl.d_in)).astype(np.float32)
            via_apply = np.asarray(sl.apply(x))[0]
            via_spmv = np.asarray(
                ops.spmv(sl.packed, x[0].astype(np.float32)))
            assert np.array_equal(via_apply, via_spmv), \
                f"{spec.name}: B=1 apply is not bit-identical to spmv"

    def test_empty_batch(self):
        """Zero active requests: apply must return an empty result,
        not crash in the kernel (the deleted gather fallback handled
        this shape)."""
        sl = self._sl_for(self._specs()[0])
        got = np.asarray(sl.apply(np.zeros((0, sl.d_in),
                                           dtype=np.float32)))
        assert got.shape == (0, sl.d_out)

    def test_no_decode_gather_fallback_remains(self):
        """`apply` must not call `ops.decode` for any batch size (the
        unfused XLA gather escape this refactor deleted)."""
        from repro.kernels import ops
        import unittest.mock as mock
        sl = self._sl_for(self._specs()[0])
        x = np.ones((8, sl.d_in), dtype=np.float32)
        with mock.patch.object(ops, "decode",
                               side_effect=AssertionError(
                                   "gather fallback resurrected")):
            sl.apply(x)


class TestSparseLinearRgcsrAuto:
    def test_batched_apply_under_rgcsr_dtans_decision(self):
        """The decode-gather SpMM path under an RGCSR-dtANS autotune
        decision (auto=True): skewed row lengths make the group-aligned
        family win, and the batched contraction must still match the
        dense reference."""
        from repro.autotune import DecisionCache
        from repro.core.rgcsr_dtans import RGCSRdtANS
        rng = np.random.default_rng(14)
        m_out, d_in = 256, 96
        w = np.zeros((d_in, m_out), dtype=np.float32)
        w[:, :8] = rng.standard_normal((d_in, 8)) * 5      # dense neurons
        tail = rng.random((d_in, m_out - 8)) < 0.06        # sparse tail
        w[:, 8:][tail] = rng.standard_normal(int(tail.sum())) * 3
        sl = SparseLinear.from_dense(
            w, sparsity=0.5, auto=True,
            autotune_cache=DecisionCache(path=None))
        assert sl.decision.fmt == "rgcsr_dtans", sl.decision.config_name
        assert isinstance(sl.mat, RGCSRdtANS)
        x = rng.standard_normal((3, d_in)).astype(np.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestCompressLmHead:
    def test_tied_head_compresses_and_validates(self):
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        params = api.init_params(cfg, jax.random.PRNGKey(1))
        sl = Engine.compress_lm_head(cfg, params, sparsity=0.5,
                                     value_bits=5, lane_width=32)
        assert sl.d_out == cfg.vocab
        assert sl.mat.dtype == np.float32     # source dtype, not forced

    def test_float64_head_dtype_preserved(self):
        cfg = get_smoke("smollm-135m").with_(vocab=48)
        rng = np.random.default_rng(15)
        params = {"embed": {
            "head": rng.standard_normal((cfg.d_model, cfg.vocab))}}
        sl = Engine.compress_lm_head(cfg, params, sparsity=0.5,
                                     value_bits=5, lane_width=32)
        assert sl.mat.dtype == np.float64

    def test_shape_mismatch_raises(self):
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        params = {"embed": {"head": np.zeros((3, 5), dtype=np.float32)}}
        with pytest.raises(ValueError, match="does not match config"):
            Engine.compress_lm_head(cfg, params)


class TestEngine:
    def test_batched_serving_drains(self):
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, slots=3, max_seq=32)
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(0, 64, size=4), 5)
                for _ in range(5)]
        done = eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert all(len(r.out) == 5 for r in reqs)
        assert all(0 <= t < 64 for r in reqs for t in r.out)
        # Bugfix regression: run_until_drained used to return [].
        assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)

    def test_drain_returns_completion_order(self):
        """Shorter requests finish first; `run_until_drained` reports
        them in completion order and clears the finished log."""
        cfg = get_smoke("smollm-135m").with_(vocab=32)
        params = api.init_params(cfg, jax.random.PRNGKey(3))
        eng = Engine(cfg, params, slots=3, max_seq=32)
        rng = np.random.default_rng(1)
        for n_new in (2, 5, 3):
            eng.submit(rng.integers(0, 32, size=3), n_new)
        done = eng.run_until_drained()
        assert [r.rid for r in done] == [0, 2, 1]
        assert eng.finished == []
        assert eng.run_until_drained() == []

    def test_rids_stay_unique_across_interleaved_submits(self):
        """Bugfix regression: the default rid was len(queue), which
        collides once the queue drains between submits — drained
        results then cannot be correlated by rid."""
        cfg = get_smoke("smollm-135m").with_(vocab=32)
        params = api.init_params(cfg, jax.random.PRNGKey(4))
        eng = Engine(cfg, params, slots=2, max_seq=32)
        rng = np.random.default_rng(2)
        r1 = eng.submit(rng.integers(0, 32, size=2), 1)
        eng.step()                      # queue drains into a slot
        r2 = eng.submit(rng.integers(0, 32, size=2), 1)
        assert r1.rid != r2.rid
        done = eng.run_until_drained()
        assert len({r.rid for r in done}) == len(done) == 2


class TestEngineSparseHead:
    """Bugfix regression: the sparse_head branch of `Engine.step` used
    to be byte-identical to the dense branch (`_head` was dead code) —
    the compressed LM head was never consulted at decode time."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_smoke("smollm-135m").with_(vocab=48)
        params = api.init_params(cfg, jax.random.PRNGKey(2))
        sl = Engine.compress_lm_head(cfg, params, sparsity=0.6,
                                     value_bits=5, lane_width=32)
        return cfg, params, sl

    def test_sparse_logits_differ_from_dense_and_match_reference(
            self, setup):
        cfg, params, sl = setup
        cache = api.make_decode_cache(cfg, 2, 16, dtype=jnp.float32)
        toks = jnp.ones((2, 1), jnp.int32)
        hidden, _ = api.decode_hidden(params, cfg, cache, toks,
                                      jnp.int32(0))
        dense_logits, _ = api.decode_step(params, cfg, cache, toks,
                                          jnp.int32(0))
        sparse_logits = np.asarray(sl.apply(hidden))
        ref = np.asarray(sl.apply_dense_reference(hidden))
        np.testing.assert_allclose(sparse_logits, ref, rtol=1e-4,
                                   atol=1e-5)
        # The pruned+quantized head must actually change the logits —
        # identical outputs would mean the dense head is still serving.
        assert not np.allclose(sparse_logits, np.asarray(dense_logits),
                               atol=1e-3)

    def test_decode_step_is_lm_head_of_decode_hidden(self, setup):
        cfg, params, _ = setup
        from repro.models.layers import lm_head
        cache = api.make_decode_cache(cfg, 2, 16, dtype=jnp.float32)
        toks = jnp.full((2, 1), 3, jnp.int32)
        hidden, _ = api.decode_hidden(params, cfg, cache, toks,
                                      jnp.int32(0))
        logits, _ = api.decode_step(params, cfg, cache, toks,
                                    jnp.int32(0))
        np.testing.assert_allclose(
            np.asarray(lm_head(params["embed"], hidden)),
            np.asarray(logits), rtol=1e-6, atol=1e-6)

    def test_engine_step_routes_through_sparse_head(self, setup):
        cfg, params, sl = setup
        eng = Engine(cfg, params, slots=2, max_seq=32, sparse_head=sl)
        calls = []
        orig = sl.apply
        sl.apply = lambda h, **kw: (calls.append(h.shape), orig(h, **kw))[1]
        try:
            eng.submit(np.array([1, 2, 3]), 2)
            eng.run_until_drained()
        finally:
            sl.apply = orig
        # one head call per decode step (prefill steps don't need
        # logits but run through step_slot's decode; the pooled decode
        # steps must all consult the compressed head)
        assert calls, "sparse head never consulted by Engine.step"
        assert all(shape == (2, 1, cfg.d_model) for shape in calls)
