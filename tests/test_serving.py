"""Serving tests: SparseLinear correctness + compression, engine batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import api
from repro.serving.engine import Engine
from repro.serving.sparse_linear import SparseLinear


@pytest.fixture(scope="module")
def sl():
    rng = np.random.default_rng(0)
    w = (rng.standard_normal((256, 640)) / 10).astype(np.float32)
    return SparseLinear.from_dense(w, sparsity=0.7, value_bits=6,
                                   lane_width=32)


class TestSparseLinear:
    def test_apply_matches_dense_reference(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 256),
                              dtype=jnp.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_single_vector_path(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 256),
                              dtype=jnp.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_compresses_vs_dense(self, sl):
        assert sl.compression_vs_dense > 1.5
        assert sl.compressed_bytes < sl.dense_bytes

    def test_3d_input(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 256),
                              dtype=jnp.float32)
        assert sl.apply(x).shape == (2, 3, 640)


class TestSparseLinearDtype:
    """The serving path must honor the packed matrix dtype end to end —
    the batched decode-gather path used to cast to float32 regardless
    (sparse_linear.py batched `apply`), silently discarding float64
    precision the single-vector SpMV path preserved."""

    @pytest.fixture(scope="class")
    def sl64(self):
        rng = np.random.default_rng(11)
        w = (rng.standard_normal((96, 200)) / 10).astype(np.float64)
        return SparseLinear.from_dense(w, sparsity=0.7, value_bits=6,
                                       lane_width=32)

    def test_float64_preserved_through_encode(self, sl64):
        assert sl64.mat.dtype == np.float64

    def test_float64_batched_regression(self, sl64):
        rng = np.random.default_rng(12)
        x = rng.standard_normal((4, 96))          # float64
        got = np.asarray(sl64.apply(x))
        want = np.asarray(sl64.apply_dense_reference(x))
        assert got.dtype == np.float64
        # float64 tolerance: a float32 contraction fails this by ~1e-7
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)

    def test_float64_single_vector(self, sl64):
        rng = np.random.default_rng(13)
        x = rng.standard_normal((1, 96))
        got = np.asarray(sl64.apply(x))
        want = np.asarray(sl64.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


class TestSparseLinearRgcsrAuto:
    def test_batched_apply_under_rgcsr_dtans_decision(self):
        """The decode-gather SpMM path under an RGCSR-dtANS autotune
        decision (auto=True): skewed row lengths make the group-aligned
        family win, and the batched contraction must still match the
        dense reference."""
        from repro.autotune import DecisionCache
        from repro.core.rgcsr_dtans import RGCSRdtANS
        rng = np.random.default_rng(14)
        m_out, d_in = 256, 96
        w = np.zeros((d_in, m_out), dtype=np.float32)
        w[:, :8] = rng.standard_normal((d_in, 8)) * 5      # dense neurons
        tail = rng.random((d_in, m_out - 8)) < 0.06        # sparse tail
        w[:, 8:][tail] = rng.standard_normal(int(tail.sum())) * 3
        sl = SparseLinear.from_dense(
            w, sparsity=0.5, auto=True,
            autotune_cache=DecisionCache(path=None))
        assert sl.decision.fmt == "rgcsr_dtans", sl.decision.config_name
        assert isinstance(sl.mat, RGCSRdtANS)
        x = rng.standard_normal((3, d_in)).astype(np.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestCompressLmHead:
    def test_tied_head_compresses_and_validates(self):
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        params = api.init_params(cfg, jax.random.PRNGKey(1))
        sl = Engine.compress_lm_head(cfg, params, sparsity=0.5,
                                     value_bits=5, lane_width=32)
        assert sl.d_out == cfg.vocab
        assert sl.mat.dtype == np.float32     # source dtype, not forced

    def test_float64_head_dtype_preserved(self):
        cfg = get_smoke("smollm-135m").with_(vocab=48)
        rng = np.random.default_rng(15)
        params = {"embed": {
            "head": rng.standard_normal((cfg.d_model, cfg.vocab))}}
        sl = Engine.compress_lm_head(cfg, params, sparsity=0.5,
                                     value_bits=5, lane_width=32)
        assert sl.mat.dtype == np.float64

    def test_shape_mismatch_raises(self):
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        params = {"embed": {"head": np.zeros((3, 5), dtype=np.float32)}}
        with pytest.raises(ValueError, match="does not match config"):
            Engine.compress_lm_head(cfg, params)


class TestEngine:
    def test_batched_serving_drains(self):
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, slots=3, max_seq=32)
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(0, 64, size=4), 5)
                for _ in range(5)]
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert all(len(r.out) == 5 for r in reqs)
        assert all(0 <= t < 64 for r in reqs for t in r.out)
