"""Serving tests: SparseLinear correctness + compression, engine batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import api
from repro.serving.engine import Engine
from repro.serving.sparse_linear import SparseLinear


@pytest.fixture(scope="module")
def sl():
    rng = np.random.default_rng(0)
    w = (rng.standard_normal((256, 640)) / 10).astype(np.float32)
    return SparseLinear.from_dense(w, sparsity=0.7, value_bits=6,
                                   lane_width=32)


class TestSparseLinear:
    def test_apply_matches_dense_reference(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 256),
                              dtype=jnp.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_single_vector_path(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 256),
                              dtype=jnp.float32)
        got = np.asarray(sl.apply(x))
        want = np.asarray(sl.apply_dense_reference(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_compresses_vs_dense(self, sl):
        assert sl.compression_vs_dense > 1.5
        assert sl.compressed_bytes < sl.dense_bytes

    def test_3d_input(self, sl):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 256),
                              dtype=jnp.float32)
        assert sl.apply(x).shape == (2, 3, 640)


class TestEngine:
    def test_batched_serving_drains(self):
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, slots=3, max_seq=32)
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(0, 64, size=4), 5)
                for _ in range(5)]
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert all(len(r.out) == 5 for r in reqs)
        assert all(0 <= t < 64 for r in reqs for t in r.out)
