"""Unit tests for the partition layer (`repro.sparse.shard`) and the
registry shard seam (`FormatSpec.shard_unit` / `shard`): boundary
arithmetic, CSR row-block slicing, plan invariants, per-family shard
units, and exact per-shard byte accounting.
"""

import numpy as np
import pytest

from repro.sparse.formats import CSR
from repro.sparse.registry import get_format, iter_formats
from repro.sparse.shard import ShardPlan, csr_row_block, shard_boundaries


def _rand_csr(m, n, density=0.2, seed=0):
    rng = np.random.default_rng(seed)
    d = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    return CSR.from_dense(d)


class TestShardBoundaries:
    def test_balanced_units(self):
        # 10 units of 8 rows over 4 shards: 3,3,2,2 units
        assert shard_boundaries(80, 4, 8) == (0, 24, 48, 64, 80)

    def test_unit_alignment(self):
        for m, k, u in [(100, 3, 16), (57, 4, 8), (128, 5, 32)]:
            b = shard_boundaries(m, k, u)
            assert b[0] == 0 and b[-1] == m and len(b) == k + 1
            assert all(x % u == 0 for x in b[1:-1]), (m, k, u, b)
            assert all(b[i] <= b[i + 1] for i in range(k))

    def test_ragged_tail(self):
        # 57 rows, unit 8 -> 8 units; 4 shards get 2 units each, the
        # last owning the 1-row tail
        assert shard_boundaries(57, 4, 8) == (0, 16, 32, 48, 57)

    def test_more_shards_than_units(self):
        b = shard_boundaries(16, 4, 16)     # one unit, four shards
        assert b == (0, 16, 16, 16, 16)     # trailing shards empty

    def test_zero_rows(self):
        assert shard_boundaries(0, 3) == (0, 0, 0, 0)

    def test_single_shard_is_whole_matrix(self):
        assert shard_boundaries(100, 1, 32) == (0, 100)

    def test_validation(self):
        with pytest.raises(ValueError):
            shard_boundaries(10, 0)
        with pytest.raises(ValueError):
            shard_boundaries(10, 2, 0)


class TestCsrRowBlock:
    def test_round_trip(self):
        a = _rand_csr(23, 17)
        d = a.to_dense()
        for r0, r1 in [(0, 23), (0, 10), (5, 18), (22, 23), (7, 7)]:
            sub = csr_row_block(a, r0, r1)
            assert sub.shape == (r1 - r0, 17)
            np.testing.assert_array_equal(sub.to_dense(), d[r0:r1])
            assert sub.indptr[0] == 0

    def test_blocks_cover_matrix(self):
        a = _rand_csr(40, 11, seed=3)
        b = shard_boundaries(40, 3, 4)
        parts = [csr_row_block(a, b[k], b[k + 1]).to_dense()
                 for k in range(3)]
        np.testing.assert_array_equal(np.concatenate(parts),
                                      a.to_dense())

    def test_out_of_range(self):
        a = _rand_csr(10, 5)
        for r0, r1 in [(-1, 5), (3, 11), (7, 3)]:
            with pytest.raises(ValueError):
                csr_row_block(a, r0, r1)


class TestShardSeam:
    def test_shard_units_per_family(self):
        """Each family's shard unit is its decode-slice / group / block
        row height at the given knobs — the alignment that keeps units
        from straddling shards."""
        assert get_format("dtans").shard_unit({"lane_width": 64}) == 64
        assert get_format("sell").shard_unit({"slice_height": 16}) == 16
        assert get_format("rgcsr").shard_unit({"group_size": 8}) == 8
        assert get_format("bcsr").shard_unit(
            {"block_shape": (4, 2)}) == 4
        assert get_format("rgcsr_dtans").shard_unit(
            {"group_size": 32}) == 32
        assert get_format("bcsr_dtans").shard_unit(
            {"block_shape": (2, 4)}) == 2
        for fmt in ("dense", "csr", "coo"):
            assert get_format(fmt).shard_unit() == 1

    @pytest.mark.parametrize("fmt",
                             [s.name for s in iter_formats()])
    def test_plan_invariants(self, fmt):
        spec = get_format(fmt)
        a = _rand_csr(70, 30, seed=7)
        kn = spec.conformance_knobs
        plan = spec.shard(a, 3, **kn)
        assert isinstance(plan, ShardPlan)
        assert plan.fmt == fmt and plan.n_shards == 3
        assert plan.shape == (70, 30)
        assert plan.unit == spec.shard_unit(spec._knobs(kn))
        assert len(plan.shards) == 3 and len(plan.shard_nbytes) == 3
        assert sum(plan.shard_rows) == 70
        assert plan.total_nbytes == sum(plan.shard_nbytes)
        assert plan.max_shard_nbytes == max(plan.shard_nbytes)
        assert all(b >= 0 for b in plan.shard_nbytes)

    def test_per_shard_nbytes_exact(self):
        """shard_nbytes matches the family's own exact accounting of
        each row block — the numbers the sharded cost model prices."""
        spec = get_format("dtans")
        a = _rand_csr(64, 24, seed=11)
        plan = spec.shard(a, 2, lane_width=16)
        for k in range(2):
            sub = csr_row_block(a, plan.boundaries[k],
                                plan.boundaries[k + 1])
            b = spec.nbytes_constructed(sub, lane_width=16)
            assert plan.shard_nbytes[k] == b

    def test_plan_validation(self):
        with pytest.raises(ValueError):
            ShardPlan(fmt="dtans", knobs=(), n_shards=2, unit=1,
                      boundaries=(0, 10), shards=((), ()),
                      shard_nbytes=(1, 1), shape=(10, 5),
                      dtype=np.float64)
