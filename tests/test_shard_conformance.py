"""Sharded-vs-single-device conformance: the ISSUE's acceptance bar.

For EVERY registered format family, at shards in {1, 2, 4} and B in
{1, 8}, the sharded execution paths must be BIT-IDENTICAL (exact
``==``, not allclose) to the single-device kernels:

* the sequential loop path (``mesh=None`` — every format has it via the
  registry-generic `FormatSpec.shard` / `shard_runner` seam), and
* the `shard_map` + psum collective path on a real multi-device mesh
  (the four kernel-backed packed types), using the 8-host-device
  fixture from conftest.

Bit-identity holds because a shard's kernel is exactly the
single-device kernel on its row block (decode is lossless; each row
accumulates in column order independent of its neighbours) and the
psum adds the true row values to zeros.  Formats auto-discover from
`repro.sparse.registry.iter_formats` — a newly registered spec joins
this sweep with zero edits here, exactly like the single-device
conformance suite.
"""

import numpy as np
import pytest
from test_spmv_conformance import CORPUS

from repro.kernels import ops, shard_ops
from repro.sparse.formats import CSR
from repro.sparse.registry import get_format, iter_formats
from repro.sparse.shard import shard_boundaries

SHARDS = (1, 2, 4)
BATCHES = (1, 8)
CASES = ("empty_rows", "powerlaw", "regular")


def _format_names():
    return [spec.name for spec in iter_formats()]


def _case(name, dtype=np.float64):
    return CSR.from_dense(CORPUS[name]().astype(dtype))


def _rhs(a, b, dtype=np.float64):
    rng = np.random.default_rng(42)
    return rng.standard_normal((a.shape[1], b)).astype(dtype)


def _reference(spec, a, x):
    """Single-device truth: the format's own packed artifact through
    its own (spmv at B == 1, spmm otherwise) runner."""
    kn = spec.conformance_knobs
    packed = spec.pack(a, **kn)
    if x.shape[1] == 1:
        y = np.asarray(spec.runner(packed, x[:, 0])())
        return y.reshape(-1)[:a.shape[0]][:, None]
    return np.asarray(spec.spmm_runner(packed, x)()
                      ).reshape(-1, x.shape[1])[:a.shape[0]]


@pytest.mark.parametrize("batch", BATCHES, ids=[f"B{b}" for b in BATCHES])
@pytest.mark.parametrize("n_shards", SHARDS,
                         ids=[f"S{k}" for k in SHARDS])
@pytest.mark.parametrize("fmt", _format_names())
@pytest.mark.parametrize("case", CASES)
def test_sharded_loop_bit_identical(case, fmt, n_shards, batch):
    """Sequential loop path (no mesh): every format, exact equality."""
    spec = get_format(fmt)
    a = _case(case)
    x = _rhs(a, batch)
    ref = _reference(spec, a, x)
    plan = spec.shard(a, n_shards, **spec.conformance_knobs)
    got = np.asarray(shard_ops.shard_spmm(plan, x))
    assert got.shape == ref.shape
    assert np.array_equal(got, ref), (
        f"{fmt} sharded loop diverges from the single-device kernel "
        f"at shards={n_shards} B={batch}")
    if batch == 1:
        gotv = np.asarray(shard_ops.shard_spmv(plan, x[:, 0]))
        assert np.array_equal(gotv, ref[:, 0])


@pytest.mark.parametrize("batch", BATCHES, ids=[f"B{b}" for b in BATCHES])
@pytest.mark.parametrize("n_shards", (2, 4),
                         ids=["S2", "S4"])
@pytest.mark.parametrize("fmt", _format_names())
@pytest.mark.parametrize("case", CASES)
def test_sharded_mesh_bit_identical(case, fmt, n_shards, batch,
                                    make_model_mesh):
    """shard_map + psum path on a real k-device mesh: every format with
    a collective-path adapter, exact equality (shards=1 needs no mesh —
    it IS the single-device path)."""
    spec = get_format(fmt)
    a = _case(case)
    plan = spec.shard(a, n_shards, **spec.conformance_knobs)
    if not shard_ops.supports_shard_map(plan):
        pytest.skip(f"{fmt} has no shard_map adapter (loop path only)")
    mesh = make_model_mesh(n_shards)
    x = _rhs(a, batch)
    ref = _reference(spec, a, x)
    got = np.asarray(shard_ops.shard_spmm(plan, x, mesh=mesh))
    assert np.array_equal(got, ref), (
        f"{fmt} shard_map path diverges from the single-device kernel "
        f"at shards={n_shards} B={batch}")


@pytest.mark.parametrize("fmt", _format_names())
def test_sharded_blocked_bit_identical(fmt):
    """Grid-blocked RHS through the sharded loop path: a training-shaped
    B = 64 pool with an explicit ragged bn (24 does not divide 64) and —
    for the entropy-decoding families — the pipelined decode must both
    equal the unblocked sharded pass exactly.  One plan per format; the
    tile knobs thread through `shard_spmm` -> per-shard run adapters ->
    the same kernels the single-device blocked conformance pins."""
    spec = get_format(fmt)
    a = _case("powerlaw")
    x = _rhs(a, 64)
    plan = spec.shard(a, 2, **spec.conformance_knobs)
    base = np.asarray(shard_ops.shard_spmm(plan, x))
    got = np.asarray(shard_ops.shard_spmm(plan, x, bn=24))
    assert np.array_equal(got, base), (
        f"{fmt}: sharded blocked pass (bn=24) diverges at B=64")
    if spec.decodes:
        pip = np.asarray(shard_ops.shard_spmm(plan, x, pipeline=True,
                                              bn=24))
        assert np.array_equal(pip, base), (
            f"{fmt}: sharded pipelined+blocked pass diverges at B=64")


@pytest.mark.parametrize("n_shards", SHARDS,
                         ids=[f"S{k}" for k in SHARDS])
def test_ops_mesh_knob_bit_identical(n_shards, make_model_mesh):
    """`ops.spmv`/`ops.spmm` with the mesh=/n_shards= knobs equal their
    single-device selves exactly — the public entry-point contract."""
    from repro.core.csr_dtans import encode_matrix
    a = _case("powerlaw")
    mat = encode_matrix(a, lane_width=16)
    x = _rhs(a, 8)
    kw = ({"mesh": make_model_mesh(n_shards)} if n_shards > 1
          else {"n_shards": 1})
    assert np.array_equal(np.asarray(ops.spmm(mat, x, **kw)),
                          np.asarray(ops.spmm(mat, x)))
    assert np.array_equal(np.asarray(ops.spmv(mat, x[:, 0], **kw)),
                          np.asarray(ops.spmv(mat, x[:, 0])))


def test_ops_shard_plan_cached_on_object():
    """Repeat sharded calls reuse the plan (one re-encode per shard
    count, like the packed-artifact cache)."""
    from repro.core.csr_dtans import encode_matrix
    a = _case("regular")
    mat = encode_matrix(a, lane_width=16)
    p1 = ops.get_shard_plan(mat, 2)
    p2 = ops.get_shard_plan(mat, 2)
    assert p1 is p2
    assert ops.get_shard_plan(mat, 4) is not p1


def test_mesh_shard_mismatch_raises(make_model_mesh):
    """A plan built for k shards refuses a mesh with a different model
    axis instead of silently mis-sharding."""
    spec = get_format("dtans")
    a = _case("regular")
    plan = spec.shard(a, 2, **spec.conformance_knobs)
    mesh = make_model_mesh(4)
    with pytest.raises(ValueError, match="model axis"):
        shard_ops.shard_spmm(plan, _rhs(a, 2), mesh=mesh)


def test_all_zero_matrix_all_shards():
    """The all-zero matrix (rows with no nonzeros) shards at every
    count and reproduces the zero result."""
    spec = get_format("dtans")
    a = _case("empty")              # 20 x 30, zero nonzeros
    for k in SHARDS:
        plan = spec.shard(a, k, **spec.conformance_knobs)
        got = np.asarray(shard_ops.shard_spmm(plan, _rhs(a, 3)))
        assert got.shape == (a.shape[0], 3)
        assert not got.any()


def test_zero_row_matrix_all_shards():
    """The genuinely 0-row matrix shards legally at every count (all
    shards empty) and returns the (0, B) result."""
    spec = get_format("dtans")
    a = CSR(indptr=np.zeros(1, np.int64), indices=np.zeros(0, np.int64),
            values=np.zeros(0, np.float64), shape=(0, 30))
    for k in SHARDS:
        assert shard_boundaries(0, k) == (0,) * (k + 1)
        plan = spec.shard(a, k, **spec.conformance_knobs)
        got = np.asarray(shard_ops.shard_spmm(plan, _rhs(a, 3)))
        assert got.shape == (0, 3)
