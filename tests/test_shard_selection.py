"""Sharding-aware selection: `collective_time` pricing,
`candidate_time(n_shards=)`, `shard_counts`, `Decision.n_shards`
round-trip / cache back-compat, cache-key separation, and zero
selector-vs-sharded-oracle regret — the ISSUE's selection-layer
acceptance bar, sharing the conftest 8-host-device mesh.
"""

import numpy as np
import pytest

from repro.autotune import (DecisionCache, V5E, candidate_time,
                            clear_memo, collective_time, fingerprint,
                            oracle_times, select, shard_counts)
from repro.autotune.search import Decision
from repro.sparse.formats import CSR
from repro.sparse.random_graphs import banded, erdos_renyi, stencil_2d


def _f32(a: CSR) -> CSR:
    return CSR(a.indptr, a.indices, a.values.astype(np.float32), a.shape)


def _suite() -> dict:
    rng = np.random.default_rng(7)
    return {
        "stencil": stencil_2d(40),
        "banded": banded(2500, 6),
        "er": erdos_renyi(1500, 10, rng),
        "er_big": erdos_renyi(8000, 100, rng),
        "tiny": erdos_renyi(120, 5, rng),
    }


#: Plain (non-entropy) families: keeps the exhaustive sharded oracle
#: cheap on the 800k-nnz suite member — the regret bar is per swept
#: format set, and the entropy families' sharded pricing is covered by
#: the same `candidate_time(n_shards=)` path.
_FMTS = ("csr", "coo", "sell", "rgcsr", "bcsr")


class TestCollectiveTime:
    def test_zero_at_one_shard(self):
        assert collective_time(1, rows=1000, cols=1000, vbytes=4) == 0.0

    def test_monotone_in_shards(self):
        """Wire volume (k-1)/k and the log2(k) latency rung both grow
        with k — more chips never makes the collective cheaper."""
        ts = [collective_time(k, rows=4000, cols=4000, vbytes=4,
                              batch=8) for k in (2, 4, 8, 16)]
        assert all(a < b for a, b in zip(ts, ts[1:]))
        assert ts[0] > 0

    def test_scales_with_batch_and_vector_size(self):
        t1 = collective_time(4, rows=1000, cols=1000, vbytes=4)
        t8 = collective_time(4, rows=1000, cols=1000, vbytes=4, batch=8)
        # latency rungs are batch-independent; only the wire term scales
        lat = 2 * V5E.collective_latency * 2
        assert t8 - lat == pytest.approx(8 * (t1 - lat))

    def test_candidate_time_prices_shards(self):
        """k-way pricing: compute terms and matrix bytes split k ways,
        the collective is added — so a batched pass over a big matrix
        gets faster with shards (per-RHS work amortizes the fixed
        latency rungs) while a small single-RHS pass does not."""
        big = _f32(erdos_renyi(4000, 40, np.random.default_rng(3)))
        fp = fingerprint(big)
        t1 = candidate_time(fp, "csr", csr_nbytes_of(fp), warm=True,
                            batch=32)
        t4 = candidate_time(fp, "csr", csr_nbytes_of(fp), warm=True,
                            batch=32, n_shards=4)
        assert t4 < t1
        tiny = _f32(erdos_renyi(60, 3, np.random.default_rng(4)))
        fpt = fingerprint(tiny)
        assert candidate_time(fpt, "csr", csr_nbytes_of(fpt),
                              warm=True, n_shards=4) > \
            candidate_time(fpt, "csr", csr_nbytes_of(fpt), warm=True)


def csr_nbytes_of(fp):
    from repro.autotune import csr_nbytes
    return csr_nbytes(fp)


class TestShardCounts:
    def test_explicit_wins(self, make_model_mesh):
        assert shard_counts(n_shards=3) == (3,)
        assert shard_counts(make_model_mesh(4), n_shards=2) == (2,)

    def test_mesh_powers_of_two(self, make_model_mesh):
        assert shard_counts(make_model_mesh(4)) == (1, 2, 4)
        assert shard_counts(make_model_mesh(8)) == (1, 2, 4, 8)
        assert shard_counts(make_model_mesh(1)) == (1,)

    def test_default(self):
        assert shard_counts() == (1,)

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            shard_counts(n_shards=0)


class TestShardedDecision:
    def test_roundtrip_and_back_compat(self):
        a = _f32(banded(400, 4))
        clear_memo()
        d = select(a, n_shards=2, cache=DecisionCache(path=None))
        assert d.n_shards == 2
        assert Decision.from_dict(d.to_dict()) == d
        # decisions cached before the sharding layer carry no n_shards
        # key: they must load as single-chip decisions, not crash
        old = {k: v for k, v in d.to_dict().items() if k != "n_shards"}
        assert Decision.from_dict(old).n_shards == 1

    def test_single_chip_key_unchanged(self):
        """select(n_shards=1) must hit the same cache row as plain
        select — pre-sharding caches stay warm."""
        a = _f32(banded(400, 4))
        cache = DecisionCache(path=None)
        clear_memo()
        select(a, cache=cache)
        select(a, n_shards=1, cache=cache)
        assert len(cache) == 1

    def test_mesh_sweep_is_separate_key(self, make_model_mesh):
        a = _f32(banded(400, 4))
        cache = DecisionCache(path=None)
        clear_memo()
        select(a, cache=cache)
        select(a, mesh=make_model_mesh(4), cache=cache)
        assert len(cache) == 2

    def test_measure_with_shards_rejected(self):
        with pytest.raises(ValueError, match="measure"):
            select(_f32(banded(300, 3)), n_shards=2, measure=True,
                   cache=DecisionCache(path=None))


class TestShardedSelector:
    _ENC: dict = {}

    def test_zero_regret_vs_sharded_oracle(self, make_model_mesh):
        """`select(mesh=)` sweeps shard counts {1, 2, 4} and must land
        on the sharded oracle's argmin exactly (same cost model, full
        enumeration — the acceptance bar is regret 0, and the spelled
        leaderboard keys must match the oracle's).  Priced streaming
        (warm=False): matrix bytes dominate there, so the big suite
        member genuinely wants chips while the tiny ones stay
        latency-bound on one."""
        mesh = make_model_mesh(4)
        cache = DecisionCache(path=None)
        sharded_pick = 0
        for name, a64 in _suite().items():
            a = _f32(a64)
            clear_memo()
            dec = select(a, warm=False, mesh=mesh, formats=_FMTS,
                         cache=cache)
            times = oracle_times(
                a, warm=False, formats=_FMTS, n_shards=(1, 2, 4),
                encode_cache=self._ENC.setdefault(name, {}))
            key = (dec.config_name if dec.n_shards == 1
                   else f"{dec.config_name}@S{dec.n_shards}")
            assert key in times
            t_best = min(times.values())
            regret = times[key] / t_best - 1.0
            assert regret <= 1e-12, \
                f"{name}: pick={key} regret={regret:.4g}"
            sharded_pick += dec.n_shards > 1
        # the sweep must actually use the mesh somewhere: at least one
        # suite matrix is big enough that k > 1 wins
        assert sharded_pick >= 1, "no matrix picked a sharded config"

    def test_big_matrix_shards_tiny_does_not(self):
        """Directional sanity on the interconnect terms: the 2500-row
        banded matrix amortizes the collective, the 120-row one is
        latency-bound and stays single-chip."""
        cache = DecisionCache(path=None)
        clear_memo()
        suite = _suite()
        big = select(_f32(suite["banded"]), warm=True, n_shards=4,
                     cache=cache)
        assert big.n_shards == 4         # forced count is honored
        clear_memo()
        pick = select(_f32(suite["tiny"]), warm=True,
                      n_shards=None, cache=cache)
        assert pick.n_shards == 1
