"""Regression coverage for the `launch.sharding.ShardingRules`
divisibility guard: a dim that does not divide its mesh axis must stay
REPLICATED (spec entry None) rather than producing a PartitionSpec that
fails to lower — the contract the module docstring states but nothing
previously tested.  Covers the `_div` guard itself, `param_spec` /
`opt_spec` on non-dividing dims, the `dp_only` folding branch, and the
fsdp-threshold (`should_fsdp`) branch, on real 8-host-device meshes
from the conftest fixture's XLA flag.
"""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import (FSDP_PARAM_THRESHOLD, ShardingRules,
                                   _div, should_fsdp)
from repro.models.config import ArchConfig


def _mesh(shape, axes):
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} host devices")
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=8, n_kv_heads=8, d_ff=256, vocab=1000)
    base.update(kw)
    return ArchConfig(**base)


class _Key:
    def __init__(self, key):
        self.key = key


def test_div_guard():
    """The guard itself: only size > 1 AND exact divisibility shard."""
    assert _div(64, 4)
    assert not _div(63, 4)      # non-dividing dim
    assert not _div(64, 1)      # trivial axis never shards
    assert _div(0, 4)           # 0 % 4 == 0: (degenerate) divisible


@pytest.mark.parametrize("d_model", [64, 63],
                         ids=["dividing", "non-dividing"])
def test_param_spec_divisibility(d_model):
    """head weight (d_model, vocab): the model-axis entry appears only
    when vocab divides the model axis; a non-dividing dim is replicated
    (None), never a lowering error."""
    mesh = _mesh((2, 4), ("data", "model"))
    vocab = 1000 if d_model == 64 else 1001   # 1001 % 4 != 0
    cfg = _cfg(d_model=d_model, vocab=vocab)
    rules = ShardingRules(cfg, mesh, fsdp=False)
    leaf = jax.ShapeDtypeStruct((d_model, vocab), np.float32)
    spec = rules.param_spec((_Key("head"),), leaf)
    if vocab % 4 == 0:
        assert spec == P(None, "model")
    else:
        assert spec == P(None, None)
    # the spec must lower against the mesh regardless
    jax.sharding.NamedSharding(mesh, spec)


def test_param_spec_fsdp_divisibility():
    """FSDP dim-0 sharding also guards: dim 0 not dividing the data
    axis stays replicated while the TP dim still shards."""
    mesh = _mesh((4, 2), ("data", "model"))
    rules = ShardingRules(_cfg(), mesh, fsdp=True)
    ok = jax.ShapeDtypeStruct((64, 128), np.float32)       # 64 % 4 == 0
    bad = jax.ShapeDtypeStruct((63, 128), np.float32)      # 63 % 4 != 0
    assert rules.param_spec((_Key("wq"),), ok) == P("data", "model")
    assert rules.param_spec((_Key("wq"),), bad) == P(None, "model")


def test_dp_only_folds_model_axis():
    """dp_only: msize collapses to 1 so NO weight dim ever takes the
    model axis (everything tensor-parallel becomes replicated), fsdp is
    forced off, and the batch folds the model axis into data
    parallelism."""
    mesh = _mesh((2, 4), ("data", "model"))
    rules = ShardingRules(_cfg(), mesh, dp_only=True)
    assert rules.msize == 1 and rules.fsdp is False
    leaf = jax.ShapeDtypeStruct((64, 64), np.float32)
    assert rules.param_spec((_Key("wq"),), leaf) == P(None, None)
    # batch of 8 = 2 (data) x 4 (model): dp_only folds both axes
    assert rules.batch_axis(8) == ("data", "model")
    # without dp_only the same batch splits over data alone
    assert ShardingRules(_cfg(), mesh, fsdp=False).batch_axis(8) == "data"


def test_batch_axis_non_dividing_batch_replicates():
    """A global batch no candidate axis set divides stays replicated
    (None) — e.g. batch=1 on a multi-chip mesh."""
    mesh = _mesh((2, 4), ("data", "model"))
    rules = ShardingRules(_cfg(), mesh, fsdp=False)
    assert rules.batch_axis(1) is None
    assert rules.batch_axis(3) is None


def test_fsdp_threshold_branches():
    """`should_fsdp` flips exactly on the analytic parameter estimate
    crossing FSDP_PARAM_THRESHOLD, and ShardingRules honors it as the
    fsdp default."""
    small = _cfg()                       # ~ hundreds of k params
    big = _cfg(n_layers=80, d_model=16384, n_heads=128, n_kv_heads=8,
               d_ff=53248, vocab=128256)   # 405B-scale head
    assert not should_fsdp(small)
    assert should_fsdp(big)
    assert FSDP_PARAM_THRESHOLD == 10e9
    mesh = _mesh((2, 4), ("data", "model"))
    assert ShardingRules(small, mesh).fsdp is False
    assert ShardingRules(big, mesh).fsdp is True
    # dp_only overrides even an above-threshold config
    assert ShardingRules(big, mesh, dp_only=True).fsdp is False


def test_opt_spec_zero1_divisibility():
    """ZeRO-1 optimizer sharding takes dim 0 only when free AND
    divisible; otherwise the param spec passes through untouched."""
    mesh = _mesh((4, 2), ("data", "model"))
    rules = ShardingRules(_cfg(), mesh, fsdp=False, zero1=True)
    assert rules.opt_spec(P(None, "model"), (64, 128)) == \
        P("data", "model")
    assert rules.opt_spec(P(None, "model"), (63, 128)) == \
        P(None, "model")                       # 63 % 4 != 0: replicated
    assert rules.opt_spec(P("model", None), (64, 128)) == \
        P("model", None)                       # dim 0 taken: untouched


def test_params_pspecs_lower_on_mesh():
    """End to end: a small param tree with deliberately non-dividing
    dims produces specs that all lower into NamedShardings."""
    mesh = _mesh((2, 4), ("data", "model"))
    cfg = _cfg(vocab=1001)
    rules = ShardingRules(cfg, mesh, fsdp=False)
    tree = {"tok": jax.ShapeDtypeStruct((1001, 63), np.float32),
            "layers": {"wq": jax.ShapeDtypeStruct((2, 63, 63),
                                                  np.float32)}}
    specs = rules.params_pspecs(tree)
    named = rules.named(specs)
    flat = jax.tree.leaves(named,
                           is_leaf=lambda x: hasattr(x, "spec"))
    assert all(hasattr(s, "spec") for s in flat)
    # non-dividing dims everywhere -> fully replicated specs
    leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert sorted(leaves, key=len) == [P(None, None),
                                       P(None, None, None)]
