"""MatrixMarket loader tests (repro.sparse.io)."""

import gzip
import io

import numpy as np
import pytest

from repro.sparse.formats import CSR
from repro.sparse.io import load_mtx, save_mtx
from repro.sparse.random_graphs import banded, stencil_2d


def _same(a: CSR, b: CSR, tol=0.0):
    assert a.shape == b.shape
    assert np.array_equal(a.indptr, b.indptr)
    assert np.array_equal(a.indices, b.indices)
    if tol:
        np.testing.assert_allclose(a.values, b.values, rtol=tol)
    else:
        assert np.array_equal(a.values, b.values)


class TestRoundtrip:
    def test_file_roundtrip(self, tmp_path):
        a = banded(200, 5)
        p = tmp_path / "banded.mtx"
        save_mtx(p, a)
        _same(a, load_mtx(p))

    def test_gzip_roundtrip(self, tmp_path):
        a = stencil_2d(12)
        p = tmp_path / "stencil.mtx.gz"
        save_mtx(p, a)
        _same(a, load_mtx(p))

    def test_stringio_roundtrip(self):
        rng = np.random.default_rng(0)
        d = rng.standard_normal((13, 17))
        d[rng.random(d.shape) < 0.8] = 0
        a = CSR.from_dense(d)
        buf = io.StringIO()
        save_mtx(buf, a, comment="test matrix\nsecond line")
        buf.seek(0)
        _same(a, load_mtx(buf))

    def test_empty_rows_and_shape_preserved(self):
        d = np.zeros((9, 4))
        d[0, 1] = 2.5
        d[8, 0] = -1.0
        a = CSR.from_dense(d)
        buf = io.StringIO()
        save_mtx(buf, a)
        buf.seek(0)
        b = load_mtx(buf)
        _same(a, b)
        assert b.shape == (9, 4)


class TestFields:
    def test_pattern(self):
        txt = ("%%MatrixMarket matrix coordinate pattern general\n"
               "% comment\n3 4 3\n1 1\n2 3\n3 4\n")
        a = load_mtx(io.StringIO(txt))
        assert a.shape == (3, 4) and a.nnz == 3
        assert (a.values == 1.0).all()
        assert a.to_dense()[1, 2] == 1.0

    def test_integer(self):
        txt = ("%%MatrixMarket matrix coordinate integer general\n"
               "2 2 2\n1 1 7\n2 2 -3\n")
        a = load_mtx(io.StringIO(txt))
        assert a.to_dense()[0, 0] == 7.0
        assert a.to_dense()[1, 1] == -3.0

    def test_symmetric_expands(self):
        txt = ("%%MatrixMarket matrix coordinate real symmetric\n"
               "3 3 3\n1 1 2.0\n2 1 5.0\n3 2 -1.0\n")
        d = load_mtx(io.StringIO(txt)).to_dense()
        assert d[0, 1] == d[1, 0] == 5.0
        assert d[1, 2] == d[2, 1] == -1.0
        assert d[0, 0] == 2.0

    def test_skew_symmetric(self):
        txt = ("%%MatrixMarket matrix coordinate real skew-symmetric\n"
               "2 2 1\n2 1 4.0\n")
        d = load_mtx(io.StringIO(txt)).to_dense()
        assert d[1, 0] == 4.0 and d[0, 1] == -4.0

    def test_array_general(self):
        # column-major body of [[1, 3], [2, 4]]
        txt = ("%%MatrixMarket matrix array real general\n"
               "2 2\n1\n2\n3\n4\n")
        d = load_mtx(io.StringIO(txt)).to_dense()
        np.testing.assert_array_equal(d, [[1.0, 3.0], [2.0, 4.0]])

    def test_zero_nnz(self):
        txt = "%%MatrixMarket matrix coordinate real general\n4 5 0\n"
        a = load_mtx(io.StringIO(txt))
        assert a.shape == (4, 5) and a.nnz == 0


class TestErrors:
    @pytest.mark.parametrize("header", [
        "not a banner at all\n1 1 0\n",
        "%%MatrixMarket matrix coordinate complex general\n1 1 0\n",
        "%%MatrixMarket matrix coordinate real hermitian\n1 1 0\n",
        "%%MatrixMarket tensor coordinate real general\n1 1 0\n",
    ])
    def test_bad_headers(self, header):
        with pytest.raises(ValueError):
            load_mtx(io.StringIO(header))

    def test_out_of_range_index(self):
        txt = ("%%MatrixMarket matrix coordinate real general\n"
               "2 2 1\n3 1 1.0\n")
        with pytest.raises(ValueError):
            load_mtx(io.StringIO(txt))

    def test_values_precision_roundtrip(self):
        a = CSR.from_dense(np.array([[np.pi, 0.0], [0.0, 1e-300]]))
        buf = io.StringIO()
        save_mtx(buf, a)
        buf.seek(0)
        _same(a, load_mtx(buf))  # %.17g is bit-exact for float64

    def test_gzipped_bytes_header(self, tmp_path):
        p = tmp_path / "x.mtx.gz"
        with gzip.open(p, "wt") as f:
            f.write("%%MatrixMarket matrix coordinate real general\n"
                    "1 1 1\n1 1 9.0\n")
        assert load_mtx(p).to_dense()[0, 0] == 9.0
