"""Cross-kernel SpMV conformance: every SpMV path in the repo against
dense ``A @ x`` on one shared adversarial corpus.

Two axes, fully parameterized:

* ``SPMV_PATHS`` — name -> callable(a: CSR, x) -> y. The hand-written
  reference paths (numpy references, the gold decode path, the pure-jnp
  oracles) register here once, and `registry_spmv_paths` auto-discovers
  one kernel path per format in `repro.sparse.registry` — a format
  registered through the registry joins the whole corpus with ZERO
  edits to this file (asserted by tests/test_registry.py's toy spec).
* ``CORPUS`` — name -> dense matrix builder covering the adversarial
  structure zoo: empty matrix, empty rows, one dense row among empties,
  power-law row lengths, all-equal values, plus a regular baseline.

Each (path, case, dtype) triple asserts against the dense product to
1e-5 (float32) / 1e-12 (float64) — the ISSUE's acceptance bar.
"""

import functools

import numpy as np
import pytest

from repro.core.bcsr_dtans import encode_bcsr_matrix
from repro.core.csr_dtans import encode_matrix, spmv_gold
from repro.core.rgcsr_dtans import encode_rgcsr_matrix
from repro.kernels import ops
from repro.kernels.bcsr_spmv import bcsr_spmv_ref, pack_bcsr
from repro.kernels.pack import pack_matrix
from repro.kernels.ref import spmv_ref
from repro.kernels.rgcsr_spmv import pack_rgcsr, rgcsr_spmv_ref
from repro.kernels.sell_spmv import pack_sell, sell_spmv_ref
from repro.sparse.bcsr import BCSR
from repro.sparse.formats import CSR
from repro.sparse.registry import iter_formats
from repro.sparse.rgcsr import RGCSR

# --------------------------------------------------------------------------
# SpMV path registry: one line per implementation.
# --------------------------------------------------------------------------


def _csr_ref(a: CSR, x):
    """Row-sequential numpy CSR SpMV (the scalar reference)."""
    y = np.zeros(a.shape[0], dtype=a.values.dtype)
    for i in range(a.shape[0]):
        s, e = a.indptr[i], a.indptr[i + 1]
        y[i] = a.values[s:e] @ x[a.indices[s:e]]
    return y


def _sell_kernel(a: CSR, x):
    return np.asarray(ops.sell_spmv(pack_sell(a, lane_width=16), x))


def _sell_oracle(a: CSR, x):
    ps = pack_sell(a, lane_width=16)
    return np.asarray(sell_spmv_ref(ps.indices, ps.values, x)
                      ).reshape(-1)[:a.shape[0]]


def _rgcsr_kernel(a: CSR, x):
    return np.asarray(ops.rgcsr_spmv(pack_rgcsr(RGCSR.from_csr(a, 8)), x))


def _rgcsr_ref(a: CSR, x):
    pr = pack_rgcsr(RGCSR.from_csr(a, 8))
    return np.asarray(rgcsr_spmv_ref(pr.deltas, pr.values, pr.nnz, x)
                      ).reshape(-1)[:a.shape[0]]


def _rgcsr_numpy(a: CSR, x):
    return RGCSR.from_csr(a, 4).spmv(np.asarray(x, dtype=a.values.dtype))


def _dtans_gold(a: CSR, x):
    return spmv_gold(encode_matrix(a, lane_width=16), x)


def _dtans_oracle(a: CSR, x):
    return np.asarray(spmv_ref(pack_matrix(encode_matrix(a,
                                                         lane_width=16)),
                               x))


def _dtans_kernel(a: CSR, x):
    return np.asarray(ops.spmv(encode_matrix(a, lane_width=16), x))


def _rgcsr_dtans_gold(a: CSR, x):
    return spmv_gold(encode_rgcsr_matrix(a, group_size=8), x)


def _rgcsr_dtans_kernel(a: CSR, x):
    return np.asarray(ops.spmv(encode_rgcsr_matrix(a, group_size=8), x))


def _bcsr_numpy(a: CSR, x):
    return BCSR.from_csr(a, (4, 4)).spmv(np.asarray(x,
                                                    dtype=a.values.dtype))


def _bcsr_oracle(a: CSR, x):
    pb = pack_bcsr(BCSR.from_csr(a, (2, 2)))
    return np.asarray(bcsr_spmv_ref(pb.block_cols, pb.values, x)
                      ).reshape(-1)[:a.shape[0]]


def _bcsr_dtans_gold(a: CSR, x):
    return spmv_gold(encode_bcsr_matrix(a, block_shape=(2, 2)), x)


def _registry_path(spec, a: CSR, x):
    return np.asarray(spec.spmv(a, x, **spec.conformance_knobs)
                      ).reshape(-1)[:a.shape[0]]


def registry_spmv_paths() -> dict:
    """One kernel path per registered format, auto-discovered — the
    registry analogue of the hand-written entries below. Evaluated at
    call time so a format registered mid-session (tests) shows up."""
    return {f"registry:{spec.name}": functools.partial(_registry_path,
                                                       spec)
            for spec in iter_formats()}


#: Hand-written reference paths; the registry kernel paths are added at
#: collection via `registry_spmv_paths`.
SPMV_PATHS = {
    "csr_ref": _csr_ref,
    "rgcsr_numpy": _rgcsr_numpy,
    "bcsr_numpy": _bcsr_numpy,
    "bcsr_oracle": _bcsr_oracle,
    "sell_oracle": _sell_oracle,
    "sell_kernel": _sell_kernel,
    "rgcsr_oracle": _rgcsr_ref,
    "rgcsr_kernel": _rgcsr_kernel,
    "dtans_gold": _dtans_gold,
    "dtans_oracle": _dtans_oracle,
    "dtans_kernel": _dtans_kernel,
    "rgcsr_dtans_gold": _rgcsr_dtans_gold,
    "rgcsr_dtans_kernel": _rgcsr_dtans_kernel,
    "bcsr_dtans_gold": _bcsr_dtans_gold,
    **registry_spmv_paths(),
}

# --------------------------------------------------------------------------
# Adversarial corpus: name -> dense matrix (float64 master copy).
# --------------------------------------------------------------------------


def _empty():
    return np.zeros((20, 30))


def _empty_rows():
    d = np.zeros((37, 23))
    d[3, 1:20:3] = np.arange(1.0, 8.0)
    d[20, 22] = -4.0
    return d


def _one_dense_row():
    d = np.zeros((40, 50))
    d[17, :] = np.linspace(-2, 2, 50)
    d[0, 0] = 1.0
    return d


def _powerlaw():
    rng = np.random.default_rng(13)
    m, n = 60, 45
    d = np.zeros((m, n))
    lens = np.minimum(rng.zipf(1.5, size=m), n)
    for i, k in enumerate(lens):
        cols = rng.choice(n, size=int(k), replace=False)
        d[i, cols] = np.round(rng.standard_normal(int(k)) * 2) / 2 + 0.25
    return d


def _all_equal_values():
    rng = np.random.default_rng(14)
    d = np.where(rng.random((31, 29)) < 0.25, 0.5, 0.0)
    return d


def _regular():
    d = np.zeros((48, 48))
    idx = np.arange(48)
    for off in (-2, 0, 3):
        sel = (idx + off >= 0) & (idx + off < 48)
        d[idx[sel], idx[sel] + off] = 1.0 + 0.125 * idx[sel]
    return d


CORPUS = {
    "empty": _empty,
    "empty_rows": _empty_rows,
    "one_dense_row": _one_dense_row,
    "powerlaw": _powerlaw,
    "all_equal_values": _all_equal_values,
    "regular": _regular,
}

TOL = {np.float32: 1e-5, np.float64: 1e-12}


@pytest.fixture(scope="module", params=list(CORPUS), ids=list(CORPUS))
def dense_case(request):
    return request.param, CORPUS[request.param]()


#: The three public ops entry points share one ``(mat, x, y=None)``
#: signature — the timing harness (`repro.autotune.measure`) drives them
#: interchangeably. name -> packed-artifact builder + runner.
OPS_ACCUMULATE = {
    "ops.spmv": lambda a, x, y: ops.spmv(
        encode_matrix(a, lane_width=16), x, y),
    "ops.sell_spmv": lambda a, x, y: ops.sell_spmv(
        pack_sell(a, lane_width=16), x, y),
    "ops.rgcsr_spmv": lambda a, x, y: ops.rgcsr_spmv(
        pack_rgcsr(RGCSR.from_csr(a, 8)), x, y),
    "ops.bcsr_spmv": lambda a, x, y: ops.bcsr_spmv(
        pack_bcsr(BCSR.from_csr(a, (4, 4))), x, y),
}


@pytest.mark.parametrize("entry", list(OPS_ACCUMULATE),
                         ids=list(OPS_ACCUMULATE))
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_ops_accumulate_y(entry, dtype):
    """y = A x + y through every ops entry point (shared signature)."""
    d = CORPUS["regular"]().astype(dtype)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(7)
    x = rng.standard_normal(a.shape[1]).astype(dtype)
    y0 = rng.standard_normal(a.shape[0]).astype(dtype)
    got = np.asarray(OPS_ACCUMULATE[entry](a, x, y0))
    tol = TOL[dtype]
    np.testing.assert_allclose(got, d @ x + y0, rtol=tol, atol=tol,
                               err_msg=f"{entry} accumulate diverges")


@pytest.mark.parametrize("path", list(SPMV_PATHS), ids=list(SPMV_PATHS))
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_spmv_conformance(dense_case, path, dtype):
    name, d64 = dense_case
    d = d64.astype(dtype)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(99)
    x = rng.standard_normal(a.shape[1]).astype(dtype)
    got = np.asarray(SPMV_PATHS[path](a, x))
    want = d @ x
    assert got.shape == want.shape, f"{path} on {name}: shape mismatch"
    tol = TOL[dtype]
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol,
                               err_msg=f"{path} diverges from dense "
                                       f"A@x on corpus case {name!r}")
