"""Cross-kernel SpMV/SpMM conformance: every SpMV path in the repo
against dense ``A @ x`` on one shared adversarial corpus, and every
registered format's multi-RHS path against dense ``A @ X`` over a
batch sweep.

Axes, fully parameterized:

* ``SPMV_PATHS`` — name -> callable(a: CSR, x) -> y. The hand-written
  reference paths (numpy references, the gold decode path, the pure-jnp
  oracles) register here once, and `registry_spmv_paths` auto-discovers
  one kernel path per format in `repro.sparse.registry` — a format
  registered through the registry joins the whole corpus with ZERO
  edits to this file (asserted by tests/test_registry.py's toy spec).
* ``SPMM_PATHS`` — the batched analogue: `registry_spmm_paths`
  discovers one `FormatSpec.spmm` path per registered format, swept
  over B in {1, 3, 8} x both dtypes (fused Pallas SpMM kernels where
  the format has one, the generic per-column fallback otherwise).
* ``CORPUS`` — name -> dense matrix builder covering the adversarial
  structure zoo: empty matrix, empty rows, one dense row among empties,
  power-law row lengths, all-equal values, plus a regular baseline.

Each (path, case, dtype) triple asserts against the dense product to
1e-5 (float32) / 1e-12 (float64) — the ISSUE's acceptance bar. The
``ops`` SpMM entry points are additionally pinned bit-identical to
their SpMV siblings at B == 1.
"""

import functools

import numpy as np
import pytest

from repro.core.bcsr_dtans import encode_bcsr_matrix
from repro.core.csr_dtans import encode_matrix, spmv_gold
from repro.core.rgcsr_dtans import encode_rgcsr_matrix
from repro.kernels import ops
from repro.kernels.bcsr_spmv import bcsr_spmv_ref, pack_bcsr
from repro.kernels.pack import pack_matrix
from repro.kernels.ref import spmv_ref
from repro.kernels.rgcsr_spmv import pack_rgcsr, rgcsr_spmv_ref
from repro.kernels.sell_spmv import pack_sell, sell_spmv_ref
from repro.sparse.bcsr import BCSR
from repro.sparse.formats import CSR
from repro.sparse.registry import iter_formats
from repro.sparse.rgcsr import RGCSR

# --------------------------------------------------------------------------
# SpMV path registry: one line per implementation.
# --------------------------------------------------------------------------


def _csr_ref(a: CSR, x):
    """Row-sequential numpy CSR SpMV (the scalar reference)."""
    y = np.zeros(a.shape[0], dtype=a.values.dtype)
    for i in range(a.shape[0]):
        s, e = a.indptr[i], a.indptr[i + 1]
        y[i] = a.values[s:e] @ x[a.indices[s:e]]
    return y


def _sell_kernel(a: CSR, x):
    return np.asarray(ops.sell_spmv(pack_sell(a, lane_width=16), x))


def _sell_oracle(a: CSR, x):
    ps = pack_sell(a, lane_width=16)
    return np.asarray(sell_spmv_ref(ps.indices, ps.values, x)
                      ).reshape(-1)[:a.shape[0]]


def _rgcsr_kernel(a: CSR, x):
    return np.asarray(ops.rgcsr_spmv(pack_rgcsr(RGCSR.from_csr(a, 8)), x))


def _rgcsr_ref(a: CSR, x):
    pr = pack_rgcsr(RGCSR.from_csr(a, 8))
    return np.asarray(rgcsr_spmv_ref(pr.deltas, pr.values, pr.nnz, x)
                      ).reshape(-1)[:a.shape[0]]


def _rgcsr_numpy(a: CSR, x):
    return RGCSR.from_csr(a, 4).spmv(np.asarray(x, dtype=a.values.dtype))


def _dtans_gold(a: CSR, x):
    return spmv_gold(encode_matrix(a, lane_width=16), x)


def _dtans_oracle(a: CSR, x):
    return np.asarray(spmv_ref(pack_matrix(encode_matrix(a,
                                                         lane_width=16)),
                               x))


def _dtans_kernel(a: CSR, x):
    return np.asarray(ops.spmv(encode_matrix(a, lane_width=16), x))


def _rgcsr_dtans_gold(a: CSR, x):
    return spmv_gold(encode_rgcsr_matrix(a, group_size=8), x)


def _rgcsr_dtans_kernel(a: CSR, x):
    return np.asarray(ops.spmv(encode_rgcsr_matrix(a, group_size=8), x))


def _bcsr_numpy(a: CSR, x):
    return BCSR.from_csr(a, (4, 4)).spmv(np.asarray(x,
                                                    dtype=a.values.dtype))


def _bcsr_oracle(a: CSR, x):
    pb = pack_bcsr(BCSR.from_csr(a, (2, 2)))
    return np.asarray(bcsr_spmv_ref(pb.block_cols, pb.values, x)
                      ).reshape(-1)[:a.shape[0]]


def _bcsr_dtans_gold(a: CSR, x):
    return spmv_gold(encode_bcsr_matrix(a, block_shape=(2, 2)), x)


def _registry_path(spec, a: CSR, x):
    return np.asarray(spec.spmv(a, x, **spec.conformance_knobs)
                      ).reshape(-1)[:a.shape[0]]


def registry_spmv_paths() -> dict:
    """One kernel path per registered format, auto-discovered — the
    registry analogue of the hand-written entries below. Evaluated at
    call time so a format registered mid-session (tests) shows up."""
    return {f"registry:{spec.name}": functools.partial(_registry_path,
                                                       spec)
            for spec in iter_formats()}


def _registry_spmm_path(spec, a: CSR, X):
    return np.asarray(spec.spmm(a, X, **spec.conformance_knobs)
                      ).reshape(-1, X.shape[1])[:a.shape[0]]


def registry_spmm_paths() -> dict:
    """One MULTI-RHS kernel path per registered format — the batched
    analogue of `registry_spmv_paths`. Formats with a fused SpMM
    kernel run it; the rest run the generic per-column fallback of
    `FormatSpec.spmm_runner`, so a third-party spec with only the
    single-vector contract still joins the B-sweep."""
    return {f"registry:{spec.name}": functools.partial(
                _registry_spmm_path, spec)
            for spec in iter_formats()}


#: Hand-written reference paths; the registry kernel paths are added at
#: collection via `registry_spmv_paths`.
SPMV_PATHS = {
    "csr_ref": _csr_ref,
    "rgcsr_numpy": _rgcsr_numpy,
    "bcsr_numpy": _bcsr_numpy,
    "bcsr_oracle": _bcsr_oracle,
    "sell_oracle": _sell_oracle,
    "sell_kernel": _sell_kernel,
    "rgcsr_oracle": _rgcsr_ref,
    "rgcsr_kernel": _rgcsr_kernel,
    "dtans_gold": _dtans_gold,
    "dtans_oracle": _dtans_oracle,
    "dtans_kernel": _dtans_kernel,
    "rgcsr_dtans_gold": _rgcsr_dtans_gold,
    "rgcsr_dtans_kernel": _rgcsr_dtans_kernel,
    "bcsr_dtans_gold": _bcsr_dtans_gold,
    **registry_spmv_paths(),
}

# --------------------------------------------------------------------------
# Adversarial corpus: name -> dense matrix (float64 master copy).
# --------------------------------------------------------------------------


def _empty():
    return np.zeros((20, 30))


def _empty_rows():
    d = np.zeros((37, 23))
    d[3, 1:20:3] = np.arange(1.0, 8.0)
    d[20, 22] = -4.0
    return d


def _one_dense_row():
    d = np.zeros((40, 50))
    d[17, :] = np.linspace(-2, 2, 50)
    d[0, 0] = 1.0
    return d


def _powerlaw():
    rng = np.random.default_rng(13)
    m, n = 60, 45
    d = np.zeros((m, n))
    lens = np.minimum(rng.zipf(1.5, size=m), n)
    for i, k in enumerate(lens):
        cols = rng.choice(n, size=int(k), replace=False)
        d[i, cols] = np.round(rng.standard_normal(int(k)) * 2) / 2 + 0.25
    return d


def _all_equal_values():
    rng = np.random.default_rng(14)
    d = np.where(rng.random((31, 29)) < 0.25, 0.5, 0.0)
    return d


def _regular():
    d = np.zeros((48, 48))
    idx = np.arange(48)
    for off in (-2, 0, 3):
        sel = (idx + off >= 0) & (idx + off < 48)
        d[idx[sel], idx[sel] + off] = 1.0 + 0.125 * idx[sel]
    return d


CORPUS = {
    "empty": _empty,
    "empty_rows": _empty_rows,
    "one_dense_row": _one_dense_row,
    "powerlaw": _powerlaw,
    "all_equal_values": _all_equal_values,
    "regular": _regular,
}

TOL = {np.float32: 1e-5, np.float64: 1e-12}


@pytest.fixture(scope="module", params=list(CORPUS), ids=list(CORPUS))
def dense_case(request):
    return request.param, CORPUS[request.param]()


#: The three public ops entry points share one ``(mat, x, y=None)``
#: signature — the timing harness (`repro.autotune.measure`) drives them
#: interchangeably. name -> packed-artifact builder + runner.
OPS_ACCUMULATE = {
    "ops.spmv": lambda a, x, y: ops.spmv(
        encode_matrix(a, lane_width=16), x, y),
    "ops.sell_spmv": lambda a, x, y: ops.sell_spmv(
        pack_sell(a, lane_width=16), x, y),
    "ops.rgcsr_spmv": lambda a, x, y: ops.rgcsr_spmv(
        pack_rgcsr(RGCSR.from_csr(a, 8)), x, y),
    "ops.bcsr_spmv": lambda a, x, y: ops.bcsr_spmv(
        pack_bcsr(BCSR.from_csr(a, (4, 4))), x, y),
}


@pytest.mark.parametrize("entry", list(OPS_ACCUMULATE),
                         ids=list(OPS_ACCUMULATE))
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_ops_accumulate_y(entry, dtype):
    """y = A x + y through every ops entry point (shared signature)."""
    d = CORPUS["regular"]().astype(dtype)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(7)
    x = rng.standard_normal(a.shape[1]).astype(dtype)
    y0 = rng.standard_normal(a.shape[0]).astype(dtype)
    got = np.asarray(OPS_ACCUMULATE[entry](a, x, y0))
    tol = TOL[dtype]
    np.testing.assert_allclose(got, d @ x + y0, rtol=tol, atol=tol,
                               err_msg=f"{entry} accumulate diverges")


@pytest.mark.parametrize("path", list(SPMV_PATHS), ids=list(SPMV_PATHS))
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_spmv_conformance(dense_case, path, dtype):
    name, d64 = dense_case
    d = d64.astype(dtype)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(99)
    x = rng.standard_normal(a.shape[1]).astype(dtype)
    got = np.asarray(SPMV_PATHS[path](a, x))
    want = d @ x
    assert got.shape == want.shape, f"{path} on {name}: shape mismatch"
    tol = TOL[dtype]
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol,
                               err_msg=f"{path} diverges from dense "
                                       f"A@x on corpus case {name!r}")


# --------------------------------------------------------------------------
# Multi-RHS (SpMM) conformance: every registered format x B x dtype.
# --------------------------------------------------------------------------

#: RHS counts swept: single vector (must match the SpMV path), an odd
#: non-power-of-two, and a serving-pool size.
SPMM_BATCHES = (1, 3, 8)

#: Collection-time snapshot of the registry (matching SPMV_PATHS); the
#: call-time discovery is exercised by tests/test_registry.py.
SPMM_PATHS = registry_spmm_paths()

#: Trimmed corpus for the B-sweep: the adversarial extremes (empty
#: rows, skewed lengths) plus the regular baseline — the full corpus x
#: batch cross-product re-tests structure handling the SpMV sweep
#: already covers, at 3x the encode cost.
SPMM_CASES = ("empty_rows", "powerlaw", "regular")


@pytest.mark.parametrize("path", list(SPMM_PATHS), ids=list(SPMM_PATHS))
@pytest.mark.parametrize("B", SPMM_BATCHES,
                         ids=[f"B{b}" for b in SPMM_BATCHES])
@pytest.mark.parametrize("case", SPMM_CASES, ids=SPMM_CASES)
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_spmm_conformance(case, path, B, dtype):
    d = CORPUS[case]().astype(dtype)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(101)
    X = rng.standard_normal((a.shape[1], B)).astype(dtype)
    got = np.asarray(SPMM_PATHS[path](a, X))
    want = d @ X
    assert got.shape == want.shape, \
        f"{path} on {case} at B={B}: shape {got.shape} != {want.shape}"
    tol = TOL[dtype]
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol,
                               err_msg=f"{path} diverges from dense "
                                       f"A@X on {case!r} at B={B}")


#: The four fused multi-RHS ops entry points (shared y= signature),
#: beside their single-vector siblings in OPS_ACCUMULATE.
OPS_SPMM = {
    "ops.spmm": (OPS_ACCUMULATE["ops.spmv"],
                 lambda a, X, Y: ops.spmm(
                     encode_matrix(a, lane_width=16), X, Y)),
    "ops.sell_spmm": (OPS_ACCUMULATE["ops.sell_spmv"],
                      lambda a, X, Y: ops.sell_spmm(
                          pack_sell(a, lane_width=16), X, Y)),
    "ops.rgcsr_spmm": (OPS_ACCUMULATE["ops.rgcsr_spmv"],
                       lambda a, X, Y: ops.rgcsr_spmm(
                           pack_rgcsr(RGCSR.from_csr(a, 8)), X, Y)),
    "ops.bcsr_spmm": (OPS_ACCUMULATE["ops.bcsr_spmv"],
                      lambda a, X, Y: ops.bcsr_spmm(
                          pack_bcsr(BCSR.from_csr(a, (4, 4))), X, Y)),
}


@pytest.mark.parametrize("entry", list(OPS_SPMM), ids=list(OPS_SPMM))
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_ops_spmm_accumulate_y(entry, dtype):
    """Y = A X + Y through every multi-RHS ops entry point."""
    d = CORPUS["regular"]().astype(dtype)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(8)
    X = rng.standard_normal((a.shape[1], 4)).astype(dtype)
    Y0 = rng.standard_normal((a.shape[0], 4)).astype(dtype)
    _, spmm_fn = OPS_SPMM[entry]
    got = np.asarray(spmm_fn(a, X, Y0))
    tol = TOL[dtype]
    np.testing.assert_allclose(got, d @ X + Y0, rtol=tol, atol=tol,
                               err_msg=f"{entry} accumulate diverges")


@pytest.mark.parametrize("entry", list(OPS_SPMM), ids=list(OPS_SPMM))
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_ops_spmm_bit_identical_at_B1(entry, dtype):
    """The acceptance bar: spmm at B == 1 produces the same BITS as the
    single-vector spmv entry point (it delegates to the same kernel)."""
    d = CORPUS["powerlaw"]().astype(dtype)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(17)
    x = rng.standard_normal(a.shape[1]).astype(dtype)
    spmv_fn, spmm_fn = OPS_SPMM[entry]
    via_spmv = np.asarray(spmv_fn(a, x, None))
    via_spmm = np.asarray(spmm_fn(a, x[:, None], None))[:, 0]
    assert np.array_equal(via_spmv, via_spmm), \
        f"{entry} at B=1 is not bit-identical to the spmv path"


def test_ops_spmm_rejects_1d_rhs():
    a = CSR.from_dense(CORPUS["regular"]())
    x = np.ones(a.shape[1], dtype=np.float32)
    with pytest.raises(ValueError, match="expects x of shape"):
        ops.sell_spmm(pack_sell(a, lane_width=16), x)


@pytest.mark.parametrize("entry", list(OPS_SPMM), ids=list(OPS_SPMM))
def test_ops_spmm_empty_batch(entry):
    """B == 0 (a serving pool with zero active requests) is legal and
    returns an empty (m, 0) result instead of reaching the kernels."""
    d = CORPUS["regular"]().astype(np.float32)
    a = CSR.from_dense(d)
    X = np.zeros((a.shape[1], 0), dtype=np.float32)
    _, spmm_fn = OPS_SPMM[entry]
    got = np.asarray(spmm_fn(a, X, None))
    assert got.shape == (a.shape[0], 0)


# --------------------------------------------------------------------------
# Grid-blocked / pipelined schedules: EXACT bit-identity against the
# plain kernels. Column tiling splits only the B axis — per-column
# arithmetic is untouched — so the pin is ``==``, not allclose, at
# every bn, both tile drivers, and under the pipelined decode.
# --------------------------------------------------------------------------

#: Serving- and training-pool sizes forced through the blocked path
#: (bn=16 splits them into 4 / 16 column tiles, ragged tail included
#: via the non-multiple 2nd case at bn=24).
BLOCKED_BATCHES = (64, 256)


@functools.lru_cache(maxsize=None)
def _blocked_pack(fmt, dtype_name):
    """One packed artifact per (format, dtype) for the blocked sweep —
    the encode is the expensive part, and the tiling contract is about
    the kernel schedule, not the encode."""
    from repro.sparse.registry import get_format
    spec = get_format(fmt)
    d = CORPUS["powerlaw"]().astype(np.dtype(dtype_name))
    a = CSR.from_dense(d)
    return spec, a, spec.pack(a, **spec.conformance_knobs)


@pytest.mark.parametrize("fmt", [s.name for s in iter_formats()])
@pytest.mark.parametrize("B", BLOCKED_BATCHES,
                         ids=[f"B{b}" for b in BLOCKED_BATCHES])
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_spmm_blocked_bit_identical(fmt, B, dtype):
    """Every registered format, both dtypes: the grid-blocked SpMM
    (bn-column tiles, ragged tail included — 24 divides neither pool)
    returns the same BITS as the unblocked kernel. Formats without a
    fused kernel take the per-column fallback, which ignores bn — the
    toy third-party spec contract (tests/test_registry.py) joins
    unchanged."""
    spec, a, packed = _blocked_pack(fmt, np.dtype(dtype).name)
    rng = np.random.default_rng(23)
    X = rng.standard_normal((a.shape[1], B)).astype(dtype)
    base = np.asarray(spec.spmm_runner(packed, X)())
    blocked = np.asarray(spec.spmm_runner(packed, X, bn=24)())
    assert np.array_equal(base, blocked), \
        f"{fmt} blocked bn=24 is not bit-identical at B={B}"


@pytest.mark.parametrize("entry", list(OPS_SPMM), ids=list(OPS_SPMM))
@pytest.mark.parametrize("tile_mode", ["loop", "grid"])
def test_ops_spmm_tile_modes_bit_identical(entry, tile_mode):
    """Both blocked drivers — the lax.map column loop and the 2-D
    pallas grid (what Mosaic double-buffers on hardware) — produce the
    same bits as the unblocked kernel, through the ops entry points."""
    d = CORPUS["powerlaw"]().astype(np.float32)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(29)
    X = rng.standard_normal((a.shape[1], 64)).astype(np.float32)
    _, spmm_fn = OPS_SPMM[entry]
    base = np.asarray(spmm_fn(a, X, None))
    builders = {
        "ops.spmm": lambda: ops.spmm(encode_matrix(a, lane_width=16), X,
                                     bn=16, tile_mode=tile_mode),
        "ops.sell_spmm": lambda: ops.sell_spmm(
            pack_sell(a, lane_width=16), X, bn=16, tile_mode=tile_mode),
        "ops.rgcsr_spmm": lambda: ops.rgcsr_spmm(
            pack_rgcsr(RGCSR.from_csr(a, 8)), X, bn=16,
            tile_mode=tile_mode),
        "ops.bcsr_spmm": lambda: ops.bcsr_spmm(
            pack_bcsr(BCSR.from_csr(a, (4, 4))), X, bn=16,
            tile_mode=tile_mode),
    }
    blocked = np.asarray(builders[entry]())
    assert np.array_equal(base, blocked), \
        f"{entry} tile_mode={tile_mode} is not bit-identical"


@pytest.mark.parametrize("fmt", ["dtans", "rgcsr_dtans", "bcsr_dtans"])
@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_spmm_pipelined_bit_identical(fmt, dtype):
    """The double-buffered decode (prologue + decode-ahead loop) is a
    pure reordering of the same segment_step sequence — pinned
    bit-identical for every entropy-decoding family, alone and
    composed with column tiling."""
    spec, a, packed = _blocked_pack(fmt, np.dtype(dtype).name)
    rng = np.random.default_rng(31)
    X = rng.standard_normal((a.shape[1], 64)).astype(dtype)
    base = np.asarray(spec.spmm_runner(packed, X)())
    piped = np.asarray(spec.spmm_runner(packed, X, pipeline=True)())
    assert np.array_equal(base, piped), f"{fmt} pipelined != plain"
    both = np.asarray(spec.spmm_runner(packed, X, pipeline=True,
                                       bn=16)())
    assert np.array_equal(base, both), f"{fmt} pipelined+blocked != plain"


@pytest.mark.parametrize("dtype", [np.float32, np.float64],
                         ids=["f32", "f64"])
def test_bcsr_dtans_fused_bit_identical(dtype):
    """The fused BCSR-dtANS block-decode contraction (one shared
    column gather per block row, `shared_cols`) returns the same bits
    as the generic per-lane gather path, spmv and spmm."""
    d = CORPUS["regular"]().astype(dtype)
    a = CSR.from_dense(d)
    pm = pack_matrix(encode_bcsr_matrix(a, block_shape=(2, 2)))
    assert pm.shared_cols, "BCSR-dtANS pack should mark shared_cols"
    rng = np.random.default_rng(37)
    X = rng.standard_normal((a.shape[1], 16)).astype(dtype)
    generic = np.asarray(ops.spmm(pm, X, fused=False))
    fused = np.asarray(ops.spmm(pm, X))      # fused=None -> auto-on
    assert np.array_equal(generic, fused), "fused spmm != generic"
    x = X[:, 0]
    gv = np.asarray(ops.spmv(pm, x, fused=False))
    fv = np.asarray(ops.spmv(pm, x, fused=True))
    assert np.array_equal(gv, fv), "fused spmv != generic"


def test_fused_rejected_without_shared_cols():
    """fused=True on a plain (non-block-filled) CSR-dtANS pack is a
    loud error, not a silent wrong answer."""
    a = CSR.from_dense(CORPUS["regular"]())
    pm = pack_matrix(encode_matrix(a, lane_width=16))
    x = np.ones((a.shape[1], 4), dtype=np.float32)
    with pytest.raises(ValueError, match="block-filled"):
        ops.spmm(pm, x, fused=True)


@pytest.mark.parametrize("entry", list(OPS_SPMM), ids=list(OPS_SPMM))
def test_ops_spmm_large_B_tiled(entry):
    """B = 4096 runs through every kernel-backed family with a forced
    tiny VMEM budget — x/y never resident whole (the budget admits
    only a fraction of the pool per tile) — and stays bit-identical to
    the unblocked kernel."""
    d = CORPUS["regular"]().astype(np.float32)
    a = CSR.from_dense(d)
    rng = np.random.default_rng(41)
    B = 4096
    X = rng.standard_normal((a.shape[1], B)).astype(np.float32)
    budget = 512 * 1024        # forces bn << B for these shapes
    builders = {
        "ops.spmm": lambda **kw: ops.spmm(
            encode_matrix(a, lane_width=16), X, **kw),
        "ops.sell_spmm": lambda **kw: ops.sell_spmm(
            pack_sell(a, lane_width=16), X, **kw),
        "ops.rgcsr_spmm": lambda **kw: ops.rgcsr_spmm(
            pack_rgcsr(RGCSR.from_csr(a, 8)), X, **kw),
        "ops.bcsr_spmm": lambda **kw: ops.bcsr_spmm(
            pack_bcsr(BCSR.from_csr(a, (4, 4))), X, **kw),
    }
    from repro.kernels.tiling import choose_bn
    bn = choose_bn(a.shape[1], 16, B, 4, budget)
    assert bn is not None and bn < B, "budget did not force tiling"
    base = np.asarray(builders[entry]())
    tiled = np.asarray(builders[entry](vmem_budget=budget))
    assert np.array_equal(base, tiled), \
        f"{entry} at B={B} tiled under budget != unblocked"
