"""Unit tests for the grid-blocked SpMM schedule (`repro.kernels.tiling`):
the VMEM-budget tile sizer, the obs accounting contract (x/y bytes land
once per PASS, never per column tile), the cost model's capacity term,
and the `SparseLinear` / `forward_hidden` sparse-head wiring at
training-shaped batch."""

import numpy as np
import pytest

from repro.kernels import ops, pack
from repro.kernels.tiling import (DEFAULT_VMEM_BYTES, LANE, MIN_BN,
                                  TILE_FRACTION, choose_bn, n_col_tiles,
                                  resolve_tile_mode)
from repro.sparse.formats import CSR


def _sparse(m, n, density=0.1, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n)).astype(dtype)
    a[rng.random((m, n)) > density] = 0
    return a


# --------------------------------------------------------------------------
# choose_bn: the VMEM-budget column-tile sizer.
# --------------------------------------------------------------------------


class TestChooseBn:
    def test_small_batch_untiled(self):
        """A pool whose x/y working set fits the budget never tiles."""
        assert choose_bn(1024, 128, 8, 4) is None
        assert choose_bn(1024, 128, 1, 4) is None

    def test_large_batch_tiles(self):
        """A pool that overflows the budget gets a bn < B."""
        bn = choose_bn(1024, 128, 1 << 20, 4)
        assert bn is not None and bn < (1 << 20)

    def test_budget_scales_bn(self):
        """Halving the budget can only shrink the tile."""
        n, rows, B, vb = 4096, 128, 65536, 4
        big = choose_bn(n, rows, B, vb, DEFAULT_VMEM_BYTES)
        small = choose_bn(n, rows, B, vb, DEFAULT_VMEM_BYTES // 2)
        assert small is not None and big is not None
        assert small <= big

    def test_lane_snap(self):
        """Tiles at least one lane wide snap DOWN to a lane multiple
        (the (8, 128) register tile shape of the accelerator)."""
        for budget in (10 ** 6, 10 ** 7, 10 ** 8):
            bn = choose_bn(4096, 128, 1 << 22, 4, budget)
            if bn is not None and bn >= LANE:
                assert bn % LANE == 0

    def test_min_bn_floor(self):
        """Even an absurdly small budget yields a usable tile."""
        bn = choose_bn(1 << 20, 128, 1 << 20, 8, 1024)
        assert bn == MIN_BN

    def test_fits_budget(self):
        """The chosen tile's x/y columns fit the budgeted fraction
        (above the MIN_BN floor, where the budget is authoritative)."""
        n, rows, vb = 8192, 128, 4
        budget = 2 ** 22
        bn = choose_bn(n, rows, 1 << 22, vb, budget)
        assert bn is not None
        if bn > MIN_BN:
            assert bn * (n + rows) * vb <= budget * TILE_FRACTION

    def test_n_col_tiles_consistent(self):
        """n_col_tiles == ceil(B / choose_bn), 1 when untiled."""
        assert n_col_tiles(1024, 128, 8, 4) == 1
        B = 1 << 20
        bn = choose_bn(1024, 128, B, 4)
        assert n_col_tiles(1024, 128, B, 4) == -(-B // bn)

    def test_rejects_bad_bn(self):
        pm = pack.pack_matrix(_encode_small())
        x = np.ones((pm.shape[1], 4), np.float32)
        with pytest.raises(ValueError):
            ops.spmm(pm, x, bn=0)

    def test_resolve_tile_mode(self):
        assert resolve_tile_mode("auto", True) == "loop"
        assert resolve_tile_mode("auto", False) == "grid"
        assert resolve_tile_mode("loop", False) == "loop"
        assert resolve_tile_mode("grid", True) == "grid"
        with pytest.raises(ValueError):
            resolve_tile_mode("diagonal", True)


def _encode_small():
    from repro.core.csr_dtans import encode_matrix
    return encode_matrix(CSR.from_dense(_sparse(32, 24)), lane_width=16)


# --------------------------------------------------------------------------
# Obs accounting: bytes are per PASS, invariant to the tile count.
# --------------------------------------------------------------------------


class TestObsTileAccounting:
    def _deltas(self, bn):
        """Counter/histogram deltas of one ops.spmm pass at this bn."""
        from repro import obs
        reg = obs.default_registry()
        pm = pack.pack_matrix(_encode_small())
        x = np.ones((pm.shape[1], 32), np.float32)
        before = reg.snapshot()
        ops.spmm(pm, x, bn=bn)
        after = reg.snapshot()
        dc = {k: v - before["counters"].get(k, 0)
              for k, v in after["counters"].items()}
        hb = before["histograms"].get("kernels.col_tiles", {"count": 0})
        ha = after["histograms"].get("kernels.col_tiles",
                                     {"count": 0, "max": 0})
        return dc, ha["count"] - hb["count"], ha.get("max")

    def test_bytes_invariant_to_bn(self):
        """x/y/matrix byte counters record the PASS, not the schedule:
        a 4-way column-tiled pass reports exactly the bytes of the
        untiled pass (satellite contract of `ops._record_pass`)."""
        base, _, _ = self._deltas(None)
        tiled, _, _ = self._deltas(8)
        byte_keys = [k for k in base if "bytes" in k]
        assert byte_keys, "no byte counters recorded?"
        for name in byte_keys:
            assert tiled.get(name) == base[name], \
                f"{name} changed under column tiling"

    def test_col_tiles_histogram(self):
        """The tile count itself IS recorded — as a histogram
        observation, not a byte counter."""
        _, dcount, hmax = self._deltas(8)
        # max is cumulative across the process registry, so >=: this
        # pass observed ceil(32 / 8) = 4 tiles
        assert dcount == 1 and hmax >= 4.0


# --------------------------------------------------------------------------
# Cost model: the VMEM-capacity tile term.
# --------------------------------------------------------------------------


class TestCostModelTileTerm:
    def test_spmm_bytes_charges_matrix_per_tile(self):
        from repro.autotune.cost_model import spmm_bytes
        one = spmm_bytes(1000, 64, 32, 4, batch=8, col_tiles=1)
        four = spmm_bytes(1000, 64, 32, 4, batch=8, col_tiles=4)
        assert four - one == 3 * 1000          # matrix re-read 3 extra times
        assert spmm_bytes(1000, 64, 32, 4, 8) == one   # default unchanged

    def test_work_time_decode_scales_with_tiles(self):
        from repro.autotune.cost_model import V5E, work_time
        from repro.sparse.registry import CostTerms
        t = CostTerms(lockstep=1e6, decode=1e6)
        t1 = work_time(t, V5E, batch=8, col_tiles=1)
        t4 = work_time(t, V5E, batch=8, col_tiles=4)
        assert t4 > t1                          # re-decode per tile
        plain = CostTerms(lockstep=1e6)
        assert work_time(plain, V5E, 8, 1) == work_time(plain, V5E, 8, 4)

    def test_candidate_time_monotone_in_batch_past_capacity(self):
        """Once the batch overflows VMEM, candidate_time keeps growing
        (the re-decode term) rather than amortizing forever."""
        from repro.autotune.cost_model import candidate_time
        from repro.autotune.fingerprint import fingerprint
        a = CSR.from_dense(_sparse(64, 48))
        fp = fingerprint(a)
        ts = [candidate_time(fp, "dtans", 4000, warm=True, batch=b)
              for b in (1, 1 << 12, 1 << 16, 1 << 20)]
        assert all(b < c for b, c in zip(ts, ts[1:]))

    def test_machine_signature_includes_vmem(self):
        """Recalibrating vmem_bytes must invalidate cached decisions."""
        import dataclasses
        from repro.autotune.cost_model import V5E
        other = dataclasses.replace(V5E, vmem_bytes=2 * V5E.vmem_bytes)
        assert other.signature() != V5E.signature()

    def test_from_dict_roundtrip(self):
        from repro.autotune.cost_model import MachineModel, V5E
        assert MachineModel.from_dict(V5E.to_dict()) == V5E


# --------------------------------------------------------------------------
# Serving + models: the sparse LM head at training-shaped batch.
# --------------------------------------------------------------------------


class TestSparseHeadWiring:
    def test_sparse_linear_blocked_bit_identical(self):
        """SparseLinear.apply with an explicit bn (and the pipelined
        decode) matches the default path bit-for-bit."""
        from repro.serving.sparse_linear import SparseLinear
        rng = np.random.default_rng(5)
        w = rng.standard_normal((48, 40)).astype(np.float32)
        layer = SparseLinear.from_dense(w, sparsity=0.6, lane_width=16)
        x = rng.standard_normal((24, 48)).astype(np.float32)
        base = np.asarray(layer.apply(x))
        assert np.array_equal(base, np.asarray(layer.apply(x, bn=8)))
        assert np.array_equal(base,
                              np.asarray(layer.apply(x, pipeline=True)))

    def test_train_lm_sparse_head_eval(self):
        """The example's sparse-head eval runs a training-shaped
        B = batch * seq pool through the head and tracks the dense
        loss (exactly at sparsity 0 up to quantization)."""
        import jax
        import sys
        sys.path.insert(0, "examples")
        from train_lm import sparse_head_eval
        from repro.configs import get_smoke
        from repro.models import api
        cfg = get_smoke("smollm-135m").with_(vocab=128)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        B, S = 2, 16
        batch = {
            "inputs": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
            "targets": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
            "mask": np.ones((B, S), np.float32),
        }
        dense, sparse, head = sparse_head_eval(params, cfg, batch,
                                               sparsity=0.3)
        assert np.isfinite(dense) and np.isfinite(sparse)
        assert head.d_out == cfg.vocab
        # an untrained model scores near uniform; the compressed head
        # must stay in the same regime, not diverge
        assert abs(sparse - dense) < 1.0

    def test_forward_hidden_matches_forward(self):
        """forward == lm_head(embed, forward_hidden) — the seam the
        sparse head replaces."""
        import jax
        from repro.configs import get_smoke
        from repro.models import api
        from repro.models.layers import lm_head
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        params = api.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(2)
        batch = {"inputs": rng.integers(0, 64, (2, 8)).astype(np.int32)}
        hidden, _ = api.forward_hidden(params, cfg, batch)
        logits, _ = api.forward(params, cfg, batch)
        np.testing.assert_array_equal(
            np.asarray(lm_head(params["embed"], hidden)),
            np.asarray(logits))
