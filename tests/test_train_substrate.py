"""Training-substrate tests: optimizers, pipeline determinism, checkpoint
atomicity/restore, fault tolerance, elasticity, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.pipeline import PipelineConfig, SyntheticTokens
from repro.optim import make_optimizer
from repro.optim.grad_compress import compress, init_error_state
from repro.train import checkpoint as ckpt
from repro.train.trainer import TrainConfig, Trainer


class TestOptimizers:
    @pytest.mark.parametrize("name,kw", [
        ("adamw", {}), ("adafactor", {}),
        ("adafactor", {"master": False}),
    ])
    def test_reduces_quadratic(self, name, kw):
        opt = make_optimizer(name, lr=0.1, **kw)
        params = {"w": jnp.asarray([3.0, -2.0, 1.0], dtype=jnp.float32)}
        state = opt.init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        l0 = float(loss(params))
        for _ in range(60):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert float(loss(params)) < 0.05 * l0

    def test_adafactor_state_is_factored(self):
        opt = make_optimizer("adafactor", lr=0.1, master=False)
        params = {"w": jnp.zeros((64, 32), dtype=jnp.float32)}
        state = opt.init(params)
        n_state = sum(x.size for x in jax.tree.leaves(state["v"]))
        assert n_state == 64 + 32  # O(n+m), not O(nm)


class TestGradCompression:
    def test_error_feedback_preserves_sum(self):
        rng = np.random.default_rng(0)
        g_true = {"w": jnp.asarray(rng.standard_normal(1000) * 1e-3,
                                   dtype=jnp.float32)}
        err = init_error_state(g_true)
        total = np.zeros(1000)
        for _ in range(50):
            comp, err = compress(g_true, err)
            total += np.asarray(comp["w"], dtype=np.float64)
        # with error feedback, accumulated quantized sum ~= true sum
        np.testing.assert_allclose(total / 50,
                                   np.asarray(g_true["w"]), rtol=1e-2,
                                   atol=1e-6)


class TestPipeline:
    def test_deterministic_and_resumable(self):
        cfg = PipelineConfig(vocab=100, seq_len=32, global_batch=8, seed=3)
        p1, p2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
        b1 = p1.batch(7)
        b2 = p2.batch(7)  # fresh object, same step -> identical batch
        np.testing.assert_array_equal(b1["inputs"], b2["inputs"])

    def test_sharding_partition(self):
        cfg = PipelineConfig(vocab=100, seq_len=16, global_batch=8, seed=0)
        p = SyntheticTokens(cfg)
        sh0 = p.batch(3, shard=0, num_shards=4)
        sh1 = p.batch(3, shard=1, num_shards=4)
        assert sh0["inputs"].shape == (2, 16)
        assert not np.array_equal(sh0["inputs"], sh1["inputs"])


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": np.arange(6).reshape(2, 3).astype(np.float32),
                "b": {"c": np.asarray(3)}}
        ckpt.save(5, tree, str(tmp_path))
        step, back = ckpt.restore_latest(str(tmp_path), tree)
        assert step == 5
        np.testing.assert_array_equal(back["a"], tree["a"])

    def test_torn_checkpoint_skipped(self, tmp_path):
        tree = {"a": np.ones(3)}
        ckpt.save(1, tree, str(tmp_path))
        # fake a torn step-2: directory without manifest
        os.makedirs(tmp_path / "step_00000002")
        step, back = ckpt.restore_latest(str(tmp_path), tree)
        assert step == 1

    def test_async_checkpointer_gc(self, tmp_path):
        c = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            c.save_async(s, {"x": np.full(4, s)})
        c.wait()
        assert ckpt.list_steps(str(tmp_path)) == [3, 4]


class TestTrainerFaultTolerance:
    def test_crash_restore_resume_deterministic(self, tmp_path):
        cfg = get_smoke("smollm-135m").with_(vocab=64)
        pipe = SyntheticTokens(PipelineConfig(
            vocab=64, seq_len=16, global_batch=4, seed=0))
        tcfg = TrainConfig(optimizer="adamw", lr=1e-3, microbatches=2,
                           ckpt_every=4, ckpt_dir=str(tmp_path))
        t1 = Trainer(cfg, tcfg, pipe, rng=jax.random.PRNGKey(1))
        with pytest.raises(RuntimeError):
            t1.run(10, log_every=0, fail_at=6)
        assert t1.try_restore()
        assert t1.step == 4           # restored at the checkpoint
        t1.run(10, log_every=0)
        # a run that never crashed must produce the same final loss
        t2 = Trainer(cfg, tcfg.__class__(optimizer="adamw", lr=1e-3,
                                         microbatches=2),
                     pipe, rng=jax.random.PRNGKey(1))
        t2.run(10, log_every=0)
        assert abs(t1.history[-1] - t2.history[-1]) < 1e-4


class TestElastic:
    def test_reshard_roundtrip(self):
        from repro.train.elastic import reshard, shrink_data_axis
        from jax.sharding import PartitionSpec as P, Mesh
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        tree = {"w": jnp.ones((4, 4))}
        out = reshard(tree, mesh, {"w": P(None, None)})
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.ones((4, 4)))
        assert shrink_data_axis(256, 16, 8) == 32
